"""GQA attention: training/prefill (chunked-causal flash-style, pure JAX) and
single-token decode against a (possibly sequence-sharded) KV cache.

The chunked path is the reference ("ref") implementation that the Pallas
flash-attention kernel in ``repro.kernels.attention`` is validated against.
It never materializes the full (S, S) score matrix: queries are processed in
chunks (python-unrolled so each chunk only visits its causal KV prefix —
no wasted upper-triangle FLOPs) with an online-softmax accumulator.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, Hkv, G, Dh)  k: (B, Skv, Hkv, Dh) -> (B, Hkv, G, Sq, Skv)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_values(p, v):
    """p: (B, Hkv, G, Sq, Skv)  v: (B, Skv, Hkv, Dh) -> (B, Sq, Hkv, G, Dh)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def dense_causal_attention(q, k, v, *, window: int | None = None,
                           q_offset: int = 0) -> jax.Array:
    """Exact, materializes (Sq, Skv) scores. Use for small S / tests.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh). Queries are at absolute
    positions q_offset..q_offset+Sq-1; keys at 0..Skv-1. Returns
    (B, Sq, H, Dh).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh) * (1.0 / math.sqrt(dh))
    s = _gqa_scores(qg, k)                               # (B,Hkv,G,Sq,Skv)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > (qpos - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_values(p, v)
    return o.reshape(b, sq, h, dh)


def chunked_causal_attention(q, k, v, *, q_chunk: int = 512,
                             kv_chunk: int = 1024,
                             window: int | None = None) -> jax.Array:
    """Flash-style online-softmax attention, causal, optional sliding window.

    Self-attention only (Sq == Skv, positions aligned). Python-unrolls query
    chunks; each q-chunk scans only its causal KV prefix (and only the chunks
    inside the sliding window when set), so FLOPs match the true lower
    triangle at chunk granularity.

    q: (B, S, H, Dh); k, v: (B, S, Hkv, Dh) -> (B, S, H, Dh)
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    if s % q_chunk or s % kv_chunk:
        # Pad to a chunk multiple; padded keys are causally in the future of
        # every real query, so they are masked; padded query rows are sliced.
        lcm = q_chunk * kv_chunk // math.gcd(q_chunk, kv_chunk)
        sp = ((s + lcm - 1) // lcm) * lcm
        pad = [(0, 0), (0, sp - s), (0, 0), (0, 0)]
        out = chunked_causal_attention(
            jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad),
            q_chunk=q_chunk, kv_chunk=kv_chunk, window=window)
        return out[:, :s]
    n_q = s // q_chunk
    scale = 1.0 / math.sqrt(dh)

    kc = k.reshape(b, s // kv_chunk, kv_chunk, hkv, dh)
    vc = v.reshape(b, s // kv_chunk, kv_chunk, hkv, dh)

    def scores(qi_g, kj, qpos, kpos):
        st = _gqa_scores(qi_g, kj)                            # (B,Hkv,G,qc,kc)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        return jnp.where(mask, st, NEG_INF)

    outs = []
    for i in range(n_q):
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        qi_g = qi.reshape(b, q_chunk, hkv, g, dh) * scale
        qpos = i * q_chunk + jnp.arange(q_chunk)

        # Causal prefix of KV chunks for this q chunk (static bounds).
        j_hi = (i * q_chunk + q_chunk + kv_chunk - 1) // kv_chunk   # exclusive
        j_lo = 0
        if window is not None:
            j_lo = max(0, (i * q_chunk - window) // kv_chunk)
        n_kv = j_hi - j_lo

        def body(carry, kv_j):
            m, l, acc = carry
            kj, vj, j = kv_j
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            st = scores(qi_g, kj, qpos, kpos)                 # (B,Hkv,G,qc,kc)
            m_new = jnp.maximum(m, st.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(st - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd",
                p.astype(vj.dtype), vj).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        ks = jax.lax.dynamic_slice_in_dim(kc, j_lo, n_kv, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vc, j_lo, n_kv, axis=1)
        js = j_lo + jnp.arange(n_kv)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), js))
        o = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,Hkv,G,qc,Dh)
        o = jnp.moveaxis(o, 3, 1).reshape(b, q_chunk, h, dh)
        outs.append(o.astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def decode_attention(q, k_cache, v_cache, pos, *, window: int | None = None,
                     impl: str = "ref", kv_len: int | None = None,
                     block_tables=None) -> jax.Array:
    """Single-token decode: q (B, 1, H, Dh) vs cache (B, Skv, Hkv, Dh).

    ``pos`` is the position of the new token — a scalar int32, or a (B,)
    vector when slots decode at independent positions (continuous batching,
    repro.serve). Cache entries at indices > pos are masked per row. With
    the cache sequence dim sharded over the "model" mesh axis, XLA SPMD
    turns the softmax/value reductions into cross-device psums
    (distributed flash-decoding).

    ``impl`` routes through the kernel suite
    (``repro.kernels.attention.ops.flash_decode``): ``"pallas"`` runs the
    split-KV flash-decode kernel (interpret mode off-TPU), ``"auto"``
    picks it on TPU, and ``kv_len`` — the static occupancy bound
    (``max(pos) + 1``, rounded up to the KV block grid by the router) —
    caps how much of the horizon is ever read on any routed path. The
    plain ``"ref"`` default below stays inline: the dense full-horizon
    read whose traffic the split-KV kernel exists to avoid, kept as the
    oracle it is validated against.

    ``block_tables`` switches to the paged cache layout: the caches are
    physical page pools (P, page, Hkv, Dh) and ``block_tables`` (B, NB)
    int32 maps each row's logical pages to physical ones
    (repro.serve.pages). Routed impls run the scalar-prefetched paged
    kernel (``ops.flash_decode_paged``); the inline ``"ref"`` path
    gathers pages in logical order and falls through to the very same
    dense computation below, so paged-vs-dense is bit-identical (masked
    rows contribute exact zeros).
    """
    if block_tables is not None:
        if impl != "ref" or kv_len is not None:
            from repro.kernels.attention import ops as kops
            return kops.flash_decode_paged(q, k_cache, v_cache,
                                           block_tables, pos,
                                           window=window, impl=impl,
                                           kv_len=kv_len)
        nb = q.shape[0]
        hkv_p, dh_p = k_cache.shape[2], k_cache.shape[3]
        bt = jnp.asarray(block_tables, jnp.int32)
        k_cache = k_cache[bt].reshape(nb, -1, hkv_p, dh_p)
        v_cache = v_cache[bt].reshape(nb, -1, hkv_p, dh_p)
    elif impl != "ref" or kv_len is not None:
        from repro.kernels.attention import ops as kops
        return kops.flash_decode(q, k_cache, v_cache, pos, window=window,
                                 impl=impl, kv_len=kv_len)
    b, _, h, dh = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, dh) * (1.0 / math.sqrt(dh))
    s = _gqa_scores(qg, k_cache)                              # (B,Hkv,G,1,Skv)
    kpos = jnp.arange(skv)
    posb = jnp.reshape(jnp.asarray(pos), (-1, 1))             # (1|B, 1)
    mask = kpos[None, :] <= posb
    if window is not None:
        mask &= kpos[None, :] > (posb - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_values(p, v_cache)                               # (B,1,Hkv,G,Dh)
    return o.reshape(b, 1, h, dh)
