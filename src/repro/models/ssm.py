"""Mamba-1 selective state-space block (as used in Jamba, arXiv:2403.19887).

TPU-native adaptation (DESIGN.md §2): the CUDA "selective scan" kernel is a
sequential HBM-resident recurrence; on TPU we use a *chunkwise two-pass*
scheme so the sequential depth is 2*L + T/L instead of T, and every step is
a wide VPU-friendly elementwise op over (B, n_chunks, d_inner, N):

  pass 1: within-chunk scan (vectorized over chunks, h0=0) -> per-chunk
          local final states + cumulative decay products
  bridge: tiny scan over chunks stitches true chunk-initial states
  pass 2: within-chunk re-scan with true initial states, emitting
          y_t = C_t . h_t (the (T, d_inner, N) state tensor is never stored).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_conv1d(x: jax.Array, w: jax.Array, cache: jax.Array | None = None):
    """Depthwise causal conv. x: (B, T, C), w: (K, C).

    If cache (B, K-1, C) is given (decode), it is prepended; the updated
    cache (last K-1 raw inputs) is always returned.
    """
    k = w.shape[0]
    if cache is not None:
        xx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    else:
        xx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    new_cache = xx[:, -(k - 1):, :] if k > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype)
    out = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out, new_cache


def _ssm_scan_chunked(a_in, u_b, c_mat, h0, chunk: int):
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + u_t, y_t = C_t . h_t.

    a_in: (B, T, D, N) decay in (0,1]; u_b: (B, T, D, N) input;
    c_mat: (B, T, N); h0: (B, D, N). Returns y (B, T, D), h_T (B, D, N).
    """
    b, t, d, n = a_in.shape
    chunk = min(chunk, t)
    if t % chunk:
        # Pad with identity steps (a=1, u=0): h is unchanged through padding,
        # so the final state stays exact; padded y rows are sliced off.
        tp = ((t + chunk - 1) // chunk) * chunk
        a_p = jnp.pad(a_in, [(0, 0), (0, tp - t), (0, 0), (0, 0)],
                      constant_values=1.0)
        u_p = jnp.pad(u_b, [(0, 0), (0, tp - t), (0, 0), (0, 0)])
        c_p = jnp.pad(c_mat, [(0, 0), (0, tp - t), (0, 0)])
        y, h_final = _ssm_scan_chunked(a_p, u_p, c_p, h0, chunk)
        return y[:, :t], h_final
    nc = t // chunk

    def to_steps(x):  # (B, T, ...) -> (L, B, nc, ...)
        return jnp.moveaxis(x.reshape(b, nc, chunk, *x.shape[2:]), 2, 0)

    a_s, u_s, c_s = to_steps(a_in), to_steps(u_b), to_steps(c_mat)

    # Pass 1: local states with h=0 at chunk start + cumulative decay.
    def p1(carry, xs):
        h, pr = carry
        a_t, u_t = xs
        return (a_t * h + u_t, pr * a_t), None

    h_loc0 = jnp.zeros((b, nc, d, n), jnp.float32)
    pr0 = jnp.ones((b, nc, d, n), jnp.float32)
    (h_loc, pr), _ = jax.lax.scan(p1, (h_loc0, pr0), (a_s, u_s))

    # Bridge: true state entering each chunk.
    def p2(h, xs):
        pr_c, hl_c = xs
        return pr_c * h + hl_c, h          # emit state *entering* this chunk

    h_final, h_init = jax.lax.scan(
        p2, h0.astype(jnp.float32),
        (jnp.moveaxis(pr, 1, 0), jnp.moveaxis(h_loc, 1, 0)))
    h_init = jnp.moveaxis(h_init, 0, 1)    # (B, nc, D, N)

    # Pass 2: re-scan with true initial states, emit y only.
    def p3(h, xs):
        a_t, u_t, c_t = xs
        h = a_t * h + u_t
        return h, jnp.einsum("bgdn,bgn->bgd", h, c_t)

    _, y_s = jax.lax.scan(p3, h_init, (a_s, u_s, c_s))
    y = jnp.moveaxis(y_s, 0, 2).reshape(b, t, d)      # (L,B,nc,D)->(B,T,D)
    return y, h_final


def _ssm_scan_chunked_fused(dt, b_mat, c_mat, xif, a, h0, chunk: int):
    """Like _ssm_scan_chunked, but the decay a_t = exp(dt_t * A) and input
    u_t = dt_t * x_t * B_t are computed INSIDE the scan steps from the
    (B, T, d)-sized streams — the (B, T, d_inner, N) tensors never hit HBM
    (§Perf iteration: cuts the mamba layer's memory term ~2x).

    dt, xif: (B, T, D); b_mat, c_mat: (B, T, N); a: (D, N); h0: (B, D, N).
    """
    b, t, d = dt.shape
    n = a.shape[1]
    chunk = min(chunk, t)
    if t % chunk:
        tp_len = ((t + chunk - 1) // chunk) * chunk
        pad2 = [(0, 0), (0, tp_len - t), (0, 0)]
        # dt=0 -> a_bar=1, u=0: identity steps
        y, h_final = _ssm_scan_chunked_fused(
            jnp.pad(dt, pad2), jnp.pad(b_mat, pad2), jnp.pad(c_mat, pad2),
            jnp.pad(xif, pad2), a, h0, chunk)
        return y[:, :t], h_final
    nc = t // chunk

    def to_steps(z):  # (B, T, ...) -> (L, B, nc, ...)
        return jnp.moveaxis(z.reshape(b, nc, chunk, *z.shape[2:]), 2, 0)

    dt_s, b_s, c_s, x_s = (to_steps(z) for z in (dt, b_mat, c_mat, xif))

    def a_u(dt_t, b_t, x_t):
        a_t = jnp.exp(dt_t[..., None] * a)             # (B, nc, D, N)
        u_t = (dt_t * x_t)[..., None] * b_t[:, :, None, :]
        return a_t, u_t

    # jax.checkpoint on the step bodies: backward recomputes the cheap
    # decay/input math instead of saving (B, nc, D, N) residuals per step
    # (without it the fused form is a net memory LOSS — see EXPERIMENTS.md
    # §Perf H3 iteration log).
    @jax.checkpoint
    def p1(carry, xs):
        h, pr = carry
        dt_t, b_t, x_t = xs
        a_t, u_t = a_u(dt_t, b_t, x_t)
        return (a_t * h + u_t, pr * a_t), None

    h_loc0 = jnp.zeros((b, nc, d, n), jnp.float32)
    pr0 = jnp.ones((b, nc, d, n), jnp.float32)
    (h_loc, pr), _ = jax.lax.scan(p1, (h_loc0, pr0), (dt_s, b_s, x_s))

    def p2(h, xs):
        pr_c, hl_c = xs
        return pr_c * h + hl_c, h

    h_final, h_init = jax.lax.scan(
        p2, h0.astype(jnp.float32),
        (jnp.moveaxis(pr, 1, 0), jnp.moveaxis(h_loc, 1, 0)))
    h_init = jnp.moveaxis(h_init, 0, 1)

    @jax.checkpoint
    def p3(h, xs):
        dt_t, b_t, c_t, x_t = xs
        a_t, u_t = a_u(dt_t, b_t, x_t)
        h = a_t * h + u_t
        return h, jnp.einsum("bgdn,bgn->bgd", h, c_t)

    _, y_s = jax.lax.scan(p3, h_init, (dt_s, b_s, c_s, x_s))
    y = jnp.moveaxis(y_s, 0, 2).reshape(b, t, d)
    return y, h_final


def mamba_mixer(p: dict, x: jax.Array, *, d_state: int, conv_dim: int,
                chunk: int = 128, state: dict | None = None,
                want_state: bool = False, fuse: bool = True):
    """Mamba-1 mixer. x: (B, T, d_model) (already pre-normed).

    p: in_x/in_z (d, di), conv_w (K, di), conv_b (di,), x_dbc (di, R+2N),
       dt_w (R, di), dt_b (di,), a_log (di, N), d_skip (di,), out_proj (di, d).
    state (decode): {"h": (B, di, N) f32, "conv": (B, K-1, di)} or None.
    Returns (y (B, T, d), new_state | None).
    """
    b, t, _ = x.shape
    di = p["conv_w"].shape[1]
    dt_rank = p["dt_w"].shape[0]

    z = x @ p["in_z"]
    xi_raw = x @ p["in_x"]                            # (B, T, di)
    conv_cache = state["conv"] if state is not None else None
    xi, new_conv = causal_conv1d(xi_raw, p["conv_w"], conv_cache)
    xi = jax.nn.silu(xi + p["conv_b"])

    dbc = xi @ p["x_dbc"]                             # (B, T, R+2N)
    dt_low = dbc[..., :dt_rank]
    b_mat = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    c_mat = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_low @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))      # (di, N), negative
    xif = xi.astype(jnp.float32)

    if state is None:
        h0 = jnp.zeros((b, di, d_state), jnp.float32)
        if fuse:
            y, h_t = _ssm_scan_chunked_fused(dt, b_mat, c_mat, xif, a,
                                             h0, chunk)
        else:
            a_bar = jnp.exp(dt[..., None] * a)        # (B, T, di, N) in HBM
            u = (dt * xif)[..., None] * b_mat[..., None, :]
            y, h_t = _ssm_scan_chunked(a_bar, u, c_mat, h0, chunk)
    else:
        a_bar = jnp.exp(dt[..., None] * a)            # (B, T=1.., di, N)
        u = (dt * xif)[..., None] * b_mat[..., None, :]
        def step(h, xs):
            a_t, u_t, c_t = xs
            h = a_t * h + u_t
            return h, jnp.einsum("bdn,bn->bd", h, c_t)

        h_t, y_s = jax.lax.scan(
            step, state["h"].astype(jnp.float32),
            (jnp.moveaxis(a_bar, 1, 0), jnp.moveaxis(u, 1, 0),
             jnp.moveaxis(c_mat, 1, 0)))
        y = jnp.moveaxis(y_s, 0, 1)

    y = y + xif * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = {"h": h_t, "conv": new_conv} if want_state else None
    return out, new_state
