"""Model assembly: parameter tables, block program (scan over repeated
pattern units), and forward passes for train / prefill / decode.

Single source of truth: every parameter is declared once as a
:class:`ParamDef` (shape, logical axes, init) — ``init_params``,
``param_shapes`` and ``param_pspecs`` all derive from the same table, so
sharding specs can never drift from the parameter tree structure.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import stores as stores_lib
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.utils.sharding import sc, spec_for


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter leaf: shape, sharding axis names, and initializer."""

    shape: tuple
    axes: tuple
    init: str = "normal"     # normal|zeros|ones|embed|alog|dtbias


def _is_def(x):
    return isinstance(x, ParamDef)


def _map_defs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Parameter tables
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff
    p = {
        "wq": ParamDef((d, h, dh), ("embed", "qheads", None)),
        "wk": ParamDef((d, hkv, dh), ("embed", "kvheads", None)),
        "wv": ParamDef((d, hkv, dh), ("embed", "kvheads", None)),
        "wo": ParamDef((h, dh, d), ("qheads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((h, dh), ("qheads", None), "zeros")
        p["bk"] = ParamDef((hkv, dh), ("kvheads", None), "zeros")
        p["bv"] = ParamDef((hkv, dh), ("kvheads", None), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((dh,), (None,), "ones")
        p["k_norm"] = ParamDef((dh,), (None,), "ones")
    return p


def _ffn_defs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    p = {"w_up": ParamDef((d, f), ("embed", "mlp")),
         "w_down": ParamDef((f, d), ("mlp", "embed"))}
    if cfg.ffn_act == "swiglu":
        p["w_gate"] = ParamDef((d, f), ("embed", "mlp"))
    return p


def _moe_defs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": ParamDef((d, e), ("embed", None)),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "emlp")),
        "w_down": ParamDef((e, f, d), ("expert", "emlp", "embed")),
    }
    if cfg.ffn_act == "swiglu":
        p["w_gate"] = ParamDef((e, d, f), ("expert", "embed", "emlp"))
    return p


def _mamba_defs(cfg: ModelConfig) -> dict:
    d, di, n, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_d_state,
                      cfg.dt_rank, cfg.ssm_conv_dim)
    return {
        "in_x": ParamDef((d, di), ("embed", "ssm_inner")),
        "in_z": ParamDef((d, di), ("embed", "ssm_inner")),
        "conv_w": ParamDef((k, di), (None, "ssm_inner")),
        "conv_b": ParamDef((di,), ("ssm_inner",), "zeros"),
        "x_dbc": ParamDef((di, r + 2 * n), ("ssm_inner", None)),
        "dt_w": ParamDef((r, di), (None, "ssm_inner")),
        "dt_b": ParamDef((di,), ("ssm_inner",), "dtbias"),
        "a_log": ParamDef((di, n), ("ssm_inner", None), "alog"),
        "d_skip": ParamDef((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _mlstm_defs(cfg: ModelConfig) -> dict:
    d, di, h = cfg.d_model, cfg.xlstm_d_inner, cfg.n_heads
    return {
        "up_x": ParamDef((d, di), ("embed", "ssm_inner")),
        "up_z": ParamDef((d, di), ("embed", "ssm_inner")),
        "wq": ParamDef((di, di), ("ssm_inner", None)),
        "wk": ParamDef((di, di), ("ssm_inner", None)),
        "wv": ParamDef((di, di), ("ssm_inner", None)),
        "w_if": ParamDef((di, 2, h), ("ssm_inner", None, None)),
        "b_if": ParamDef((2, h), (None, None), "zeros"),
        "out": ParamDef((di, di), ("ssm_inner", None)),
        "down": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _slstm_defs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "w": ParamDef((d, 4, d), ("embed", None, "slstm_h")),
        "b": ParamDef((4, d), (None, "slstm_h"), "zeros"),
        "r": ParamDef((h, dh, 4, dh), (None, None, None, None)),
        "out": ParamDef((d, d), ("slstm_h", "embed")),
    }


_MIXER_DEFS = {
    "attn": _attn_defs, "attn_local": _attn_defs,
    "mamba": _mamba_defs, "mlstm": _mlstm_defs, "slstm": _slstm_defs,
}


def block_defs(cfg: ModelConfig, blk: str) -> dict:
    """ParamDef tree of one layer block (``mixer:ffn`` plan entry)."""
    mixer, ffn = blk.split(":")
    p = {"ln1": ParamDef((cfg.d_model,), (None,), "ones"),
         "mixer": _MIXER_DEFS[mixer](cfg)}
    if ffn != "none":
        p["ln2"] = ParamDef((cfg.d_model,), (None,), "ones")
        p["ffn"] = _ffn_defs(cfg) if ffn == "dense" else _moe_defs(cfg)
    return p


def model_defs(cfg: ModelConfig) -> dict:
    """Whole-model ParamDef tree (embeddings, scan stack, tail, head)."""
    plan = cfg.layer_plan()
    n_rep, unit, n_tail = cfg.scan_split()
    defs = {}
    if cfg.embed_inputs:
        defs["tok_embed"] = ParamDef((cfg.vocab_size, cfg.d_model),
                                     ("vocab", "embed"), "embed")
    if n_rep > 0:
        defs["scan"] = {str(j): block_defs(cfg, plan[j]) for j in range(unit)}
    defs["tail"] = {str(i): block_defs(cfg, plan[n_rep * unit + i])
                    for i in range(n_tail)}
    defs["final_norm"] = ParamDef((cfg.d_model,), (None,), "ones")
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"))
    return defs


# ---------------------------------------------------------------------------
# Materialization from defs
# ---------------------------------------------------------------------------

def _init_one(key, d: ParamDef, dtype, stack: int | None):
    shape = ((stack,) + d.shape) if stack else d.shape
    if d.init == "zeros":
        return jnp.zeros(shape, dtype)
    if d.init == "ones":
        return jnp.ones(shape, dtype)
    if d.init == "alog":
        # S4D-real init: A_n = n+1 per state channel
        n = d.shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, shape).astype(jnp.float32)
    if d.init == "dtbias":
        return jnp.full(shape, math.log(math.expm1(0.01)), jnp.float32)
    std = 0.02 if d.init == "embed" else (
        1.0 / math.sqrt(max(1, d.shape[0] if len(d.shape) < 2 else
                            math.prod(d.shape[:-1])
                            if d.axes[-1] in ("embed",) else d.shape[0])))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def _tree_init(key, defs, dtype, stack: int | None):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, d, dtype, stack) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def init_params(cfg: ModelConfig, key) -> dict:
    """Materialize real parameters (smoke/tests/examples)."""
    dtype = jnp.dtype(cfg.param_dtype)
    defs = model_defs(cfg)
    n_rep, _, _ = cfg.scan_split()
    out = {}
    k_top, k_scan, k_tail = jax.random.split(key, 3)
    for name, sub in defs.items():
        if name == "scan":
            out[name] = _tree_init(k_scan, sub, dtype, n_rep)
        elif name == "tail":
            out[name] = _tree_init(k_tail, sub, dtype, None)
        else:
            out[name] = _tree_init(k_top, sub, dtype, None)
    return out


def param_shapes(cfg: ModelConfig) -> dict:
    """ShapeDtypeStructs for the full parameter tree (no allocation)."""
    dtype = jnp.dtype(cfg.param_dtype)
    defs = model_defs(cfg)
    n_rep, _, _ = cfg.scan_split()

    def mk(stack):
        def f(d):
            shape = ((stack,) + d.shape) if stack else d.shape
            dt = jnp.float32 if d.init in ("alog", "dtbias") else dtype
            return jax.ShapeDtypeStruct(shape, dt)
        return f

    out = {}
    for name, sub in defs.items():
        stack = n_rep if name == "scan" else None
        out[name] = _map_defs(mk(stack), sub)
    return out


def param_pspecs(cfg: ModelConfig, rules: dict, mesh_sizes: dict) -> dict:
    """PartitionSpec tree matching :func:`model_defs` under ``rules``."""
    defs = model_defs(cfg)
    n_rep, _, _ = cfg.scan_split()

    def mk(stacked):
        def f(d: ParamDef):
            shape = ((n_rep,) + d.shape) if stacked else d.shape
            axes = (("stack",) + d.axes) if stacked else d.axes
            return spec_for(shape, axes, rules, mesh_sizes)
        return f

    out = {}
    for name, sub in defs.items():
        out[name] = _map_defs(mk(name == "scan"), sub)
    return out


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count. active_only: MoE experts counted as top-k."""
    total = 0
    for blk in cfg.layer_plan():
        defs = block_defs(cfg, blk)
        flat = jax.tree_util.tree_flatten_with_path(
            defs, is_leaf=_is_def)[0]   # jax.tree.flatten_with_path needs
        for path, d in flat:            # newer jax than the floor we support
            n = math.prod(d.shape)
            if active_only and d.shape and d.shape[0] == cfg.n_experts \
                    and len(d.shape) == 3 and cfg.n_experts > 0:
                n = n * cfg.experts_per_token // cfg.n_experts
            total += n
    total += cfg.d_model  # final norm
    if cfg.embed_inputs:
        total += cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    return total


# ---------------------------------------------------------------------------
# Cache (decode state) tables
# ---------------------------------------------------------------------------

def _cache_defs(cfg: ModelConfig, blk: str, batch: int, seq: int) -> dict:
    mixer = blk.split(":")[0]
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_eff
    h = cfg.n_heads
    if mixer in ("attn", "attn_local"):
        # full-length cache also for local layers (window masked at use)
        return {
            "k": ParamDef((batch, seq, hkv, dh),
                          ("batch", "kv_seq", "kvheads", None), "zeros"),
            "v": ParamDef((batch, seq, hkv, dh),
                          ("batch", "kv_seq", "kvheads", None), "zeros"),
        }
    if mixer == "mamba":
        di, n, k = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_conv_dim
        return {
            "h": ParamDef((batch, di, n),
                          ("batch", "ssm_inner", None), "zeros"),
            "conv": ParamDef((batch, k - 1, di),
                             ("batch", None, "ssm_inner"), "zeros"),
        }
    if mixer == "mlstm":
        di = cfg.xlstm_d_inner
        dh_i = di // h
        return {
            "c": ParamDef((batch, h, dh_i, dh_i),
                          ("batch", "qheads", None, None), "zeros"),
            "n": ParamDef((batch, h, dh_i),
                          ("batch", "qheads", None), "zeros"),
            "m": ParamDef((batch, h), ("batch", "qheads"), "zeros"),
        }
    if mixer == "slstm":
        d = cfg.d_model
        return {
            "c": ParamDef((batch, d), ("batch", "slstm_h"), "zeros"),
            "n": ParamDef((batch, d), ("batch", "slstm_h"), "zeros"),
            "h": ParamDef((batch, d), ("batch", "slstm_h"), "zeros"),
            "m": ParamDef((batch, h), ("batch", None), "zeros"),
        }
    raise ValueError(mixer)


def cache_defs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Decode-cache ParamDef tree (KV / SSM / xLSTM state per block)."""
    plan = cfg.layer_plan()
    n_rep, unit, n_tail = cfg.scan_split()
    out = {}
    if n_rep > 0:
        out["scan"] = {str(j): _cache_defs(cfg, plan[j], batch, seq)
                       for j in range(unit)}
    out["tail"] = {str(i): _cache_defs(cfg, plan[n_rep * unit + i], batch, seq)
                   for i in range(n_tail)}
    return out


def _cache_leaf_dtype(cfg, d: ParamDef):
    # recurrent states fp32; KV cache in param dtype
    if d.axes[1] == "kv_seq":
        return jnp.dtype(cfg.param_dtype)
    return jnp.float32


def cache_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct tree of the decode cache at serve shapes."""
    defs = cache_defs(cfg, batch, seq)
    n_rep, _, _ = cfg.scan_split()

    def mk(stacked):
        def f(d):
            shape = ((n_rep,) + d.shape) if stacked else d.shape
            return jax.ShapeDtypeStruct(shape, _cache_leaf_dtype(cfg, d))
        return f

    return {k: _map_defs(mk(k == "scan"), v) for k, v in defs.items()}


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Zero-filled decode cache matching :func:`cache_shapes`."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq))


def cache_pspecs(cfg: ModelConfig, rules: dict, mesh_sizes: dict,
                 batch: int, seq: int) -> dict:
    """PartitionSpec tree matching :func:`cache_defs` under ``rules``."""
    defs = cache_defs(cfg, batch, seq)
    n_rep, _, _ = cfg.scan_split()

    def mk(stacked):
        def f(d):
            shape = ((n_rep,) + d.shape) if stacked else d.shape
            axes = (("stack",) + d.axes) if stacked else d.axes
            return spec_for(shape, axes, rules, mesh_sizes)
        return f

    return {k: _map_defs(mk(k == "scan"), v) for k, v in defs.items()}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _project(x, w, b=None):
    """x: (B,S,d) @ w: (d,H,Dh) -> (B,S,H,Dh)."""
    y = jnp.einsum("bsd,dhe->bshe", x, w)
    if b is not None:
        y = y + b
    return y


def _attn_mixer(cfg: ModelConfig, p: dict, x, *, local: bool, mode: str,
                positions, cache, pos, cache_len: int | None = None,
                attn_impl: str | None = None, kv_len: int | None = None,
                store_flavor: str | None = None, block_tables=None):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff
    q = _project(x, p["wq"], p.get("bq"))
    k = _project(x, p["wk"], p.get("bk"))
    v = _project(x, p["wv"], p.get("bv"))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_kind == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        q = L.apply_mrope(q, positions, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.rope_theta)
    window = cfg.sliding_window if local else None

    new_cache = None
    flav = store_flavor or "standard"
    if mode == "decode" and block_tables is not None:
        # paged cache: leaves are physical page pools (P, page, Hkv, Dh)
        # shared across slots; scatter each slot's new row into the
        # physical page its block table names for the current logical
        # page. The engine guarantees every page in a chunk's write
        # range is allocated and exclusively held (CoW already done),
        # so the in-place scatter can never touch a shared page.
        ps = cache["k"].shape[1]
        nb = block_tables.shape[1]
        p1 = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
        lp = jnp.minimum(p1 // ps, nb - 1)    # overshoot-retiring clamp
        phys = block_tables[jnp.arange(b), lp]
        row = p1 % ps
        kc = cache["k"].at[phys, row].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[phys, row].set(v[:, 0].astype(cache["v"].dtype))
        # page pools stay pool-resident with heads on TP (no-op unmeshed)
        kc = sc(kc, None, None, "kvheads", None)
        vc = sc(vc, None, None, "kvheads", None)
        y = attn_lib.decode_attention(q, kc, vc, pos, window=window,
                                      impl=attn_impl or "ref",
                                      kv_len=kv_len,
                                      block_tables=block_tables)
        new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        # the in-place KV row writes route through the store-flavor door
        # (repro.kernels.stores): standard = the historical dus paths,
        # nt = the cache-aliased full-tile Pallas writer
        kc = stores_lib.kv_row_update(cache["k"], k, pos, flavor=flav)
        vc = stores_lib.kv_row_update(cache["v"], v, pos, flavor=flav)
        # keep the updated cache on the slot-cache layout: the in-place
        # row write must not trigger a resharding gather (no-op unmeshed)
        kc = sc(kc, "batch", "kv_seq", "kvheads", None)
        vc = sc(vc, "batch", "kv_seq", "kvheads", None)
        y = attn_lib.decode_attention(q, kc, vc, pos, window=window,
                                      impl=attn_impl or "ref",
                                      kv_len=kv_len)
        new_cache = {"k": kc, "v": vc}
    else:
        y = attn_lib.chunked_causal_attention(
            q, k, v, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk, window=window)
        if mode == "prefill":
            kd = k.astype(jnp.dtype(cfg.param_dtype))
            vd = v.astype(jnp.dtype(cfg.param_dtype))
            if cache_len is not None and cache_len > s:
                # build the KV buffer at the full decode horizon in the
                # prefill graph itself — decode then updates it in place
                # (donation), with no post-hoc jnp.pad regrow/copy
                kd = stores_lib.pad_to_horizon(kd, cache_len, flavor=flav)
                vd = stores_lib.pad_to_horizon(vd, cache_len, flavor=flav)
            kd = sc(kd, "batch", "kv_seq", "kvheads", None)
            vd = sc(vd, "batch", "kv_seq", "kvheads", None)
            new_cache = {"k": kd, "v": vd}
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"])
    return out, new_cache


def _mamba_mixer(cfg, p, x, *, mode, cache):
    want_state = mode in ("prefill", "decode")
    y, st = ssm_lib.mamba_mixer(
        p, x, d_state=cfg.ssm_d_state, conv_dim=cfg.ssm_conv_dim,
        chunk=cfg.ssm_chunk, state=cache if mode == "decode" else None,
        want_state=want_state, fuse=cfg.ssm_fuse)
    return y, st


def _mlstm_mixer(cfg, p, x, *, mode, cache):
    xm = x @ p["up_x"]
    z = x @ p["up_z"]
    want_state = mode in ("prefill", "decode")
    y, st = xlstm_lib.mlstm_mixer(
        p, xm, n_heads=cfg.n_heads, chunk=max(16, cfg.ssm_chunk // 2),
        state=cache if mode == "decode" else None, want_state=want_state)
    y = y * jax.nn.silu(z)
    return y @ p["down"], st


def _slstm_mixer(cfg, p, x, *, mode, cache):
    want_state = mode in ("prefill", "decode")
    y, st = xlstm_lib.slstm_mixer(
        p, x, n_heads=cfg.n_heads,
        state=cache if mode == "decode" else None, want_state=want_state)
    return y, st


def apply_block(cfg: ModelConfig, blk: str, p: dict, x, *, mode: str,
                positions, cache, pos, cache_len: int | None = None,
                attn_impl: str | None = None, kv_len: int | None = None,
                store_flavor: str | None = None, block_tables=None):
    """Returns (x_out, aux_loss, new_cache)."""
    mixer, ffn = blk.split(":")
    hx = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer in ("attn", "attn_local"):
        y, new_cache = _attn_mixer(cfg, p["mixer"], hx,
                                   local=(mixer == "attn_local"),
                                   mode=mode, positions=positions,
                                   cache=cache, pos=pos, cache_len=cache_len,
                                   attn_impl=attn_impl, kv_len=kv_len,
                                   store_flavor=store_flavor,
                                   block_tables=block_tables)
    elif mixer == "mamba":
        y, new_cache = _mamba_mixer(cfg, p["mixer"], hx, mode=mode,
                                    cache=cache)
    elif mixer == "mlstm":
        y, new_cache = _mlstm_mixer(cfg, p["mixer"], hx, mode=mode,
                                    cache=cache)
    elif mixer == "slstm":
        y, new_cache = _slstm_mixer(cfg, p["mixer"], hx, mode=mode,
                                    cache=cache)
    else:
        raise ValueError(mixer)
    x = x + y
    x = sc(x, "act_batch", None, "act_embed")
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        hx = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "dense":
            y = L.dense_ffn(p["ffn"], hx, cfg.ffn_act)
        else:
            y, aux = moe_lib.moe_ffn(
                p["ffn"], hx, n_experts=cfg.n_experts,
                top_k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group_size, act=cfg.ffn_act)
        x = x + y
        x = sc(x, "act_batch", None, "act_embed")
    return x, aux, new_cache


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full": save nothing


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            mode: str = "train",
            cache: dict | None = None, pos=None, cache_len: int | None = None,
            attn_impl: str | None = None, kv_len: int | None = None,
            store_flavor: str | None = None, block_tables=None):
    """Run the model.

    batch: {"tokens": (B,S) int32} or {"embeds": (B,S,d)}; optional
    "positions" ((B,S) int32, or (3,B,S) for mrope).
    mode: "train" -> logits
          "prefill" -> (logits, cache); `cache_len` (optional) preallocates
                       the attention KV buffers at the full decode horizon
                       inside the prefill graph (repro.serve slot caches)
          "decode" -> (logits, cache); S==1, `pos` required — scalar int32,
                      or (B,) int32 for per-slot positions (continuous
                      batching: each row attends/updates at its own pos).
                      `attn_impl` routes decode attention through the
                      split-KV kernel suite ("ref"/"pallas"/"auto", see
                      models.attention.decode_attention) and `kv_len`
                      statically bounds how much of the cache horizon a
                      step may read (occupancy bound, repro.serve).
    `store_flavor` ("standard"|"nt"|"auto", None = standard) picks the
    KV-writer store path (repro.kernels.stores): how decode rows are
    written into the cache and how prefill pads to the horizon.
    `block_tables` ((B, NB) int32, decode only) switches attention KV
    leaves to the paged layout: caches are physical page pools and each
    row's logical pages map through its table row (repro.serve.pages).
    Returns logits (B, S, V) plus aux-loss scalar as (logits, aux[, cache]).
    """
    if cfg.embed_inputs:
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = jnp.take(params["tok_embed"], tokens, axis=0)
    else:
        x = batch["embeds"]
        b, s, _ = x.shape
    x = x.astype(jnp.dtype(cfg.param_dtype))

    if "positions" in batch:
        positions = batch["positions"]
    elif mode == "decode":
        p1 = jnp.asarray(pos)
        base = jnp.broadcast_to(p1[:, None] if p1.ndim else p1,
                                (b, 1)).astype(jnp.int32)
        positions = jnp.broadcast_to(base, (3, b, 1)) \
            if cfg.rope_kind == "mrope" else base
    else:
        base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        positions = jnp.broadcast_to(base, (3, b, s)) \
            if cfg.rope_kind == "mrope" else base

    if cfg.rope_kind == "sinusoidal":
        pe = L.sinusoidal_embedding(
            positions if positions.ndim == 2 else positions[0], cfg.d_model)
        x = x + pe.astype(x.dtype)

    x = sc(x, "act_batch", None, "act_embed")
    plan = cfg.layer_plan()
    n_rep, unit, n_tail = cfg.scan_split()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"tail": {}}

    if n_rep > 0 and mode == "decode" and cfg.decode_unroll:
        unit_blocks = [plan[j] for j in range(unit)]
        new_slices_all = []
        for r in range(n_rep):
            p_r = jax.tree.map(lambda x: x[r], params["scan"])
            c_r = jax.tree.map(lambda x: x[r], cache["scan"])
            new_slices = {}
            for j, blk in enumerate(unit_blocks):
                x, a, nc = apply_block(cfg, blk, p_r[str(j)], x,
                                       mode=mode, positions=positions,
                                       cache=c_r[str(j)], pos=pos,
                                       cache_len=cache_len,
                                       attn_impl=attn_impl, kv_len=kv_len,
                                       store_flavor=store_flavor,
                                       block_tables=block_tables)
                aux_total = aux_total + a
                new_slices[str(j)] = nc
            new_slices_all.append(new_slices)
        new_cache["scan"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_slices_all)
    elif n_rep > 0:
        unit_blocks = [plan[j] for j in range(unit)]

        def unit_body(x_aux, xs):
            x, aux = x_aux
            p_slice, c_slice = xs
            new_slices = {}
            for j, blk in enumerate(unit_blocks):
                cj = c_slice[str(j)] if c_slice is not None else None
                x, a, nc = apply_block(cfg, blk, p_slice[str(j)], x,
                                       mode=mode, positions=positions,
                                       cache=cj, pos=pos,
                                       cache_len=cache_len,
                                       attn_impl=attn_impl, kv_len=kv_len,
                                       store_flavor=store_flavor,
                                       block_tables=block_tables)
                aux = aux + a
                if nc is not None:
                    new_slices[str(j)] = nc
            return (x, aux), (new_slices if new_slices else None)

        body = _remat_wrap(cfg, unit_body)
        if mode == "decode":
            xs = (params["scan"], cache["scan"])
        elif mode == "prefill":
            xs = (params["scan"], None)
        else:
            xs = (params["scan"], None)
        (x, aux_total), scan_caches = jax.lax.scan(body, (x, aux_total), xs)
        if mode in ("prefill", "decode") and scan_caches is not None:
            new_cache["scan"] = scan_caches

    for i in range(n_tail):
        blk = plan[n_rep * unit + i]
        ci = cache["tail"][str(i)] \
            if (cache is not None and mode == "decode") else None
        x, a, nc = apply_block(cfg, blk, params["tail"][str(i)], x,
                               mode=mode, positions=positions,
                               cache=ci, pos=pos, cache_len=cache_len,
                               attn_impl=attn_impl, kv_len=kv_len,
                               store_flavor=store_flavor,
                               block_tables=block_tables)
        aux_total = aux_total + a
        if nc is not None and mode in ("prefill", "decode"):
            new_cache["tail"][str(i)] = nc

    if mode == "prefill":
        # Serving: only the last position's logits are needed to start
        # decoding — skip the (B, S, V) vocab matmul entirely.
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    logits = sc(logits, "act_batch", None, "vocab")

    if mode == "train":
        return logits, aux_total
    return logits, aux_total, new_cache
