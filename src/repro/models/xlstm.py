"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory with recurrent gate mixing), both with stabilized exponential gating.

TPU-native adaptation (DESIGN.md §2):

* mLSTM trains/prefills **chunkwise-parallel**: within a chunk the linear
  recurrence is evaluated as a decay-masked attention matmul (MXU-friendly,
  no per-step (Dh,Dh) state materialization — the sequential form would
  store T x (Dh,Dh) residuals for backward, ~38 GB/layer at 4k); across
  chunks a short scan carries (C, n, m). Decode uses the exact sequential
  step. ``mlstm_sequential`` is kept as the correctness oracle.

* sLSTM is inherently sequential (nonlinear recurrent mixing) — the
  framework's designated *loop-carried-dependency* (LCD) workload, the TPU
  analogue of the paper's latency-bound Gauss-Seidel case study. The time
  scan is chunk-checkpointed (outer scan over chunks, rematted inner scan)
  so backward stores only chunk-boundary carries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def _heads(x, h):
    b, t, d = x.shape
    return x.reshape(b, t, h, d // h)


def _gates(p, x):
    g = (jnp.einsum("btd,dgh->btgh", x, p["w_if"]) +
         p["b_if"]).astype(jnp.float32)                  # (B,T,2,H)
    return g[..., 0, :], jax.nn.log_sigmoid(g[..., 1, :])  # log_i, log_f


def _qkv(p, x, h):
    dh = x.shape[-1] // h
    q = _heads(x @ p["wq"], h)
    k = _heads(x @ p["wk"], h) * (dh ** -0.5)
    v = _heads(x @ p["wv"], h)
    return q, k, v


def _zero_state(b, h, dh):
    return (jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), NEG, jnp.float32))


def mlstm_chunkwise(p: dict, x: jax.Array, *, n_heads: int, chunk: int = 64,
                    state0=None, want_state: bool = False):
    """Chunkwise-parallel stabilized mLSTM. x: (B, T, di)."""
    b, t, di = x.shape
    h = n_heads
    dh = di // h
    chunk = min(chunk, t)
    q, k, v = _qkv(p, x, h)
    log_i, log_f = _gates(p, x)                           # (B,T,H)
    tp = ((t + chunk - 1) // chunk) * chunk
    if tp != t:
        # Pad with state-invariant steps: i -> 0 (log NEG), f -> 1 (log 0).
        padt = [(0, 0), (0, tp - t)]
        q, k, v = (jnp.pad(a, padt + [(0, 0), (0, 0)]) for a in (q, k, v))
        log_i = jnp.pad(log_i, padt + [(0, 0)], constant_values=NEG)
        log_f = jnp.pad(log_f, padt + [(0, 0)], constant_values=0.0)
    t_orig, t = t, tp
    nc = t // chunk

    def ck(a):  # (B,T,...) -> (nc, B, L, ...)
        return jnp.moveaxis(a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    qs, ks, vs = ck(q), ck(k), ck(v)
    lis, lfs = ck(log_i), ck(log_f)

    if state0 is None:
        state0 = _zero_state(b, h, dh)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        c0, n0, m0 = carry
        q_c, k_c, v_c, li_c, lf_c = xs                    # (B,L,H,*) / (B,L,H)
        f_cum = jnp.cumsum(lf_c, axis=1)                  # F_t, (B,L,H)
        # a[t,s] = F_t - F_s + logi_s  (valid s<=t)
        a = (f_cum[:, :, None, :] - f_cum[:, None, :, :] +
             li_c[:, None, :, :])                         # (B,T_q,T_s,H)
        a = jnp.where(causal[None, :, :, None], a, NEG)
        inter = f_cum + m0[:, None, :]                    # (B,L,H)
        m_t = jnp.maximum(a.max(axis=2), inter)           # (B,L,H)
        d_mat = jnp.exp(a - m_t[:, :, None, :])           # (B,L,L,H)
        w_inter = jnp.exp(inter - m_t)                    # (B,L,H)

        qf = q_c.astype(jnp.float32)
        kf = k_c.astype(jnp.float32)
        vf = v_c.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * d_mat
        num = jnp.einsum("btsh,bshd->bthd", scores, vf) + \
            w_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qf,
                                            jnp.swapaxes(c0, -1, -2))
        den = scores.sum(axis=2) + \
            w_inter * jnp.einsum("bthd,bhd->bth", qf, n0)
        y_c = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # chunk-end state
        f_last = f_cum[:, -1]                             # (B,H)
        g = f_last[:, None, :] - f_cum + li_c        # (B,L,H) decay to end
        m_new = jnp.maximum(f_last + m0, g.max(axis=1))
        w_old = jnp.exp(f_last + m0 - m_new)
        w_in = jnp.exp(g - m_new[:, None, :])             # (B,L,H)
        c_new = w_old[..., None, None] * c0 + jnp.einsum(
            "bshd,bshe->bhde", w_in[..., None] * vf, kf)
        n_new = w_old[..., None] * n0 + jnp.einsum(
            "bsh,bshd->bhd", w_in, kf)
        return (c_new, n_new, m_new), y_c

    (c, n, m), y_s = jax.lax.scan(body, state0, (qs, ks, vs, lis, lfs))
    y = jnp.moveaxis(y_s, 0, 1).reshape(b, t, di).astype(x.dtype)[:, :t_orig]
    return y, ((c, n, m) if want_state else None)


def mlstm_sequential(p: dict, x: jax.Array, *, n_heads: int,
                     state0=None, want_state: bool = False):
    """Exact per-step recurrence (decode path + chunkwise oracle)."""
    b, t, di = x.shape
    h = n_heads
    dh = di // h
    q, k, v = _qkv(p, x, h)
    log_i, log_f = _gates(p, x)
    c0, n0, m0 = state0 if state0 is not None else _zero_state(b, h, dh)

    def step(carry, xs):
        c, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = xs
        m_new = jnp.maximum(lf_t + m, li_t)
        i_p = jnp.exp(li_t - m_new)
        f_p = jnp.exp(lf_t + m - m_new)
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        c = f_p[..., None, None] * c + i_p[..., None, None] * (
            vf[..., :, None] * kf[..., None, :])
        n = f_p[..., None] * n + i_p[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", c, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                          jnp.exp(-m_new))[..., None]
        return (c, n, m_new), num / den

    tm = lambda a: jnp.moveaxis(a, 1, 0)
    (c, n, m), y_s = jax.lax.scan(
        step, (c0, n0, m0), (tm(q), tm(k), tm(v), tm(log_i), tm(log_f)))
    y = jnp.moveaxis(y_s, 0, 1).reshape(b, t, di).astype(x.dtype)
    return y, ((c, n, m) if want_state else None)


def mlstm_mixer(p: dict, x: jax.Array, *, n_heads: int, chunk: int = 64,
                state: dict | None = None, want_state: bool = False):
    """Dispatch: chunkwise for train/prefill, sequential for decode."""
    st0 = (state["c"], state["n"], state["m"]) if state is not None else None
    if x.shape[1] > 1 or state is None:
        y, st = mlstm_chunkwise(p, x, n_heads=n_heads, chunk=chunk,
                                state0=st0, want_state=want_state)
    else:
        y, st = mlstm_sequential(p, x, n_heads=n_heads, state0=st0,
                                 want_state=want_state)
    new_state = ({"c": st[0], "n": st[1], "m": st[2]}
                 if (want_state and st is not None) else None)
    return y @ p["out"], new_state


def slstm_mixer(p: dict, x: jax.Array, *, n_heads: int, chunk: int = 128,
                state: dict | None = None, want_state: bool = False):
    """sLSTM: scalar memory, head-block-diagonal recurrent weights.

    p: w (d, 4, d), b (4, d), r (H, Dh, 4, Dh), out (d, d).
    Gate order: [i, f, z, o]. Chunk-checkpointed time scan; non-multiple
    lengths are padded with masked (state-invariant) steps.
    """
    b, t, d = x.shape
    h = n_heads
    chunk = min(chunk, t)
    tp = ((t + chunk - 1) // chunk) * chunk
    valid = jnp.arange(tp) < t
    if tp != t:
        x = jnp.pad(x, ((0, 0), (0, tp - t), (0, 0)))
    nc = tp // chunk

    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
        h0 = jnp.zeros((b, d), jnp.float32)
        m0 = jnp.full((b, h), NEG, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    xs_chunks = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    valid_chunks = valid.reshape(nc, chunk)

    @jax.checkpoint
    def chunk_body(carry, xs):
        return _slstm_chunk(p, xs[0], carry, n_heads=n_heads, valid=xs[1])

    (c, n, hh, m), y_s = jax.lax.scan(chunk_body, (c0, n0, h0, m0),
                                      (xs_chunks, valid_chunks))
    # y_s: (nc, L, B, d) — inner scan stacks time, outer stacks chunks.
    y = jnp.moveaxis(y_s, 2, 0).reshape(b, tp, d).astype(x.dtype)[:, :t]
    new_state = ({"c": c, "n": n, "h": hh, "m": m} if want_state else None)
    return y @ p["out"], new_state


def _slstm_chunk(p, x_c, carry, *, n_heads, valid=None):
    """One chunk of the sLSTM recurrence. x_c: (B, L, d)."""
    b, l, d = x_c.shape
    h = n_heads
    dh = d // h
    wx = (jnp.einsum("btd,dge->btge", x_c, p["w"]) +
          p["b"]).astype(jnp.float32)                     # (B,L,4,d)
    r = p["r"].astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((l,), bool)

    def step(carry, xs):
        wx_t, ok = xs
        c, n, h_prev, m = carry
        hp = h_prev.reshape(b, h, dh)
        rec = jnp.einsum("bhd,hdge->bghe", hp, r)         # (B,4,H,Dh)
        g = wx_t + rec.reshape(b, 4, d)
        li = g[:, 0].reshape(b, h, dh)
        lf = jax.nn.log_sigmoid(g[:, 1]).reshape(b, h, dh)
        z = jnp.tanh(g[:, 2])
        o = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum((lf + m[..., None]).max(-1), li.max(-1))
        i_p = jnp.exp(li - m_new[..., None]).reshape(b, d)
        f_p = jnp.exp(lf + m[..., None] - m_new[..., None]).reshape(b, d)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
        # padded steps leave the state untouched
        c_new = jnp.where(ok, c_new, c)
        n_new = jnp.where(ok, n_new, n)
        h_new = jnp.where(ok, h_new, h_prev)
        m_new = jnp.where(ok, m_new, m)
        return (c_new, n_new, h_new, m_new), h_new

    return jax.lax.scan(step, carry, (jnp.moveaxis(wx, 1, 0), valid))
