"""Core neural layers: norms, activations, rotary embeddings (RoPE / M-RoPE),
dense & gated MLPs. Pure functions over explicit parameter pytrees."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation, cast back to input dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """SwiGLU gate: silu(gate) * up."""
    return jax.nn.silu(gate) * up


def dense_ffn(p: dict, x: jax.Array, act: str = "swiglu") -> jax.Array:
    """SwiGLU (llama-family) or plain GELU (musicgen-family) MLP."""
    if act == "swiglu":
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = swiglu(g, u)
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    elif act == "relu2":   # squared ReLU (Nemotron/Minitron family)
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:
        raise ValueError(f"unknown ffn act {act}")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,), fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.

    x: (..., S, H, Dh); positions: broadcastable to (..., S) int32.
    Rotates pairs (x[2i], x[2i+1]) — "interleaved-free" half-split layout
    (llama convention: first half / second half).
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # (half,)
    # angles: (..., S, half); cos/sin: (..., S, 1, half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, theta: float,
                sections: tuple = (2, 3, 3)) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    The rotary dims are split into (temporal, height, width) sections; each
    section uses its own position stream.

    x: (B, S, H, Dh); positions_3d: (3, B, S) int32 — [t, h, w] position ids.
    sections: relative split of the half-dim in 8ths (t:h:w = 2:3:3 default,
    scaled to Dh//2).
    """
    half = x.shape[-1] // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sum(sizes[:-1])
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    # Build per-frequency position ids by section.
    angle_parts = []
    off = 0
    for i, sz in enumerate(sizes):
        f = freqs[off:off + sz]
        pos = positions_3d[i]                                    # (B, S)
        angle_parts.append(pos[..., None].astype(jnp.float32) * f)
        off += sz
    angles = jnp.concatenate(angle_parts, axis=-1)               # (B, S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jax.Array, dim: int,
                         max_period: float = 10000.0) -> jax.Array:
    """Absolute sinusoidal position embedding (musicgen-family backbone)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.cos(angles), jnp.sin(angles)], axis=-1)
