"""Mixture-of-Experts layer: GShard-style grouped dense dispatch.

Formulation chosen for SPMD friendliness on TPU meshes (see DESIGN.md §3.2):
activations after the attention all-reduce are replicated over the "model"
axis, experts are sharded over "model" (expert parallelism), token groups are
sharded over "data". Dispatch/combine are einsums against a one-hot
(group, tokens, experts, capacity) tensor — each model shard selects its own
experts' tokens locally, and the combine contraction over the expert axis
produces the single per-layer all-reduce (same collective cost as a dense TP
MLP). Over-capacity tokens are dropped (Switch-style), tracked by an aux
load-balance loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _group(x: jax.Array, group_size: int) -> jax.Array:
    """(B, S, d) -> (G, Sg, d) with G*Sg == B*S."""
    b, s, d = x.shape
    t = b * s
    g = max(1, t // group_size)
    return x.reshape(g, t // g, d)


def moe_ffn(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, group_size: int = 1024,
            act: str = "swiglu", renormalize: bool = True):
    """Top-k routed MoE MLP.

    p: {"router": (d, E), "w_gate": (E, d, f), "w_up": (E, d, f),
        "w_down": (E, f, d)}
    x: (B, S, d). Returns (out (B, S, d), aux_loss scalar fp32).
    """
    b, s, d = x.shape
    xg = _group(x, group_size)                       # (G, Sg, d)
    g, sg, _ = xg.shape
    e = n_experts
    cap = max(top_k, int(round(top_k * sg * capacity_factor / e)))

    # --- Router (fp32) ---
    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)          # (G, Sg, E)
    top_p, top_e = jax.lax.top_k(probs, top_k)       # (G, Sg, K)
    if renormalize:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- Aux load-balance loss (Switch): E*mean(frac_tok * frac_prob) ---
    sel_onehot = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32)
    frac_tokens = sel_onehot.mean(axis=(0, 1))
    frac_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    # --- Capacity assignment: position of each (token, k) slot in its expert
    # queue, computed per group with a cumsum over the flattened (Sg*K) slots.
    slot_e = top_e.reshape(g, sg * top_k)            # (G, SgK)
    slot_oh = jax.nn.one_hot(slot_e, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(slot_oh, axis=1) * slot_oh - 1  # (G, SgK, E)
    pos = pos_in_e.max(axis=-1)                 # (G, SgK) queue position
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)

    # One-hot dispatch/combine: (G, Sg, K, E, C) folded to (G, Sg, E, C)
    oh_e = jax.nn.one_hot(slot_e, e, dtype=xg.dtype)            # (G, SgK, E)
    oh_c = jax.nn.one_hot(pos, cap, dtype=xg.dtype)             # (G, SgK, C)
    oh_c = oh_c * keep[..., None].astype(xg.dtype)
    disp_k = jnp.einsum("gte,gtc->gtec", oh_e, oh_c)    # (G, SgK, E, C)
    disp_k = disp_k.reshape(g, sg, top_k, e, cap)
    dispatch = disp_k.sum(axis=2)                        # (G, Sg, E, C)
    combine = jnp.einsum("gskec,gsk->gsec", disp_k,
                         top_p.astype(xg.dtype))         # (G, Sg, E, C)

    # --- Expert computation (E sharded over "model") ---
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)              # (G, E, C, d)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * \
            jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["w_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])            # (G, E, C, d)

    # --- Combine (contraction over E,C => all-reduce over "model") ---
    out = jnp.einsum("gsec,gecd->gsd", combine, ye)              # (G, Sg, d)
    return out.reshape(b, s, d), aux
