"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips
("pod", "data", "model") — the "pod" axis is a pure extra data-parallel
axis whose gradient all-reduce crosses the inter-pod (DCN) boundary once
per step.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (see repro.launch.dryrun)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many real devices exist (tests/smoke)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
