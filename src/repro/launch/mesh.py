"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips
("pod", "data", "model") — the "pod" axis is a pure extra data-parallel
axis whose gradient all-reduce crosses the inter-pod (DCN) boundary once
per step.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devs)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (see repro.launch.dryrun)")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many real devices exist (tests/smoke)."""
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_serve_mesh(spec: str | None):
    """Build a serve mesh from a CLI spec ``"axes=sizes"``, e.g.
    ``"data,model=1,2"`` -> a (1, 2) mesh on axes ("data", "model").

    ``None`` or ``""`` returns ``None`` — the engines' single-device
    path. Sizes must multiply to at most the visible device count (use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to fake N
    host devices for CPU smoke runs).
    """
    if not spec:
        return None
    try:
        axes_s, sizes_s = spec.split("=")
        axes = tuple(a.strip() for a in axes_s.split(","))
        shape = tuple(int(s) for s in sizes_s.split(","))
    except ValueError as e:
        raise ValueError(
            f"bad mesh spec {spec!r}; expected 'axis,axis=size,size' "
            "like 'data,model=1,2'") from e
    if len(axes) != len(shape) or not axes:
        raise ValueError(
            f"mesh spec {spec!r}: {len(axes)} axes vs {len(shape)} sizes")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {spec!r} needs {n} devices, have {len(devs)}; run "
            f"under XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return jax.make_mesh(shape, axes, devices=devs[:n])
