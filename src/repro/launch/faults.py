"""Fault tolerance & straggler mitigation for thousand-node runs.

Pieces (wired together by repro.launch.train):
 * StragglerDetector — EWMA + z-score over per-step wall times; flags a
   step (and by extension the slowest host when per-host times are fed)
   as a straggler. Mitigation hook: raise the checkpoint cadence and/or
   trigger elastic re-mesh when the same host trips K times.
 * HeartbeatRegistry — host liveness bookkeeping with a miss budget
   (stands in for the TPU runtime's health service in this container).
 * elastic_mesh_shape — largest (data, model)-factorable mesh from the
   surviving chip count; model-parallel width is preserved when possible
   (weights reshard along data only — cheap restart from checkpoint).
 * RestartManager — crash-recovery driver: run step fn, checkpoint every
   N steps, on failure restore latest commit and resume (used by the
   fault-injection integration test).
"""

from __future__ import annotations

import dataclasses
import math
import time


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1
    z_thresh: float = 3.0
    warmup: int = 8

    def __post_init__(self):
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flags = 0

    def observe(self, dt: float) -> bool:
        """Feed one step time; returns True if it is a straggler step."""
        self.n += 1
        if self.n <= self.warmup:
            # prime the EWMA
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        z = (dt - self.mean) / max(math.sqrt(self.var), 1e-9)
        is_straggler = z > self.z_thresh
        if is_straggler:
            self.flags += 1
        else:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var + \
                self.alpha * (dt - self.mean) ** 2
        return is_straggler


@dataclasses.dataclass
class HeartbeatRegistry:
    n_hosts: int
    miss_budget: int = 3

    def __post_init__(self):
        self.last_seen = {h: 0.0 for h in range(self.n_hosts)}
        self.misses = {h: 0 for h in range(self.n_hosts)}

    def beat(self, host: int, t: float | None = None):
        self.last_seen[host] = t if t is not None else time.time()
        self.misses[host] = 0

    def sweep(self, timeout: float, now: float | None = None) -> list:
        """Returns hosts considered dead (miss budget exhausted)."""
        now = now if now is not None else time.time()
        dead = []
        for h, t in self.last_seen.items():
            if now - t > timeout:
                self.misses[h] += 1
                if self.misses[h] >= self.miss_budget:
                    dead.append(h)
        return dead


def elastic_mesh_shape(n_chips: int, *, model_pref: int = 16,
                       pod_size: int = 256) -> tuple:
    """Pick (pod, data, model) for a degraded chip count.

    Keeps the model axis at `model_pref` if n_chips allows (weights then
    reshard only along data); shrinks pods first.
    """
    pods = max(1, n_chips // pod_size)
    per_pod = n_chips // pods if pods > 1 else n_chips
    model = model_pref
    while model > 1 and per_pod % model:
        model //= 2
    data = per_pod // model
    if pods > 1:
        return (pods, data, model)
    return (data, model)


class RestartManager:
    """Checkpoint-every-N, restore-on-failure step driver."""

    def __init__(self, checkpointer, ckpt_every: int = 50):
        self.ckpt = checkpointer
        self.every = ckpt_every
        self.restarts = 0

    def run(self, state, step_fn, n_steps: int, *, start_step: int = 0,
            inject_failure_at: int | None = None):
        """Runs step_fn(state, step)->state; simulated failures raise
        RuntimeError once at `inject_failure_at` (integration tests)."""
        step = start_step
        failed_once = False
        while step < n_steps:
            try:
                if inject_failure_at is not None and not failed_once \
                        and step == inject_failure_at:
                    failed_once = True
                    raise RuntimeError("injected node failure")
                state = step_fn(state, step)
                step += 1
                if step % self.every == 0:
                    self.ckpt.save(step, state)
            except RuntimeError:
                self.restarts += 1
                self.ckpt.wait()
                got = self.ckpt.restore_latest(state)
                if got[0] is None:
                    step = start_step     # no checkpoint yet: restart fresh
                else:
                    step, state = got
        self.ckpt.wait()
        return state, step
