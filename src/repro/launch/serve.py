"""Serving driver on the continuous-batching engine (repro.serve).

Prompts are prefilled into preallocated KV slots (cache built once at
the full horizon — no ``jnp.pad`` regrow, which used to copy the whole
cache: a system-scale write allocate, DESIGN.md §2) and decoded in
multi-token in-graph chunks: ``ceil(gen/chunk)`` decode dispatches
instead of one per token.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serve import Request, ServeEngine

#: REPRO_DTYPE_POLICY values -> jax default matmul precision. Set by
#: scripts/launch_env.sh (the config-driven runtime policy block);
#: consumed here so the driver and the env script agree on one table.
_DTYPE_POLICIES = {"bf16": "bfloat16", "tf32": "tensorfloat32",
                   "f32": "highest"}


def apply_runtime_policy(env: dict | None = None) -> dict:
    """Apply the launch-env runtime policy this process can still honor.

    ``scripts/launch_env.sh`` exports three kinds of policy knobs:
    process-start ones (tcmalloc LD_PRELOAD, XLA step-marker flags,
    TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD) that only the shell can
    apply, and in-process ones this hook picks up — today the dtype
    policy: ``REPRO_DTYPE_POLICY`` in {bf16, tf32, f32} maps to jax's
    default matmul precision. Returns the subset of policy that was
    applied, for the launch banner (an unknown policy value raises —
    a typo'd policy must not silently serve full-precision traffic).
    """
    env = os.environ if env is None else env
    applied = {}
    policy = env.get("REPRO_DTYPE_POLICY", "")
    if policy:
        prec = _DTYPE_POLICIES.get(policy)
        if prec is None:
            raise ValueError(
                f"REPRO_DTYPE_POLICY={policy!r}: expected one of "
                f"{sorted(_DTYPE_POLICIES)}")
        jax.config.update("jax_default_matmul_precision", prec)
        applied["dtype_policy"] = f"{policy} -> {prec}"
    marker = env.get("REPRO_STEP_MARKER", "")
    if marker and "--xla_step_marker_location" not in \
            env.get("XLA_FLAGS", ""):
        # XLA flags are read at backend init; by the time python code
        # runs it is too late to set them. The env script is the right
        # place — flag the miss loudly instead of silently ignoring it.
        applied["step_marker"] = (
            f"REPRO_STEP_MARKER={marker} set but XLA_FLAGS lacks "
            f"--xla_step_marker_location (source scripts/launch_env.sh)")
    return applied


def generate(cfg, params, prompt_tokens, gen_len: int, *,
             temperature: float = 0.0, seed: int = 0,
             chunk: int | None = None, machine: str | None = None,
             mesh=None, replicas: int = 1,
             engine_out: list | None = None,
             fault_tolerant: bool = False,
             pipeline: bool | int = 0):
    """Greedy/temperature batched generation. prompt_tokens: (B, S).

    One slot per prompt; the whole batch is admitted at once (a single
    batched prefill), then decoded in chunks. ``chunk=None`` plans the
    chunk size analytically from the port model (repro.serve.planner).
    ``mesh`` shards every engine replica over the device mesh
    (params + KV over ``kvheads`` -> TP; ``None`` keeps the bit-exact
    single-device path); ``replicas > 1`` splits the batch across N
    engines behind a round-robin :class:`repro.serve.ReplicaRouter`,
    and ``fault_tolerant=True`` upgrades the router to
    :class:`repro.serve.FaultTolerantRouter` (replica health tracking,
    request rescue, priced degradation — same results on a healthy
    fleet). Pass a list as ``engine_out`` to receive the engine(s)
    (dispatch counters) for inspection. ``pipeline`` enables the
    engines' double-buffered decode dispatch (token streams stay
    byte-identical to the serial rounds).
    """
    import numpy as np

    b, s = prompt_tokens.shape
    if chunk is None and gen_len > 1:
        from repro.serve.planner import plan_chunk_size
        chunk = plan_chunk_size(cfg, b, s + gen_len, machine=machine,
                                max_chunk=min(32, gen_len - 1),
                                mesh=mesh).chunk
    replicas = max(1, int(replicas))
    slots = -(-b // replicas)
    engines = [ServeEngine(cfg, params, max_slots=slots,
                           max_len=s + gen_len,
                           chunk=min(chunk or 1, max(1, gen_len - 1)),
                           temperature=temperature, seed=seed, mesh=mesh,
                           pipeline=pipeline)
               for _ in range(replicas)]
    prompts = np.asarray(prompt_tokens)
    reqs = [Request(rid=str(i), prompt=tuple(int(t) for t in prompts[i]),
                    max_new_tokens=gen_len) for i in range(b)]
    if replicas == 1 and not fault_tolerant:
        results = engines[0].run(reqs)
    else:
        from repro.serve import FaultTolerantRouter, ReplicaRouter
        cls = FaultTolerantRouter if fault_tolerant else ReplicaRouter
        results = cls(engines, policy="round_robin",
                      max_queue=max(8, b)).run(reqs)
    if engine_out is not None:
        engine_out.extend(engines)
    import jax.numpy as jnp
    return jnp.stack([jnp.asarray(results[str(i)]) for i in range(b)])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=0,
                    help="decode tokens per dispatch (0 = plan from the "
                         "port model's tier-resolved step cost)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="device mesh spec 'data,model=1,N' "
                         "(default: single-device, no mesh)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the round-robin router "
                         "(default 1: no router)")
    ap.add_argument("--fault-tolerant", action="store_true",
                    help="route through the health-tracking "
                         "FaultTolerantRouter (replica quarantine/eject, "
                         "request rescue, priced degradation)")
    ap.add_argument("--pipeline", type=int, default=0,
                    help="in-flight decode rounds per engine (0 = serial "
                         "dispatch; 2 = double-buffered). Token streams "
                         "are byte-identical either way")
    ap.add_argument("--plan-db", default="",
                    help="path to a repro.serve.plandb JSON database; "
                         "installed before planning so admission plans "
                         "are O(1) DB hits (missing keys fall back to "
                         "online planning, bit-identically)")
    args = ap.parse_args(argv)

    policy = apply_runtime_policy()
    for k, v in sorted(policy.items()):
        print(f"runtime policy: {k}: {v}")
    if args.plan_db:
        from repro.serve import plandb
        db = plandb.PlanDB.load(args.plan_db)
        plandb.install(db)
        print(f"plan db: {args.plan_db} ({len(db.chunks)} chunk plans, "
              f"{len(db.tiles)} tile plans)")
    from repro.launch.mesh import make_serve_mesh
    mesh = make_serve_mesh(args.mesh)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    # params and prompts must be independent streams: reusing one key for
    # both correlates the prompt ids with the embedding init
    k_params, k_prompts = jax.random.split(key)
    params = M.init_params(cfg, k_params)
    prompts = jax.random.randint(k_prompts, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    eng_out: list = []
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen,
                    temperature=args.temperature, seed=args.seed,
                    chunk=args.chunk or None, mesh=mesh,
                    replicas=args.replicas, engine_out=eng_out,
                    fault_tolerant=args.fault_tolerant,
                    pipeline=args.pipeline)
    dt = time.time() - t0
    eng = eng_out[0]
    shard = f" tp={eng.tp}" if mesh is not None else ""
    repl = f" x{len(eng_out)} replicas" if len(eng_out) > 1 else ""
    gap = eng.stats()["mean_dispatch_gap_s"]
    pipe = f" pipeline={eng.pipeline}" if eng.pipeline else ""
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) — "
          f"{eng.decode_dispatches} decode dispatches "
          f"(chunk={eng.chunk}) + {eng.prefill_dispatches} prefill"
          f"{shard}{repl}{pipe} | mean dispatch gap {1e3 * gap:.2f}ms")
    print("sample:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
