"""Batched serving driver: prefill a batch of prompts, then decode with a
donated KV cache (in-place updates — the NT-store analogue, DESIGN.md §2).

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.train import serve as serve_lib


def generate(cfg, params, prompt_tokens, gen_len: int, *,
             temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature batched generation. prompt_tokens: (B, S)."""
    b, s = prompt_tokens.shape
    total = s + gen_len
    prefill = jax.jit(serve_lib.make_prefill_step(cfg))
    decode = jax.jit(serve_lib.make_decode_step(cfg), donate_argnums=(1,))

    logits, cache = prefill(params, {"tokens": prompt_tokens})

    # grow attention KV buffers to the full generation horizon
    def grow(x):
        if x.ndim == 4 and x.shape[1] == s:        # (B, S, Hkv, Dh)
            return jnp.pad(x, [(0, 0), (0, gen_len), (0, 0), (0, 0)])
        if x.ndim == 5 and x.shape[2] == s:        # stacked scan caches
            return jnp.pad(x, [(0, 0), (0, 0), (0, gen_len), (0, 0), (0, 0)])
        return x
    cache = jax.tree.map(grow, cache)

    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(gen_len - 1):
        logits1, cache = decode(params, cache, {"tokens": tok[:, None]},
                                jnp.int32(s + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits1 / temperature, axis=-1)
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits1, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen,
                    temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())
    return toks


if __name__ == "__main__":
    main()
