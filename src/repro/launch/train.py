"""End-to-end training driver.

Runs any registered architecture (full or smoke config) on whatever mesh
the host supports, with checkpoint/restart, straggler detection and
optional int8 error-feedback gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import make_iterator
from repro.launch.faults import StragglerDetector
from repro.launch.mesh import make_test_mesh
from repro.optim.adamw import OptConfig
from repro.optim.compression import compress_tree, init_residual
from repro.train import step as step_lib
from repro.utils.sharding import TRAIN_RULES, use_mesh_rules


def build(cfg, shape, oc, accum, compress):
    base_step = step_lib.make_train_step(cfg, oc, accum)
    if not compress:
        return base_step

    grad_fn = jax.value_and_grad(step_lib.make_loss_fn(cfg), has_aux=True)
    from repro.optim.adamw import adamw_update

    def step(state, batch):
        (loss, parts), grads = grad_fn(state["params"], batch)
        grads, resid = compress_tree(grads, state["resid"])
        new_p, new_opt, om = adamw_update(oc, state["params"], grads,
                                          state["opt"], state["step"])
        return ({"params": new_p, "opt": new_opt, "resid": resid,
                 "step": state["step"] + 1},
                {"loss": loss, **parts, **om})
    return step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    oc = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                   total_steps=args.steps)

    mesh = make_test_mesh((1, 1))
    step_fn = jax.jit(build(cfg, shape, oc, args.accum, args.compress),
                      donate_argnums=(0,))

    key = jax.random.PRNGKey(args.seed)
    state = step_lib.init_train_state(cfg, key)
    if args.compress:
        state["resid"] = init_residual(state["params"])

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None:
        got_step, got_state = ckpt.restore_latest(state)
        if got_step is not None:
            start, state = got_step, got_state
            print(f"[restore] resumed from step {start}")

    it = make_iterator(cfg, shape, seed=args.seed)
    detector = StragglerDetector()
    losses = []
    with mesh, use_mesh_rules(None, None):
        for i in range(start, args.steps):
            batch = next(it)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            if detector.observe(dt):
                print(f"[straggler] step {i} took {dt*1e3:.0f} ms")
            if i % args.log_every == 0:
                print(f"step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if ckpt is not None and (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state)
        if ckpt is not None:
            ckpt.save(args.steps, state, block=True)
    if losses:
        print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    else:
        print(f"done: resumed at step {start} >= {args.steps}; nothing to do")
    return losses


if __name__ == "__main__":
    main()
