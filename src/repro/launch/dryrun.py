import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective metrics.

The two lines above MUST stay the first statements in this module: jax
locks the platform device count at first init, and the production meshes
need 512 placeholder host devices. Do not fold this into conftest or
pyproject — smoke tests and benches must see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.adamw import OptConfig
from repro.train import serve as serve_lib
from repro.train import step as step_lib
from repro.utils.sharding import (SERVE_FSDP_RULES, SERVE_RULES, TRAIN_RULES,
                                  mesh_axis_sizes, use_mesh_rules)

COLLECTIVE_RE = re.compile(
    r"""(?P<dtype>[a-z0-9]+)\[(?P<dims>[\d,]*)\][^=]*=\s*
        (?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|
         collective-permute)(?:-start)?\(""",
    re.VERBOSE)


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand/result bytes per collective kind from compiled HLO."""
    from repro.utils.hw import dtype_bytes
    out: dict = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op")
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * dtype_bytes(m.group("dtype"))
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    return step_lib.batch_shapes(cfg, shape)


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               donate: bool = True, oc: "OptConfig | None" = None,
               decode_loop: int = 0, serve_variant: str = "resident2d"):
    """Build (jitted_fn, args_shapes) for one (arch x shape x mesh) cell."""
    sizes = mesh_axis_sizes(mesh)
    if shape.kind == "train":
        rules = TRAIN_RULES
        accum = step_lib.default_accum_steps(cfg, shape, sizes)
        oc = oc or OptConfig()
        fn = step_lib.make_train_step(cfg, oc, accum)
        state_shapes = step_lib.train_state_shapes(cfg, oc)
        bshapes = step_lib.batch_shapes(cfg, shape)
        state_sh = _named(mesh, step_lib.train_state_pspecs(cfg, rules,
                                                            sizes, oc))
        batch_sh = _named(mesh, step_lib.batch_pspecs(cfg, bshapes, rules, sizes))
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None),
                      donate_argnums=(0,) if donate else ())
        meta = {"accum_steps": accum, "rules": "train",
                "moments": oc.moments_dtype}
        return jfn, (state_shapes, bshapes), rules, meta

    tp = sizes.get("model", 1)
    fsdp = serve_lib.serve_uses_fsdp(cfg, tp=tp)
    from repro.utils.sharding import SERVE_FSDP_GATHER_RULES
    if not fsdp:
        rules = SERVE_RULES
    elif serve_variant == "gather":
        rules = SERVE_FSDP_GATHER_RULES
    else:
        rules = SERVE_FSDP_RULES
    pshapes = M.param_shapes(cfg)
    p_sh = _named(mesh, M.param_pspecs(cfg, rules, sizes))
    bshapes = step_lib.batch_shapes(cfg, shape)
    batch_sh = _named(mesh, step_lib.batch_pspecs(cfg, bshapes, rules, sizes))
    meta = {"serve_fsdp": fsdp, "rules": "serve_fsdp" if fsdp else "serve"}

    if shape.kind == "prefill":
        fn = serve_lib.make_prefill_step(cfg)
        cache_sh = _named(mesh, M.cache_pspecs(cfg, rules, sizes,
                                               shape.global_batch,
                                               shape.seq_len))
        jfn = jax.jit(fn, in_shardings=(p_sh, batch_sh),
                      out_shardings=(None, cache_sh))
        return jfn, (pshapes, bshapes), rules, meta

    # decode
    if decode_loop and cfg.embed_inputs:
        fn = serve_lib.make_decode_loop_step(cfg, decode_loop)
        meta["decode_loop"] = decode_loop
    else:
        fn = serve_lib.make_decode_step(cfg)
    cshapes = M.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    cache_sh = _named(mesh, M.cache_pspecs(cfg, rules, sizes,
                                           shape.global_batch, shape.seq_len))
    jfn = jax.jit(fn, in_shardings=(p_sh, cache_sh, batch_sh, None),
                  out_shardings=(None, cache_sh),
                  donate_argnums=(1,) if donate else ())
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return jfn, (pshapes, cshapes, bshapes, pos), rules, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             cfg: ModelConfig | None = None,
             keep_text: bool = False, oc=None, decode_loop: int = 0,
             serve_variant: str = "resident2d") -> dict:
    """Lower + compile one cell; return the §Dry-run/§Roofline record."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_devices": mesh.devices.size}
    t0 = time.time()
    jfn, args, rules, meta = lower_cell(cfg, shape, mesh, oc=oc,
                                        decode_loop=decode_loop,
                                        serve_variant=serve_variant)
    rec.update(meta)
    with mesh, use_mesh_rules(mesh, rules):
        lowered = jfn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(ma.argument_size_in_bytes +
                          ma.output_size_in_bytes +
                          ma.temp_size_in_bytes -
                          ma.alias_size_in_bytes),
    }
    from repro.core.baseline import normalize_cost_analysis
    ca = normalize_cost_analysis(compiled.cost_analysis())
    rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                   "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                   "transcendentals": float(ca.get("transcendentals", 0.0))}
    text = compiled.as_text()
    rec["collectives"] = parse_collectives(text)
    rec["hlo_bytes"] = len(text)

    # In-core + WA analysis (the paper's model applied to the compiled
    # artifact) — trip-multiplied accounting for §Roofline.
    from repro.core import portmodel, wa
    from repro.core.machine import MACHINES
    rep = portmodel.analyze(text, MACHINES["tpu_v5e"],
                            n_devices=rec["n_devices"])
    rec["portmodel"] = {
        "tp_cycles": rep.tp_cycles,
        "cp_cycles": rep.cp_cycles,
        "serial_cycles": rep.serial_cycles,
        "flops": rep.flops,
        "bytes_hbm": rep.bytes_hbm,
        "coll_bytes": rep.coll_bytes,
        "bottleneck": rep.bottleneck(),
        "unknown_ops": rep.unknown_ops,
        "n_instrs": rep.n_instrs,
        "trips": {k: v for k, v in sorted(rep.trips_seen.items())[:16]},
        "top_ports": dict(sorted(rep.port_occupation.items(),
                                 key=lambda kv: -kv[1])[:6]),
        "loop_bytes": dict(sorted(rep.loop_bytes.items(),
                                  key=lambda kv: -(kv[1][0] * kv[1][1]))[:12]),
    }
    rec["wa"] = wa.analyze_text_stores(text)
    rec["wa_ratio"] = rec["wa"]["wa_ratio"]
    if keep_text:
        rec["hlo_text"] = text
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in shapes_for(get_config(arch)):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, sh in cells:
        for mp in meshes:
            tag = f"{arch}_{sh}_{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)")
                continue
            try:
                rec = run_cell(arch, sh, multi_pod=mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                mem = rec["memory"]["peak_bytes"] / 1e9
                print(f"[ok]   {tag}: peak {mem:.2f} GB/dev, "
                      f"flops/dev {rec['cost']['flops']:.3e}, "
                      f"lower {rec['lower_s']}s compile {rec['compile_s']}s",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — sweep must survive
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                with open(os.path.join(args.out, tag + ".err"), "w") as f:
                    f.write(traceback.format_exc())
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
