"""Sharded token data pipeline.

Two sources:
 * SyntheticLM — deterministic per-step token stream (zipfian marginals,
   shift-register sequence structure so the LM loss is learnable), used by
   tests/examples and the end-to-end driver;
 * MemmapCorpus — packed uint16/uint32 token files (np.memmap), the
   production path: each data-parallel shard reads only its slice.

Batches are built host-locally per shard and assembled with
jax.make_array_from_callback against the live mesh sharding, so no host
ever materializes the global batch (multi-pod friendly).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        v = self.vocab_size
        # zipf-ish marginals + short-range structure: x[t] depends on x[t-1]
        base = rng.zipf(1.3, size=(batch_size, self.seq_len + 1)) % v
        shift = np.roll(base, 1, axis=1) * 31
        toks = ((base + shift) % v).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


@dataclasses.dataclass
class MemmapCorpus:
    path: str
    seq_len: int
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.n_tokens = self._data.shape[0]

    def batch(self, step: int, batch_size: int) -> dict:
        span = self.seq_len + 1
        n_seq = self.n_tokens // span
        rng = np.random.default_rng(step)
        idx = rng.integers(0, n_seq, size=batch_size)
        rows = np.stack([self._data[i * span:(i + 1) * span] for i in idx])
        rows = rows.astype(np.int32)
        return {"tokens": rows[:, :-1], "targets": rows[:, 1:]}


def device_put_batch(batch: dict, mesh, batch_spec_tree: dict) -> dict:
    """Place host batch onto the mesh with the given PartitionSpecs."""
    out = {}
    for k, v in batch.items():
        spec = batch_spec_tree.get(k, P())
        sharding = NamedSharding(mesh, spec)
        arr = np.asarray(v)

        def cb(index):
            return arr[index]

        out[k] = jax.make_array_from_callback(arr.shape, sharding, cb)
    return out


def make_iterator(cfg: ModelConfig, shape: ShapeSpec, mesh=None,
                  batch_specs: dict | None = None, source=None, seed=0):
    """Yields device-placed training batches forever."""
    src = source or SyntheticLM(cfg.vocab_size, shape.seq_len, seed)
    step = 0
    while True:
        b = src.batch(step, shape.global_batch)
        if cfg.rope_kind == "mrope":
            pos = np.broadcast_to(
                np.arange(shape.seq_len, dtype=np.int32),
                (shape.global_batch, shape.seq_len))
            b["positions"] = np.broadcast_to(
                pos, (3, shape.global_batch, shape.seq_len)).copy()
        if not cfg.embed_inputs:
            rng = np.random.default_rng(step)
            b["embeds"] = rng.standard_normal(
                (shape.global_batch, shape.seq_len, cfg.d_model),
                dtype=np.float32).astype(np.dtype("bfloat16")
                                         if cfg.param_dtype == "bfloat16"
                                         else np.float32)
            b.pop("tokens")
        if mesh is not None and batch_specs is not None:
            b = device_put_batch(b, mesh, batch_specs)
        else:
            b = {k: jnp.asarray(v) for k, v in b.items()}
        yield b
        step += 1
