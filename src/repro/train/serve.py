"""Serving steps: prefill (build cache, emit last-token logits only) and
single-token decode against a donated, possibly sequence-sharded KV cache.

Cache donation is the framework's "non-temporal store" analogue (DESIGN.md
§2): without it every decode step would copy the whole multi-GB cache
(a write-allocate at system scale); with donation the dynamic-update-slice
happens in place.

The continuous-batching engine (repro.serve) builds on these steps:
``make_prefill_step(cfg, cache_len=H)`` preallocates the KV buffers at the
full decode horizon inside the prefill graph (no post-hoc regrow), and
``repro.serve.decode.make_chunked_decode_step`` generalizes
:func:`make_decode_loop_step` with per-slot positions and in-graph
temperature sampling.
"""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train.step import model_inputs


def make_prefill_step(cfg: ModelConfig, cache_len: int | None = None,
                      store_flavor: str | None = None):
    """Prefill step: (params, batch) -> (last-token logits, cache).

    ``cache_len`` preallocates the attention KV buffers at the full decode
    horizon inside the prefill graph — the serve engine's slot caches are
    built once here instead of being regrown (copied) after the fact.
    ``store_flavor`` picks the cache-fill store path
    (repro.kernels.stores; None = standard).
    """
    def prefill(params, batch):
        logits, aux, cache = M.forward(cfg, params, model_inputs(cfg, batch),
                                       mode="prefill", cache_len=cache_len,
                                       store_flavor=store_flavor)
        return logits, cache
    return prefill


def make_decode_step(cfg: ModelConfig):
    """Single-token decode step: (params, cache, batch, pos) -> (logits, cache).

    ``pos`` may be a scalar (whole batch at one position) or a (B,) vector
    (per-slot positions, continuous batching).
    """
    def decode(params, cache, batch, pos):
        logits, aux, new_cache = M.forward(
            cfg, params, model_inputs(cfg, batch), mode="decode",
            cache=cache, pos=pos)
        return logits[:, 0], new_cache
    return decode


def make_decode_loop_step(cfg: ModelConfig, n_tokens: int):
    """Multi-token in-graph greedy decode (§Perf iteration for the
    collective-bound serve cells): the per-layer FSDP weight all-gather is
    loop-invariant, so XLA hoists it out of the token scan — one gather
    per n_tokens instead of per token. Token-id models only.

    Thin greedy wrapper over the generalized chunked decode step
    (repro.serve.decode) kept for the dryrun/perf call sites.
    """
    from repro.serve.decode import make_chunked_decode_step
    step = make_chunked_decode_step(cfg, n_tokens, temperature=0.0)

    def loop(params, cache, batch, pos):
        toks, cache, _pos = step(params, cache, batch["tokens"], pos,
                                 jax.random.PRNGKey(0))
        return toks, cache

    return loop


def serve_uses_fsdp(cfg: ModelConfig, tp: int = 16,
                    hbm_budget: float = 10e9) -> bool:
    """Pure-TP weights only when they fit a chip's HBM with headroom."""
    return cfg.param_count() * 2 / tp > hbm_budget
