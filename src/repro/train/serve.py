"""Serving steps: prefill (build cache, emit last-token logits only) and
single-token decode against a donated, possibly sequence-sharded KV cache.

Cache donation is the framework's "non-temporal store" analogue (DESIGN.md
§2): without it every decode step would copy the whole multi-GB cache
(a write-allocate at system scale); with donation the dynamic-update-slice
happens in place.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as M
from repro.train.step import model_inputs


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, aux, cache = M.forward(cfg, params, model_inputs(cfg, batch),
                                       mode="prefill")
        return logits, cache
    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, cache, batch, pos):
        logits, aux, new_cache = M.forward(
            cfg, params, model_inputs(cfg, batch), mode="decode",
            cache=cache, pos=pos)
        return logits[:, 0], new_cache
    return decode


def make_decode_loop_step(cfg: ModelConfig, n_tokens: int):
    """Multi-token in-graph greedy decode (§Perf iteration for the
    collective-bound serve cells): the per-layer FSDP weight all-gather is
    loop-invariant, so XLA hoists it out of the token scan — one gather
    per n_tokens instead of per token. Token-id models only."""
    assert cfg.embed_inputs, "loop decode needs a token embedding"

    def step(params, cache, batch, pos):
        def body(carry, t):
            cache, tok = carry
            logits, _, cache = M.forward(cfg, params, {"tokens": tok},
                                         mode="decode", cache=cache,
                                         pos=pos + t)
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
            return (cache, nxt), nxt[:, 0]

        (cache, _), toks = jax.lax.scan(
            body, (cache, batch["tokens"]),
            jnp.arange(n_tokens, dtype=jnp.int32))
        return jnp.swapaxes(toks, 0, 1), cache

    return step


def serve_uses_fsdp(cfg: ModelConfig, tp: int = 16,
                    hbm_budget: float = 10e9) -> bool:
    """Pure-TP weights only when they fit a chip's HBM with headroom."""
    return cfg.param_count() * 2 / tp > hbm_budget
