"""Losses: token cross-entropy (fp32, vocab-shard friendly) + MoE aux."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None):
    """Mean token CE. logits (B,S,V) any float dtype; targets (B,S) int32.

    logsumexp/gather in fp32; reductions over the (possibly model-sharded)
    vocab dim lower to SPMD psums.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
