"""Train-step builder: loss → grads (with microbatch accumulation) → AdamW.

The returned step function is pure and pjit-ready: state and batch carry
NamedShardings derived from the logical-axis rule tables, gradients inherit
parameter shardings (GSPMD inserts the reduce-scatter/all-gather schedule),
and the whole state is donated.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as M
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state
from repro.train.losses import cross_entropy
from repro.utils.sharding import (TRAIN_RULES, mesh_axis_sizes, spec_for,
                                  use_mesh_rules)

AUX_COEF = 0.01


def model_inputs(cfg: ModelConfig, batch: dict) -> dict:
    keys = ("tokens", "embeds", "positions")
    return {k: batch[k] for k in keys if k in batch}


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = M.forward(cfg, params, model_inputs(cfg, batch),
                                mode="train")
        ce = cross_entropy(logits, batch["targets"])
        loss = ce + AUX_COEF * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, oc: OptConfig, accum_steps: int = 1):
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, parts), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l, a_acc + parts["aux"]), None

            def split(x):
                return x.reshape(accum_steps, x.shape[0] // accum_steps,
                                 *x.shape[1:])

            mbs = jax.tree.map(
                lambda x: split(x) if x.ndim >= 2 and
                x.shape[0] % accum_steps == 0 else
                jnp.broadcast_to(x, (accum_steps,) + x.shape), batch)
            # mrope positions (3, B, S): microbatch along axis 1
            if "positions" in batch and batch["positions"].ndim == 3 \
                    and batch["positions"].shape[0] == 3:
                p = batch["positions"]
                mbs["positions"] = jnp.moveaxis(
                    p.reshape(3, accum_steps, p.shape[1] // accum_steps,
                              p.shape[2]), 1, 0)
            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              params)
            (grads, loss, aux), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), mbs)
            loss = loss / accum_steps
            parts = {"ce": loss, "aux": aux / accum_steps}
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        new_params, new_opt, om = adamw_update(
            oc, params, grads, state["opt"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, **parts, **om}
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# Shapes & shardings for AOT lowering
# ---------------------------------------------------------------------------

def train_state_shapes(cfg: ModelConfig, oc: OptConfig | None = None) -> dict:
    ps = M.param_shapes(cfg)
    if oc is not None and oc.moments_dtype == "int8":
        def mo(s):
            return {"q": jax.ShapeDtypeStruct(s.shape, jnp.int8),
                    "s": jax.ShapeDtypeStruct(s.shape[:-1] + (1,),
                                              jnp.float32)}
    else:
        def mo(s):
            return jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {"params": ps,
            "opt": {"m": jax.tree.map(mo, ps), "v": jax.tree.map(mo, ps)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(cfg: ModelConfig, key,
                     oc: OptConfig | None = None) -> dict:
    params = M.init_params(cfg, key)
    md = oc.moments_dtype if oc is not None else "float32"
    return {"params": params, "opt": init_opt_state(params, md),
            "step": jnp.zeros((), jnp.int32)}


def batch_shapes(cfg: ModelConfig, shape: ShapeSpec, *,
                 with_targets: bool = True) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        s = 1
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.dtype(cfg.param_dtype))
    if cfg.rope_kind == "mrope":
        out["positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    if with_targets and shape.kind == "train":
        out["targets"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return out


def batch_pspecs(cfg: ModelConfig, shapes: dict, rules: dict,
                 mesh_sizes: dict) -> dict:
    def f(name, s):
        if name == "positions" and len(s.shape) == 3 and s.shape[0] == 3:
            axes = (None, "batch", None)
        else:
            axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return spec_for(s.shape, axes, rules, mesh_sizes)
    return {k: f(k, v) for k, v in shapes.items()}


def train_state_pspecs(cfg: ModelConfig, rules: dict, mesh_sizes: dict,
                       oc: OptConfig | None = None) -> dict:
    pp = M.param_pspecs(cfg, rules, mesh_sizes)
    from jax.sharding import PartitionSpec as P
    if oc is not None and oc.moments_dtype == "int8":
        def mo(spec):
            entries = tuple(spec)
            s_spec = P(*(entries[:-1] + (None,))) if entries else P()
            return {"q": spec, "s": s_spec}
        mom = jax.tree.map(mo, pp,
                           is_leaf=lambda x: isinstance(x, P))
        return {"params": pp, "opt": {"m": mom, "v": mom}, "step": P()}
    return {"params": pp, "opt": {"m": pp, "v": pp}, "step": P()}


def default_accum_steps(cfg: ModelConfig, shape: ShapeSpec,
                        mesh_sizes: dict, budget_bytes: float = 2.5e9) -> int:
    """Pick gradient-accumulation steps so the per-device stored scan
    carries (residual stream per layer under full remat) fit the budget."""
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_sizes.get(ax, 1)
    b_loc = max(1, shape.global_batch // dp)
    carry = b_loc * shape.seq_len * cfg.d_model * 2 * cfg.n_layers
    accum = 1
    while carry / accum > budget_bytes and accum < b_loc:
        accum *= 2
    return accum
