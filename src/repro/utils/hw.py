"""Hardware constants for the target TPU fleet and roofline math.

These mirror the paper's Table I ("core features") for our three target
TPU generations, plus the assignment-mandated v5e numbers used for all
roofline terms:

    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MemTier:
    """One level of a machine's memory hierarchy (ECM-style tier).

    Capacities are the working-set capacity *visible from one core* (the
    classic cache-ladder x-axis): private L1/L2 capacity for the private
    tiers, the shared slice a single core can realistically occupy for
    L3/SLC, and ``inf`` for DRAM/HBM. Bandwidths are single-core
    sustained rates; ``shared_bw`` is the socket-level ceiling for
    shared tiers (0.0 marks a private tier whose aggregate bandwidth
    scales linearly with active cores).

    ``wa_residue`` parametrizes write-allocate evasion quality at this
    tier boundary, after the CloverLeaf WA-evasion study (arXiv:
    2311.04797): the fraction of allocate-read traffic that *remains*
    when the machine's evasion mechanism (cache-line claim, SpecI2M, NT
    stores) engages for stores homed here. 1.0 = no mechanism operates
    at this boundary; 0.0 = perfect evasion.
    """

    name: str                  # "L1" / "L2" / "L3" / "DRAM" / "VMEM"...
    capacity_bytes: float      # working-set capacity seen from one core
    load_bw: float             # bytes/s, single-core sustained load
    store_bw: float            # bytes/s, single-core sustained store
    shared_bw: float = 0.0     # socket ceiling; 0.0 = private tier
    wa_residue: float = 1.0    # allocate fraction left under evasion


def _cache_ladder(clock_hz: float, levels: tuple) -> tuple:
    """Build a MemTier ladder from per-level (name, capacity, load B/cy,
    store B/cy, shared GB/s or 0, wa_residue) rows at a fixed clock."""
    return tuple(
        MemTier(name=n, capacity_bytes=float(cap),
                load_bw=ld * clock_hz, store_bw=st * clock_hz,
                shared_bw=sh * 1e9, wa_residue=res)
        for (n, cap, ld, st, sh, res) in levels)


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    # peak compute
    bf16_flops: float          # FLOP/s per chip
    int8_ops: float            # OP/s per chip
    # memory system
    hbm_bytes: float           # capacity per chip
    hbm_bw: float              # bytes/s per chip
    vmem_bytes: float          # on-chip vector memory
    # interconnect
    ici_link_bw: float         # bytes/s per link (one direction)
    ici_links: int             # links per chip (3D torus: 6; 2D: 4)
    # core geometry (for the in-core port model)
    clock_hz: float
    n_mxu: int                 # 128x128 systolic arrays per core
    n_vpu: int                 # (8,128) vector ALU lanesets usable per cycle
    native_tile: tuple = (8, 128)  # tile granule (fp32 sublane x lane)
    mem_tiers: tuple = ()      # MemTier ladder (VMEM -> HBM), inner first


def _tpu_tiers(vmem_bytes: float, hbm_bw: float) -> tuple:
    """VMEM + HBM ladder for a TPU chip.

    VMEM feeds the compute units at roughly an order of magnitude above
    HBM (it backs every VPU operand fetch); HBM is the DMA-visible tier.
    Both claim full tiles on store (the Grace-like `auto_claim`
    behaviour, DESIGN.md §2), so the WA residue is 0 at both tiers.
    """
    return (
        MemTier("VMEM", float(vmem_bytes), 10.0 * hbm_bw, 10.0 * hbm_bw,
                shared_bw=10.0 * hbm_bw, wa_residue=0.0),
        MemTier("HBM", math.inf, hbm_bw, hbm_bw,
                shared_bw=hbm_bw, wa_residue=0.0),
    )


# TPU v5e — the assignment's target chip. 197 bf16 TFLOP/s at ~0.94 GHz
# with 4 MXUs: 4 * 128*128 * 2 flop * clock ≈ 197e12 → clock ≈ 1.5e9 / ...
# Public spec: 393 int8 TOPS / 197 bf16 TFLOPS, 16 GB HBM2E @ 819 GB/s,
# 1.6 Tbps ICI x4 links (=50 GB/s/link/dir).
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    bf16_flops=197e12,
    int8_ops=394e12,
    hbm_bytes=16e9,
    hbm_bw=819e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=4,
    clock_hz=1.5e9,   # modeled: 4 MXU * 128*128*2 * 1.5e9 = 196.6e12
    n_mxu=4,
    n_vpu=8,
    mem_tiers=_tpu_tiers(128 * 2**20, 819e9),
)

# TPU v5p — the "Sapphire Rapids" of the comparison: widest compute.
TPU_V5P = ChipSpec(
    name="tpu_v5p",
    bf16_flops=459e12,
    int8_ops=918e12,
    hbm_bytes=95e9,
    hbm_bw=2765e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=100e9,
    ici_links=6,
    clock_hz=1.75e9,  # modeled: 8 MXU * 128*128*2 * 1.75e9 ≈ 459e12
    n_mxu=8,
    n_vpu=16,
    mem_tiers=_tpu_tiers(128 * 2**20, 2765e9),
)

# TPU v4 — previous generation baseline.
TPU_V4 = ChipSpec(
    name="tpu_v4",
    bf16_flops=275e12,
    int8_ops=275e12,
    hbm_bytes=32e9,
    hbm_bw=1228e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=6,
    clock_hz=1.05e9,  # modeled: 8 MXU * 128*128*2 * 1.05e9 ≈ 275e12
    n_mxu=8,
    n_vpu=16,
    mem_tiers=_tpu_tiers(128 * 2**20, 1228e9),
)

CHIPS = {c.name: c for c in (TPU_V5E, TPU_V5P, TPU_V4)}


# --- the paper's actual CPUs (Table I / Table II core features) -------------

@dataclasses.dataclass(frozen=True)
class CpuSpec:
    """Core + node features of one paper CPU (Table I / Table II).

    Port counts describe the scheduler-visible functional-unit groups the
    in-core model needs: FMA-capable SIMD pipes (the `mxu` analogue), total
    SIMD/FP pipes (`vpu`), load/store pipes (`vlsu`), and the single
    divider pipe (`vdiv`).
    """
    name: str
    vendor: str
    uarch: str
    isa: str
    clock_hz: float            # fixed core clock used in the paper's runs
    issue_width: int           # rename/dispatch width, µops per cycle
    simd_width_bytes: int      # native datapath width per FP pipe
    n_fma: int                 # FMA-capable SIMD pipes
    n_simd: int                # all SIMD/FP ALU pipes
    n_load: int                # load pipes (SIMD-capable)
    n_store: int               # store-data pipes
    fma_latency: float         # cycles
    load_latency: float        # L1 load-to-use, cycles (vector)
    fdiv_recip_tput: float     # cycles per full-width vector divide
    fdiv_latency: float
    l1d_bytes: int
    mem_bw: float              # bytes/s sustained per socket (stream-like)
    xsocket_bw: float          # bytes/s cross-socket/C2C link
    cores: int                 # cores per socket
    wa_mode: str               # write-allocate behaviour (core/wa.py)
    mem_tiers: tuple = ()      # MemTier cache ladder, L1 first, DRAM last


# AMD Genoa / Zen 4 (EPYC 9654). 6-wide; 4 FP pipes of which FP0/FP1 are
# 256-bit FMA (AVX-512 is double-pumped on the 256-bit datapath); divider
# on one pipe, not pipelined. WA evasion only via explicit NT stores.
ZEN4 = CpuSpec(
    name="zen4", vendor="AMD", uarch="Zen 4", isa="x86-64 AVX-512(2x256b)",
    clock_hz=2.4e9, issue_width=6, simd_width_bytes=32,
    n_fma=2, n_simd=4, n_load=2, n_store=1,
    fma_latency=4.0, load_latency=7.0,
    fdiv_recip_tput=6.5, fdiv_latency=13.0,
    l1d_bytes=32 * 1024, mem_bw=460.8e9, xsocket_bw=50e9, cores=96,
    wa_mode="explicit_only",
    # Cache ladder (B/cy single core at 2.4 GHz; shared GB/s socket).
    # Standard stores write-allocate at every boundary (residue 1.0);
    # only explicit NT stores evade, fully, at the DRAM interface.
    mem_tiers=_cache_ladder(2.4e9, (
        ("L1", 32 * 1024, 64.0, 32.0, 0.0, 1.0),
        ("L2", 1 * 2**20, 32.0, 32.0, 0.0, 1.0),
        ("L3", 32 * 2**20, 24.0, 20.0, 1380.0, 1.0),   # one CCD slice
        ("DRAM", math.inf, 16.0, 10.0, 460.8, 0.0),    # NT: full evasion
    )),
)

# Intel Sapphire Rapids / Golden Cove (Xeon 8470). 6-wide; with AVX-512
# ports P0+P1 fuse into one 512-bit FMA pipe next to P5 -> two 512-bit
# FMA pipes; divider on P0; 2x512b loads + 1x512b store per cycle.
# SpecI2M evades write-allocates only near bandwidth saturation.
GOLDEN_COVE = CpuSpec(
    name="golden_cove", vendor="Intel", uarch="Golden Cove",
    isa="x86-64 AVX-512", clock_hz=2.0e9, issue_width=6,
    simd_width_bytes=64, n_fma=2, n_simd=2, n_load=2, n_store=1,
    fma_latency=4.0, load_latency=7.0,
    fdiv_recip_tput=8.0, fdiv_latency=16.0,
    l1d_bytes=48 * 1024, mem_bw=307.2e9, xsocket_bw=48e9, cores=52,
    wa_mode="saturation_gated",
    # SpecI2M operates only at the memory interface and leaves ~10% of
    # the allocate traffic behind even when fully engaged (Fig. 4).
    mem_tiers=_cache_ladder(2.0e9, (
        ("L1", 48 * 1024, 128.0, 64.0, 0.0, 1.0),
        ("L2", 2 * 2**20, 64.0, 48.0, 0.0, 1.0),
        ("L3", 105 * 2**20, 20.0, 12.0, 900.0, 1.0),   # mesh-limited
        ("DRAM", math.inf, 15.0, 10.0, 307.2, 0.1),    # SpecI2M residue
    )),
)

# NVIDIA Grace / Neoverse V2. 8-wide; 4x128-bit SIMD pipes V0..V3, all
# FMA-capable; divider on V0; 3 load + 2 store pipes. The cache claims
# lines on store misses -> next-to-optimal automatic WA evasion.
NEOVERSE_V2 = CpuSpec(
    name="neoverse_v2", vendor="NVIDIA", uarch="Neoverse V2",
    isa="AArch64 NEON/SVE2(4x128b)", clock_hz=3.4e9, issue_width=8,
    simd_width_bytes=16, n_fma=4, n_simd=4, n_load=3, n_store=2,
    fma_latency=4.0, load_latency=6.0,
    fdiv_recip_tput=7.0, fdiv_latency=15.0,
    l1d_bytes=64 * 1024, mem_bw=500e9, xsocket_bw=450e9, cores=72,
    wa_mode="auto_claim",
    # The cache claims lines on store misses at every level, so the WA
    # residue is 0 at every tier boundary — the paper's "next-to-
    # optimal automatic WA evasion".
    mem_tiers=_cache_ladder(3.4e9, (
        ("L1", 64 * 1024, 48.0, 32.0, 0.0, 0.0),
        ("L2", 1 * 2**20, 32.0, 24.0, 0.0, 0.0),
        ("L3", 114 * 2**20, 16.0, 12.0, 1100.0, 0.0),  # SLC
        ("DRAM", math.inf, 15.0, 11.0, 500.0, 0.0),    # LPDDR5X
    )),
)

CPU_CHIPS = {c.name: c for c in (ZEN4, GOLDEN_COVE, NEOVERSE_V2)}

# Assignment-mandated roofline constants (v5e).
PEAK_FLOPS = TPU_V5E.bf16_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_link_bw


def dtype_bytes(dtype_str: str) -> int:
    return {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
        "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
        "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
        "float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
        "int64": 8, "int32": 4, "int16": 2, "int8": 1, "uint8": 1,
        "bool": 1,
    }.get(dtype_str, 4)
