"""Hardware constants for the target TPU fleet and roofline math.

These mirror the paper's Table I ("core features") for our three target
TPU generations, plus the assignment-mandated v5e numbers used for all
roofline terms:

    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    # peak compute
    bf16_flops: float          # FLOP/s per chip
    int8_ops: float            # OP/s per chip
    # memory system
    hbm_bytes: float           # capacity per chip
    hbm_bw: float              # bytes/s per chip
    vmem_bytes: float          # on-chip vector memory
    # interconnect
    ici_link_bw: float         # bytes/s per link (one direction)
    ici_links: int             # links per chip (3D torus: 6; 2D: 4)
    # core geometry (for the in-core port model)
    clock_hz: float
    n_mxu: int                 # 128x128 systolic arrays per core
    n_vpu: int                 # (8,128) vector ALU lanesets usable per cycle
    native_tile: tuple = (8, 128)  # HBM/VMEM tile granule (fp32 sublane x lane)


# TPU v5e — the assignment's target chip. 197 bf16 TFLOP/s at ~0.94 GHz
# with 4 MXUs: 4 * 128*128 * 2 flop * clock ≈ 197e12 → clock ≈ 1.5e9 / ...
# Public spec: 393 int8 TOPS / 197 bf16 TFLOPS, 16 GB HBM2E @ 819 GB/s,
# 1.6 Tbps ICI x4 links (=50 GB/s/link/dir).
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    bf16_flops=197e12,
    int8_ops=394e12,
    hbm_bytes=16e9,
    hbm_bw=819e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=4,
    clock_hz=1.5e9,   # modeled: 4 MXU * 128*128*2 * 1.5e9 = 196.6e12
    n_mxu=4,
    n_vpu=8,
)

# TPU v5p — the "Sapphire Rapids" of the comparison: widest compute.
TPU_V5P = ChipSpec(
    name="tpu_v5p",
    bf16_flops=459e12,
    int8_ops=918e12,
    hbm_bytes=95e9,
    hbm_bw=2765e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=100e9,
    ici_links=6,
    clock_hz=1.75e9,  # modeled: 8 MXU * 128*128*2 * 1.75e9 ≈ 459e12
    n_mxu=8,
    n_vpu=16,
)

# TPU v4 — previous generation baseline.
TPU_V4 = ChipSpec(
    name="tpu_v4",
    bf16_flops=275e12,
    int8_ops=275e12,
    hbm_bytes=32e9,
    hbm_bw=1228e9,
    vmem_bytes=128 * 2**20,
    ici_link_bw=50e9,
    ici_links=6,
    clock_hz=1.05e9,  # modeled: 8 MXU * 128*128*2 * 1.05e9 ≈ 275e12
    n_mxu=8,
    n_vpu=16,
)

CHIPS = {c.name: c for c in (TPU_V5E, TPU_V5P, TPU_V4)}

# Assignment-mandated roofline constants (v5e).
PEAK_FLOPS = TPU_V5E.bf16_flops
HBM_BW = TPU_V5E.hbm_bw
ICI_BW = TPU_V5E.ici_link_bw


def dtype_bytes(dtype_str: str) -> int:
    return {
        "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
        "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
        "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
        "float64": 8, "float32": 4, "bfloat16": 2, "float16": 2,
        "int64": 8, "int32": 4, "int16": 2, "int8": 1, "uint8": 1,
        "bool": 1,
    }.get(dtype_str, 4)
