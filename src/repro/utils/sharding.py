"""Logical-axis sharding rules (MaxText-style) and constraint helpers.

Parameters/caches/activations carry *logical* axis names; a ``Rules`` table
maps each logical name to an ordered list of mesh-axis candidates. The spec
builder greedily assigns candidates subject to (a) divisibility of the dim
by the mesh-axis size and (b) no mesh axis used twice in one spec — this is
what lets e.g. grok-1's 8 experts fall back from expert-parallel to
ffn-dim tensor-parallel automatically.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Meta mesh-axis groups, expanded against the live mesh's axis names.
FSDP = ("pod", "data")
TP = ("model",)
DATA = ("pod", "data")

TRAIN_RULES = {
    "batch": DATA,
    "act_batch": DATA,      # activation batch dim at block boundaries
    "act_embed": (),        # activation d_model dim at block boundaries
    "embed": FSDP,          # FSDP: weight d_model rows sharded, gathered at use
    "mlp": TP,
    "qheads": TP,
    "kvheads": TP,
    "vocab": TP,
    "expert": TP,
    "emlp": TP,             # fallback when expert-count doesn't divide TP
    "ssm_inner": TP,
    "slstm_h": TP,
    "kv_seq": TP,           # decode KV-cache sequence dim
    "stack": (),            # scan-stacked leading dim: never sharded
    None: (),
}

# Serving: no FSDP on weights by default (pure TP); big archs override.
SERVE_RULES = dict(TRAIN_RULES, embed=())

# Serve-engine rules (repro.serve.ServeEngine): the KV cache shards over
# *heads* (kvheads -> TP) with the sequence dim resident — the split-KV
# and paged decode kernels tile the sequence themselves, so the TP split
# must land on the embarrassingly parallel head dim, not on kv_seq (which
# SERVE_RULES would grab first and which a block-table gather cannot
# shard). Batch stays on the data axis.
SERVE_ENGINE_RULES = dict(SERVE_RULES, kv_seq=())

# FSDP-flavored engine rules: same KV layout, activations 2D-sharded.
SERVE_ENGINE_FSDP_RULES = dict(SERVE_ENGINE_RULES, act_batch=(),
                               act_embed=FSDP)

# FSDP serving for > HBM models. `act_embed` -> FSDP turns every matmul
# into a partial-sum over resident 2D-sharded weights + an activation
# all-reduce (KBs) instead of a per-layer weight all-gather (GBs) — see
# EXPERIMENTS.md §Perf H2.
SERVE_FSDP_RULES = dict(TRAIN_RULES, act_batch=(), act_embed=FSDP)

# The pre-H2 baseline: weights FSDP-sharded, activations batch-sharded —
# GSPMD all-gathers every layer's weights per step (kept for the §Perf
# before/after comparison).
SERVE_FSDP_GATHER_RULES = dict(TRAIN_RULES)


class _MeshState(threading.local):
    def __init__(self):
        self.mesh = None
        self.rules = None


_STATE = _MeshState()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: dict | None):
    prev = (_STATE.mesh, _STATE.rules)
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def tp_degree(mesh_sizes: dict, rules: dict | None = None) -> int:
    """Tensor-parallel degree of a mesh under ``rules``.

    The product of the mesh-axis sizes that the ``kvheads`` logical axis
    may shard over — the number of ways attention heads (and with them
    the per-shard KV stream) are split. ``rules=None`` uses the standard
    TP group. Missing axes contribute 1, so a pure-data mesh (or no
    mesh at all, ``mesh_sizes={}``) has TP degree 1.
    """
    axes = (rules or {}).get("kvheads", TP)
    prod = 1
    for a in axes:
        prod *= int(mesh_sizes.get(a, 1))
    return prod


def rules_fingerprint(rules: dict | None) -> tuple:
    """Stable, hashable identity of a rules table (plan memo keys).

    ``id(rules)`` would alias a rebuilt-but-identical table to a
    different key (and a mutated one to the same key); this folds the
    table's *contents* instead. The ``None`` logical axis is folded via
    ``str`` so the tuple sorts cleanly.
    """
    if rules is None:
        return ()
    return tuple(sorted((str(k), tuple(v)) for k, v in rules.items()))


def spec_for(shape: tuple, axes: tuple, rules: dict, mesh_sizes: dict) -> P:
    """Build a PartitionSpec for `shape` with logical `axes` under `rules`."""
    assert len(shape) == len(axes), (shape, axes)
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        cands = rules.get(ax, ())
        picked = []
        prod = 1
        for m in cands:
            if m in used or m not in mesh_sizes:
                continue
            if dim % (prod * mesh_sizes[m]) != 0:
                continue
            picked.append(m)
            prod *= mesh_sizes[m]
        used.update(picked)
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    return P(*parts)


def sc(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient mesh/rules (no-op when
    no mesh is installed — smoke tests on one device)."""
    if _STATE.mesh is None or _STATE.rules is None:
        return x
    sizes = mesh_axis_sizes(_STATE.mesh)
    spec = spec_for(x.shape, tuple(axes), _STATE.rules, sizes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, spec))


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
