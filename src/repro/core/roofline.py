"""§Roofline: three-term analysis of every compiled dry-run cell.

    compute term    = HLO_FLOPs / (chips x peak FLOP/s)
    memory term     = tier-resolved ECM ladder term [WA/RMW-adjusted]
    collective term = wire bytes / (chips x ICI bw)

The memory term is no longer a flat ``bytes / HBM_BW``: the WA-adjusted
traffic is resolved against the machine's memory ladder
(core/memtier.py), which degrades to exactly the flat HBM number for
working sets that resolve to the backing tier (the common case for
whole-model dry runs) but correctly credits VMEM/cache-resident cells.
Numbers come from the port-model analyzer's trip-multiplied accounting
(XLA's cost_analysis visits while bodies once — see portmodel.py); raw
cost_analysis values are kept alongside for the naive-baseline comparison.
The in-core port model supplies a *tighter* compute bound (T_comp_port)
than flops/peak — the paper's model used "as part of holistic performance
models such as Roofline" (paper §I.A).
"""

from __future__ import annotations

import dataclasses

from repro.core import memtier, portmodel
from repro.core.machine import MACHINES, MachineModel
from repro.utils.hw import PEAK_FLOPS, ICI_BW


@dataclasses.dataclass
class RooflineCell:
    """Roofline terms + accounting for one (arch, shape, mesh) cell."""

    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device terms, seconds
    t_compute: float
    t_memory: float
    t_collective: float
    t_compute_port: float         # port-model in-core bound (>= t_compute)
    dominant: str
    # accounting (per device)
    flops: float
    bytes_hbm: float
    coll_bytes: dict
    wa_ratio: float
    # usefulness
    model_flops: float            # 6*N*D (global)
    useful_ratio: float           # model_flops / (flops * n_devices)
    bottleneck_port: str
    peak_fraction: float          # (model_flops/chips/peak) / bound
    notes: str = ""
    # memory-ladder resolution (core/memtier.py)
    bottleneck_tier: str = "HBM"  # slowest transfer leg of the ladder
    home_tier: str = "HBM"        # tier the working set resolves to

    @property
    def bound(self) -> float:
        """The roofline bound: slowest of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)


def collective_seconds(coll_bytes: dict, ici_bw: float = ICI_BW,
                       links: int = 4) -> float:
    """Wire bytes already include ring factors (isa.py); a chip moves its
    share over `links` links in parallel for ring algorithms."""
    total = sum(coll_bytes.values())
    return total / (ici_bw * links)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode counts one
    token per sequence, prefill counts forward-only (2*N*D)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one step
    return 2.0 * n * tokens


def analyze_cell(rec: dict, cfg, shape, hlo_text: str | None = None,
                 machine: MachineModel | None = None,
                 report: "portmodel.Report | None" = None) -> RooflineCell:
    """Build the roofline row for one dry-run record.

    rec: the JSON record from repro.launch.dryrun. hlo_text: compiled HLO
    (for port-model accounting); without it we fall back to raw
    cost_analysis (documented as under-counting loops).
    """
    machine = machine or MACHINES["tpu_v5e"]
    chips = rec["n_devices"]
    if report is None and hlo_text is not None:
        report = portmodel.analyze(hlo_text, machine, n_devices=chips)

    if report is not None:
        flops = report.flops
        bytes_hbm = report.bytes_hbm
        coll = report.coll_bytes
        t_port = report.seconds(machine)
        port = report.bottleneck()
    else:
        flops = rec["cost"]["flops"]
        bytes_hbm = rec["cost"]["bytes_accessed"]
        coll = {k: v["bytes"] for k, v in rec.get("collectives", {}).items()}
        t_port = 0.0
        port = "n/a"

    wa_ratio = rec.get("wa_ratio", 1.0)
    t_c = flops / PEAK_FLOPS
    # tier-resolved memory term: the record's WA ratio is already folded
    # into the traffic (store_frac=0 keeps the ladder from re-applying
    # its own per-tier WA model on top). The working set is the traffic
    # itself — an upper bound that resolves whole-module cells to the
    # backing HBM/DRAM tier, where this degrades to bytes * wa / bw.
    res = memtier.memory_seconds(machine, bytes_hbm * wa_ratio,
                                 store_frac=0.0)
    t_m = res.seconds
    t_x = collective_seconds(coll)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    if t_port > t_c and t_port >= max(t_m, t_x):
        dominant = "compute(port)"
    else:
        dominant = max(terms, key=terms.get)

    mf = model_flops_for(cfg, shape)
    useful = mf / max(flops * chips, 1.0)
    bound = max(t_c, t_m, t_x, t_port)
    ideal = mf / chips / PEAK_FLOPS
    return RooflineCell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        n_devices=chips, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        t_compute_port=t_port, dominant=dominant, flops=flops,
        bytes_hbm=bytes_hbm, coll_bytes=dict(coll), wa_ratio=wa_ratio,
        model_flops=mf, useful_ratio=useful, bottleneck_port=port,
        peak_fraction=ideal / bound if bound > 0 else 0.0,
        bottleneck_tier=res.bottleneck_tier, home_tier=res.home)


def to_markdown(cells: list) -> str:
    """Render roofline cells as a GitHub-flavored markdown table."""
    hdr = ("| arch | shape | mesh | T_comp | T_comp(port) | T_mem | T_coll "
           "| dominant | tier | MF/HLO | peak-frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.t_compute*1e3:.2f}ms "
            f"| {c.t_compute_port*1e3:.2f}ms | {c.t_memory*1e3:.2f}ms "
            f"| {c.t_collective*1e3:.2f}ms | {c.dominant} "
            f"| {c.bottleneck_tier} "
            f"| {c.useful_ratio:.2f} | {c.peak_fraction:.1%} |")
    return hdr + "\n".join(rows)
