"""The paper's analysis stack: HLO parsing, in-core port models, WA
modes, ECM memory ladders, roofline, calibration, and RPE validation.

See docs/architecture.md for the dataflow between these modules.
"""
