"""Multi-tier memory-hierarchy (ECM-style) model with WA-aware ladders.

The in-core port model (``core/portmodel.py``) assumes operands are
resident next to the core; everything else is the memory hierarchy's
problem. This module models that problem in the Execution-Cache-Memory
(ECM) tradition of Hofmann et al.'s generational Intel analysis
(arXiv:1702.07554): a working set is *resolved* to its home tier (the
innermost cache level that holds it), and the time of a loop's memory
traffic is composed from the per-tier transfer legs between the core
and that home tier.

Two compositions are offered:

* ``overlap="none"`` — classic pessimistic ECM: the legs serialize, the
  memory term is the *sum* of leg times. Right for in-order-ish
  machines and single-buffered transfers.
* ``overlap="full"`` — all legs stream concurrently (hardware
  prefetchers on the paper CPUs, double-buffered DMA on TPUs): the
  memory term is the *max* leg time. This is the default, and it makes
  a DRAM-resident working set degrade exactly to the familiar flat
  ``bytes / mem_bw`` roofline term.

Write-allocate awareness: each :class:`repro.utils.hw.MemTier` carries a
``wa_residue`` — the allocate-read traffic fraction that survives when
the machine's WA-evasion mechanism engages at that boundary (CloverLeaf
WA-evasion study, arXiv:2311.04797). The per-tier store traffic is the
Fig. 4 behavioural model (``core/wa.py``) evaluated with that residue
and with the *modeled* interface saturation at the home tier, so
SpecI2M on `golden_cove` engages only when the ladder says the memory
interface actually saturates — not at a caller-supplied constant gate.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.machine import MachineModel, get_machine
from repro.utils.hw import MemTier


def tiers_of(machine) -> tuple:
    """The MemTier ladder of a machine (model or registered name).

    Machines registered without tiers (e.g. ad-hoc test models) get a
    single flat DRAM tier synthesized from their `dma` entry so every
    consumer can assume a non-empty ladder.
    """
    m = get_machine(machine) if isinstance(machine, str) else machine
    tiers = getattr(m, "mem_tiers", ()) or ()
    if tiers:
        return tuple(tiers)
    return (_fallback_dram(m),)


def _fallback_dram(m: MachineModel) -> MemTier:
    """Synthesize a flat DRAM tier from a model's `dma` byte rate.

    The residue is 0 because a measured/declared `dma` rate already
    reflects whatever allocate traffic the machine generates — charging
    WA on top would double-count it.
    """
    entry = m.table.get("dma")
    bw = m.clock_hz / entry.cycles_per_unit if entry is not None else 1e10
    return MemTier("DRAM", math.inf, bw, bw, shared_bw=bw, wa_residue=0.0)


def resolve_home(tiers, ws_bytes: float) -> MemTier:
    """The innermost tier whose capacity holds ``ws_bytes``.

    Zero-capacity tiers (a machine file may publish a disabled level,
    e.g. a host model with no discernible L3 plateau) are skipped: they
    can never be a home tier, and :func:`ladder` drops them from the
    transfer legs too. Working sets larger than every finite tier
    resolve to the last tier, which by convention is the backing
    DRAM/HBM level.
    """
    home = None
    for t in tiers:
        if t.capacity_bytes <= 0:
            continue
        home = t
        if ws_bytes <= t.capacity_bytes:
            break
    if home is None:
        raise ValueError("machine has no usable memory tiers")
    return home


def ladder(tiers, ws_bytes: float) -> tuple:
    """The transfer legs for a working set: every non-empty tier from
    the innermost level down to (and including) its home tier."""
    home = resolve_home(tiers, ws_bytes)
    legs = []
    for t in tiers:
        if t.capacity_bytes <= 0:
            continue
        legs.append(t)
        if t is home:        # identity: tier names need not be unique
            break
    return tuple(legs)


def effective_bw(tier: MemTier, cores_active: int = 1) -> tuple:
    """(load, store) bytes/s of one tier with ``cores_active`` cores.

    Private tiers scale linearly with cores; shared tiers saturate at
    their socket ceiling (load and store share it proportionally).
    """
    c = max(1, int(cores_active))
    ld, st = tier.load_bw * c, tier.store_bw * c
    if tier.shared_bw > 0:
        cap = tier.shared_bw
        ld, st = min(ld, cap), min(st, cap)
    return ld, st


def modeled_saturation(machine, ws_bytes: float,
                       cores_active: int | None = None) -> float:
    """Modeled interface saturation of a working set's home tier, 0..1.

    This is the gate `saturation_gated` WA evasion (SPR SpecI2M) needs:
    demanded bandwidth (active cores each sustaining their single-core
    rate) against the home tier's shared ceiling. Private tiers scale
    with the cores driving them, so their interface never saturates and
    the function returns 0.0 — SpecI2M correctly stays dormant for
    cache-resident working sets.
    """
    m = get_machine(machine) if isinstance(machine, str) else machine
    home = resolve_home(tiers_of(m), ws_bytes)
    if home.shared_bw <= 0:
        return 0.0
    cores = cores_active if cores_active is not None \
        else (getattr(m, "cores", 0) or 1)
    demand = max(1, int(cores)) * (home.load_bw + home.store_bw)
    return max(0.0, min(1.0, demand / home.shared_bw))


@dataclasses.dataclass(frozen=True)
class TierLeg:
    """One transfer leg of a resolved ladder."""

    tier: str                 # tier name
    seconds: float            # time this leg needs for the traffic
    load_bytes: float         # demand loads crossing this boundary
    store_bytes: float        # WA-adjusted store traffic at this leg
    wa_ratio: float           # store traffic / stored payload here
    load_bw: float            # effective bytes/s used for the load term
    store_bw: float


@dataclasses.dataclass(frozen=True)
class TierResolution:
    """A working set resolved against one machine's memory hierarchy."""

    machine: str
    ws_bytes: float
    home: str                 # home tier name
    legs: tuple               # TierLeg per traversed boundary
    seconds: float            # composed ECM memory term
    saturation: float         # modeled home-interface saturation 0..1
    overlap: str              # composition used ("full" | "none")

    @property
    def bottleneck_tier(self) -> str:
        """Name of the slowest transfer leg."""
        if not self.legs:
            return "none"
        return max(self.legs, key=lambda leg: leg.seconds).tier

    @property
    def traffic_bytes(self) -> float:
        """Total WA-adjusted traffic over the bottleneck leg."""
        if not self.legs:
            return 0.0
        worst = max(self.legs, key=lambda leg: leg.seconds)
        return worst.load_bytes + worst.store_bytes


def transfer_time(machine, *, ws_bytes: float, load_bytes: float,
                  store_bytes: float = 0.0, nt_stores: bool = False,
                  cores_active: int | None = None,
                  overlap: str = "full") -> TierResolution:
    """Compose the ECM memory term of one traffic profile on a machine.

    ``ws_bytes`` picks the home tier; ``load_bytes``/``store_bytes``
    are the demand traffic (per the whole machine if ``cores_active``
    is socket-wide). Store traffic is WA-adjusted per leg: the Fig. 4
    behavioural mode of the machine is evaluated with each tier's
    ``wa_residue`` and the home tier's modeled saturation, so the same
    stores can cost 2x on a Zen 4 DRAM leg and 1x on a Grace one.
    """
    from repro.core import wa  # lazy: wa lazily imports memtier back

    m = get_machine(machine) if isinstance(machine, str) else machine
    tiers = tiers_of(m)
    legs_t = ladder(tiers, ws_bytes)
    cores = cores_active if cores_active is not None \
        else (getattr(m, "cores", 0) or 1)
    sat = modeled_saturation(m, ws_bytes, cores)
    mode = getattr(m, "wa_mode", "") or "auto_claim"

    legs = []
    for t in legs_t:
        ratio = wa.machine_traffic_ratio(
            mode, nt_stores=nt_stores, bw_utilization=sat,
            residue=t.wa_residue)
        ld_bw, st_bw = effective_bw(t, cores)
        st_traffic = store_bytes * ratio
        sec = load_bytes / ld_bw + st_traffic / st_bw
        legs.append(TierLeg(tier=t.name, seconds=sec,
                            load_bytes=load_bytes, store_bytes=st_traffic,
                            wa_ratio=ratio, load_bw=ld_bw, store_bw=st_bw))
    if overlap not in ("full", "none"):
        raise ValueError(f"unknown overlap mode {overlap!r}")
    total = (max((leg.seconds for leg in legs), default=0.0)
             if overlap == "full" else sum(leg.seconds for leg in legs))
    return TierResolution(
        machine=getattr(m, "name", str(machine)), ws_bytes=float(ws_bytes),
        home=legs_t[-1].name if legs_t else "none", legs=tuple(legs),
        seconds=total, saturation=sat, overlap=overlap)


def memory_seconds(machine, traffic_bytes: float,
                   ws_bytes: float | None = None, *,
                   store_frac: float = 1.0 / 3.0,
                   nt_stores: bool = False,
                   cores_active: int | None = None,
                   overlap: str = "full") -> TierResolution:
    """Tier-resolved memory term for an aggregate traffic count.

    Convenience wrapper for callers (roofline, portmodel.compare) that
    only know total HBM/DRAM bytes: the traffic is split into loads and
    stores by ``store_frac`` (streaming code is ~2 loads per store) and
    the working set defaults to the traffic itself — an upper-bound
    proxy that sends big modules to the DRAM/HBM tier, which is where
    the flat roofline lived before this model existed.
    """
    ws = traffic_bytes if ws_bytes is None else ws_bytes
    return transfer_time(
        machine, ws_bytes=float(ws),
        load_bytes=traffic_bytes * (1.0 - store_frac),
        store_bytes=traffic_bytes * store_frac,
        nt_stores=nt_stores, cores_active=cores_active, overlap=overlap)


def page_gather_time(machine, *, n_pages: int, page_bytes: float,
                     table_bytes: float = 0.0,
                     ws_bytes: float | None = None,
                     cores_active: int | None = None,
                     overlap: str = "full") -> TierResolution:
    """Tier-resolved seconds of a block-table page gather (pure reads).

    ``n_pages`` live pages of ``page_bytes`` each, plus the block-table
    entries themselves (``table_bytes`` — a few bytes per page, but a
    *dependent* load the dense path never issues). The working set
    defaults to the gathered bytes; pass the full pool size to price
    the gather against where the pool actually lives
    (repro.serve.kv_traffic does). No stores, so this leg carries no
    write-allocate term on any machine — the WA story of paging is in
    the stores it *avoids* (:func:`page_copy_time` prices the ones it
    adds back: CoW).
    """
    load = n_pages * page_bytes + table_bytes
    ws = load if ws_bytes is None else ws_bytes
    return transfer_time(machine, ws_bytes=float(ws), load_bytes=load,
                         store_bytes=0.0, cores_active=cores_active,
                         overlap=overlap)


def page_copy_time(machine, *, page_bytes: float, n_pages: int = 1,
                   ws_bytes: float | None = None, nt_stores: bool = False,
                   cores_active: int | None = None,
                   overlap: str = "full") -> TierResolution:
    """Tier-resolved seconds of a page-to-page copy (CoW fork).

    Reads ``n_pages`` source pages and stores the same bytes to fresh
    destination pages — the store side is WA-adjusted per leg exactly
    like any other allocating store (``transfer_time``), which is what
    makes CoW cost machine-dependent: a Zen 4 DRAM-resident copy pays
    the write-allocate read of the destination, Grace's claim-based
    mode does not.
    """
    b = n_pages * page_bytes
    ws = 2.0 * b if ws_bytes is None else ws_bytes
    return transfer_time(machine, ws_bytes=float(ws), load_bytes=b,
                         store_bytes=b, nt_stores=nt_stores,
                         cores_active=cores_active, overlap=overlap)
