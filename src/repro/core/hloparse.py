"""Parser for post-optimization HLO text (``compiled.as_text()``).

Regex-grammar based (DESIGN.md §7): resilient to XLA version drift —
unknown constructs degrade to generic instructions, never crash. Extracts
exactly what the in-core model needs:

 * computations (fusion bodies, while bodies/conditions, ENTRY)
 * per-instruction: opcode, result shape(s), operand names, attributes
 * dot dimension numbers, slice/dus info, collective metadata
 * while-loop trip counts (recovered from the condition's constants —
   XLA's HloCostAnalysis visits loop bodies ONCE, which under-counts a
   scanned 80-layer model by 80x; we re-multiply)
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.utils.hw import dtype_bytes

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\(.*?\)|[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?|[a-z][a-z0-9]*\[\])\s*"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_CALL = re.compile(r"(calls|body|condition|to_apply)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


@dataclasses.dataclass
class Shape:
    """One HLO array shape: element dtype string + dimension tuple."""

    dtype: str
    dims: tuple

    @property
    def elems(self) -> int:
        """Total element count (1 for scalars)."""
        return int(math.prod(self.dims)) if self.dims else 1

    @property
    def bytes(self) -> int:
        """Unpadded byte size (elements x dtype width)."""
        return self.elems * dtype_bytes(self.dtype)


@dataclasses.dataclass
class Instr:
    """One parsed HLO instruction (opcode, shapes, operands, attrs)."""

    name: str
    opcode: str
    shapes: list          # list[Shape] (tuple results flattened)
    operands: list        # operand instruction names
    attrs: str            # raw attribute text
    is_root: bool = False

    @property
    def shape(self) -> Shape:
        """The primary (first) result shape."""
        return self.shapes[0]

    def attr_comp(self, key: str) -> str | None:
        """Name of the computation referenced by a calls/body/condition/
        to_apply attribute, or None if the attribute is absent."""
        for k, v in _ATTR_CALL.findall(self.attrs):
            if k == key:
                return v
        return None

    def attr_dims(self, key: str) -> tuple:
        """Integer tuple of a ``key={1,2,...}`` attribute (() if absent)."""
        m = re.search(key + r"=\{([\d,]*)\}", self.attrs)
        if not m or not m.group(1):
            return ()
        return tuple(int(x) for x in m.group(1).split(","))


@dataclasses.dataclass
class Computation:
    """One HLO computation: a named, ordered instruction list."""

    name: str
    instrs: list
    is_entry: bool = False

    @property
    def root(self) -> Instr:
        """The ROOT instruction (falls back to the last instruction)."""
        for i in self.instrs:
            if i.is_root:
                return i
        return self.instrs[-1]

    def by_name(self) -> dict:
        """{instruction name: Instr} lookup for this computation."""
        return {i.name: i for i in self.instrs}


@dataclasses.dataclass
class HloModule:
    """A parsed HLO module: all computations plus the ENTRY one."""

    name: str
    computations: dict    # name -> Computation
    entry: Computation


def parse_shapes(text: str) -> list:
    """Parse a result type: single shape, scalar, or tuple."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(x) for x in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append(Shape(m.group(1), dims))
    if not out:
        out.append(Shape("f32", ()))
    return out


def _split_operands_attrs(rest: str) -> tuple:
    """rest starts after 'opcode(' — split balanced operand list / attrs."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str) -> HloModule:
    """Parse ``compiled.as_text()`` into an HloModule (never raises on
    unknown constructs — they degrade to generic instructions)."""
    mod_name = "unknown"
    m = re.match(r"HloModule\s+([\w\.\-]+)", text)
    if m:
        mod_name = m.group(1)
    comps: dict = {}
    entry_name = None
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            h = _COMP_HDR.match(line.strip())
            if h and line.rstrip().endswith("{"):
                cur = Computation(h.group(2), [], is_entry=bool(h.group(1)))
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            if cur.is_entry:
                entry_name = cur.name
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        root, name, typ, opcode, rest = im.groups()
        ops_text, attrs = _split_operands_attrs(rest)
        attrs = attrs.strip()
        if opcode in ("parameter", "constant", "iota"):
            operands = []
            if opcode == "parameter" and ops_text.strip().isdigit():
                attrs = f"parameter_index={ops_text.strip()} " + attrs
        else:
            operands = _OPERAND_RE.findall(ops_text)
        cur.instrs.append(Instr(
            name=name, opcode=opcode, shapes=parse_shapes(typ),
            operands=operands, attrs=attrs, is_root=bool(root)))
    if entry_name is None:
        # fall back: biggest computation
        entry_name = max(comps, key=lambda c: len(comps[c].instrs))
    return HloModule(mod_name, comps, comps[entry_name])


_TRIP_RE = re.compile(r'known_trip_count\\?"\s*:\s*\{\\?"n\\?":\\?"(\d+)')


def while_trip_count(mod: HloModule, wh: Instr, trips: dict) -> int:
    """Trip count of a while instruction.

    Primary source: XLA's own ``backend_config known_trip_count``
    annotation on the instruction. Fallback: largest small integer in the
    condition computation (heuristic, capped — vocab-sized constants in
    gather/sort conditions must not masquerade as trip counts)."""
    m = _TRIP_RE.search(wh.attrs)
    if m:
        return int(m.group(1))
    cond_name = wh.attr_comp("condition")
    if cond_name and cond_name in trips:
        t = trips[cond_name]
        if t <= 8192:           # cap the heuristic (layer/chunk scans)
            return t
    return 1


def trip_counts_from_text(text: str) -> dict:
    """Map condition-computation name -> trip count, straight from text."""
    trips: dict = {}
    cur = None
    for line in text.splitlines():
        h = _COMP_HDR.match(line.strip())
        if h and line.rstrip().endswith("{"):
            cur = h.group(2)
            continue
        if cur is None:
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        m = _CONST_INT.search(line)
        if m:
            v = int(m.group(1))
            if 1 < v <= 10_000_000:
                trips[cur] = max(trips.get(cur, 1), v)
    return trips
