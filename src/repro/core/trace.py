"""Machine-independent µ-op trace IR.

``lower()`` turns a parsed HLO module into a :class:`Trace`: a tree of
:class:`TraceRegion`\\ s (the entry computation, inlined fusion/call
bodies, and ``while`` loop bodies) whose :class:`TraceOp` records carry
everything the scheduling backends need and nothing machine-specific:

 * the µ-op decomposition (``isa.decompose``: class + unit counts),
 * dependency edges (operand names, region-local),
 * the latency *class* (the machine file supplies the actual cycles),
 * boundary HBM traffic per op (slice-capped, fusion-projected — the
   byte math of the old monolithic analyzer, verbatim),
 * loop structure with resolved trip counts.

Lowering runs **once per module**: a registry-wide fan-out used to
re-parse and re-decompose the same HLO once per machine; now every
``(machine, backend)`` pair replays one shared trace
(`core/backends/`). The traversal order of ``lower`` deliberately
mirrors the old ``Analyzer._comp`` recursion so the default TP-bound
backend reproduces the pre-refactor reports bit-for-bit
(tests/test_golden_compare.py).
"""

from __future__ import annotations

import dataclasses
import re

from repro.core import isa
from repro.core.hloparse import (Computation, HloModule, Instr,
                                 parse_hlo, trip_counts_from_text,
                                 while_trip_count)

#: opcodes whose HBM traffic is the slice, not the sliced operand
SLICE_LIKE = frozenset({"slice", "dynamic-slice", "gather"})
#: ops XLA:TPU fuses into consumers (their edges can stay in VMEM)
FUSIBLE = frozenset({"fusion", "reduce", "broadcast", "transpose",
                     "copy", "convert", "reshape", "bitcast"}) | \
    isa.CHEAP_EW | isa.XLU_OPS | isa.DIV_OPS


def params_in_order(comp: Computation) -> list:
    """Parameter instructions sorted by their declared parameter index
    (HLO text lists them in dataflow order, not index order)."""
    ps = [i for i in comp.instrs if i.opcode == "parameter"]

    def key(i):
        m = re.search(r"parameter_index=(\d+)", i.attrs)
        return int(m.group(1)) if m else 1 << 30
    return sorted(ps, key=key)


@dataclasses.dataclass
class TraceOp:
    """One trace record: a decomposed instruction, an inlined call-like
    region, a loop region, or an alias-elided carry copy."""

    name: str
    opcode: str
    kind: str = "op"              # "op" | "inline" | "loop" | "elided"
    uops: tuple = ()              # ((µ-op class, units), ...)
    deps: tuple = ()              # operand names (region-local)
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_kind: str = ""
    unknown: bool = False
    free: bool = False            # FREE_OPS: zero latency base
    lat_cls: str | None = None    # latency class (None: while/fusion)
    # boundary HBM bytes (slice-capped); None when outside a boundary
    # region or for while/free ops — backends must distinguish "no
    # boundary here" from a genuine 0-byte boundary (fused edge)
    dma_bytes: float | None = None
    region: "TraceRegion | None" = None   # inline / loop body
    trips: int = 1                # loop trip count
    # inline only: body parameter name -> outer operand name, and the
    # body root's name — lets a flattening backend (mca_sched) stitch
    # dependency edges across the call boundary
    param_map: dict | None = None
    root_name: str | None = None


@dataclasses.dataclass
class TraceRegion:
    """An ordered op list lowered from one computation visit.

    ``boundary`` marks regions whose op results cross the HBM boundary
    (the entry computation and ``while`` bodies); inlined fusion/call
    bodies stay in VMEM and carry no per-op traffic.
    """

    name: str
    boundary: bool
    ops: list

    def n_ops(self) -> int:
        """Total op records in this region and every nested region."""
        n = 0
        for op in self.ops:
            n += 1
            if op.region is not None:
                n += op.region.n_ops()
        return n


@dataclasses.dataclass
class Trace:
    """A lowered module: the entry region plus lowering metadata."""

    module_name: str
    entry: TraceRegion
    n_devices: int = 1

    def n_ops(self) -> int:
        """Total op records across the whole trace."""
        return self.entry.n_ops()


def lower_text(hlo_text: str, n_devices: int = 1) -> Trace:
    """Parse and lower one compiled HLO text."""
    return lower(parse_hlo(hlo_text), trip_counts_from_text(hlo_text),
                 n_devices)


def lower(mod: HloModule, trips: dict, n_devices: int = 1) -> Trace:
    """Lower a parsed module (with trip counts) into a Trace."""
    entry = _lower_comp(mod, mod.entry, trips, n_devices, boundary=True)
    return Trace(mod.name, entry, n_devices)


def _internal_edges(comp: Computation) -> set:
    """Values that XLA:TPU would keep in VMEM: produced by a fusible
    op with ALL consumers fusible in the same computation. The CPU
    backend (which we parse) fuses at different granularity; without
    this projection scan-body elementwise chains are charged one HBM
    round-trip per op. Diamonds (<=4 fusible consumers, e.g. the
    online-softmax p -> {sum, dot}) fuse on TPU via producer
    duplication, so they are internal too (DESIGN.md §7)."""
    cons: dict = {}
    for i in comp.instrs:
        for o in i.operands:
            cons.setdefault(o, []).append(i)
    internal = set()
    for i in comp.instrs:
        if i.opcode not in FUSIBLE or i.is_root:
            continue
        if len(i.shapes) != 1:
            continue
        cs = cons.get(i.name, [])
        if not cs or len(cs) > 4:
            continue
        # NOTE: a `dot` consumer does NOT make an edge internal — MXU
        # operands are materialized (that is exactly what the Pallas
        # flash kernel eliminates, see EXPERIMENTS.md §Perf).
        if all(c.opcode in FUSIBLE for c in cs):
            internal.add(i.name)
    return internal


def _hbm_bytes(mod, instr: Instr, shapes_of,
               internal: set = frozenset()) -> float:
    """HBM traffic of one op boundary, slice-aware: a (dynamic-)slice
    or gather reads only the slice, not its (possibly scan-stacked)
    operand; a dynamic-update-slice touches only the update region."""
    op = instr.opcode
    res = sum(s.bytes for s in instr.shapes)
    if instr.name in internal:
        res = 0.0           # stays in VMEM (fused into its consumer)
    if op == "convert":
        return 0.0          # native-bf16 projection (see fusion case)
    if op in SLICE_LIKE:
        return 2.0 * res
    if op in ("dynamic-update-slice", "scatter"):
        upd = shapes_of.get(instr.operands[1]) \
            if len(instr.operands) > 1 else None
        ub = upd.bytes if upd is not None else res
        return 2.0 * ub

    def op_bytes(opnd: str) -> float:
        if opnd in internal:
            return 0.0
        s = shapes_of.get(opnd)
        return float(s.bytes) if s is not None else 0.0

    if op == "fusion":
        body = mod.computations.get(instr.attr_comp("calls") or "")
        total = float(res)
        if body is None:
            return total + sum(op_bytes(o) for o in instr.operands)
        # fusion rooted in a dynamic-update-slice updates in place:
        # traffic = the update region, not the full carried buffer
        by_name = body.by_name()
        root = body.root
        for _ in range(4):      # unwrap trivial roots (incl. the
            # XLA:CPU float-normalization converts, DESIGN.md §7)
            if root.opcode in ("bitcast", "copy", "reshape",
                               "transpose", "convert") and root.operands:
                nxt = by_name.get(root.operands[0])
                if nxt is None:
                    break
                root = nxt
            else:
                break
        # pure dtype-convert fusion: does not exist on native-bf16 TPUs
        # (CPU backend upcasts bf16 ops to f32 and materializes copies)
        if body.root.opcode == "convert" and root.opcode == "parameter":
            return 0.0
        dus_root = False
        res_elems = sum(s.elems for s in instr.shapes)
        if root.opcode == "dynamic-update-slice" and res > 0:
            dus_root = True
            b_shapes = {i.name: i.shape for i in body.instrs}
            upd = b_shapes.get(root.operands[1]) \
                if len(root.operands) > 1 else None
            if upd is not None:
                total = 2.0 * upd.bytes
        params = params_in_order(body)
        for idx, opnd in enumerate(instr.operands):
            if dus_root:
                # in-place update fusion: any operand with the target
                # buffer's element count is a (possibly dtype-
                # normalized) version of the buffer being updated —
                # physically only the update region is touched.
                s_op = shapes_of.get(opnd)
                if s_op is not None and s_op.elems == res_elems:
                    continue
            full = op_bytes(opnd)
            pname = params[idx].name if idx < len(params) else None
            if pname is None or full == 0.0:
                total += full
                continue
            cons = [i for i in body.instrs if pname in i.operands]
            if cons and all(c.opcode in SLICE_LIKE for c in cons):
                total += sum(sum(sh.bytes for sh in c.shapes)
                             for c in cons)
            else:
                total += full
        return total
    return float(res) + sum(op_bytes(o) for o in instr.operands)


def _latency_class(instr: Instr) -> str | None:
    """The machine-file class whose latency gates this op's consumers
    (None for while/fusion, whose own body cost is the latency)."""
    if instr.opcode in ("while", "fusion"):
        return None
    return ("mxu" if instr.opcode == "dot" else
            "xlu" if instr.opcode in isa.XLU_OPS else
            "vdiv" if instr.opcode in isa.DIV_OPS else "vpu")


def _lower_comp(mod, comp: Computation, trips, n_devices: int,
                boundary: bool) -> TraceRegion:
    """Lower one computation visit, mirroring Analyzer._comp's order."""
    shapes_of = {i.name: i.shape for i in comp.instrs}
    internal = _internal_edges(comp) if boundary else frozenset()
    # union cap: N slices of one source stream the source once
    slice_budget: dict = {}
    # carry double-buffer copies feeding only the root tuple are
    # removed by XLA copy elision -> free
    n_cons: dict = {}
    for i in comp.instrs:
        for o in i.operands:
            n_cons[o] = n_cons.get(o, 0) + 1
    root = comp.root
    elided = {
        i.name for i in comp.instrs
        if i.opcode == "copy" and n_cons.get(i.name, 0) <= 1 and
        root.opcode == "tuple" and i.name in root.operands}

    ops: list = []
    for instr in comp.instrs:
        if instr.name in elided:     # alias-elided carry copy: free
            ops.append(TraceOp(instr.name, instr.opcode, kind="elided",
                               deps=tuple(instr.operands)))
            continue
        node = _lower_instr(mod, instr, shapes_of, trips, n_devices)
        if boundary and instr.opcode != "while" and \
                instr.opcode not in isa.FREE_OPS:
            b = _hbm_bytes(mod, instr, shapes_of, internal)
            if instr.opcode in SLICE_LIKE and instr.operands:
                src = instr.operands[0]
                s = shapes_of.get(src)
                if s is not None:
                    left = slice_budget.setdefault(src, float(s.bytes))
                    read = min(b / 2.0, left)
                    slice_budget[src] = left - read
                    b = read + b / 2.0        # capped read + write
            node.dma_bytes = b
        ops.append(node)
    return TraceRegion(comp.name, boundary, ops)


def _lower_instr(mod, instr: Instr, shapes_of, trips,
                 n_devices: int) -> TraceOp:
    """Lower one non-elided instruction to its TraceOp."""
    op = instr.opcode
    base = dict(name=instr.name, opcode=op, deps=tuple(instr.operands),
                free=op in isa.FREE_OPS, lat_cls=_latency_class(instr))
    if op == "fusion":
        body = mod.computations.get(instr.attr_comp("calls") or "")
        return TraceOp(kind="inline", **base,
                       **_inline_fields(mod, body, instr, trips,
                                        n_devices))
    if op == "while":
        body = mod.computations.get(instr.attr_comp("body") or "")
        n = while_trip_count(mod, instr, trips)
        region = None
        if body is not None:
            region = _lower_comp(mod, body, trips, n_devices,
                                 boundary=True)
        return TraceOp(kind="loop", region=region, trips=n, **base)
    if op in ("conditional", "call", "async-start"):
        tgt = instr.attr_comp("calls") or instr.attr_comp("to_apply")
        body = mod.computations.get(tgt or "")
        return TraceOp(kind="inline", **base,
                       **_inline_fields(mod, body, instr, trips,
                                        n_devices))
    u = isa.decompose(instr, shapes_of, n_devices)
    return TraceOp(kind="op", uops=tuple(u.uops), flops=u.flops,
                   coll_bytes=u.coll_bytes, coll_kind=u.coll_kind,
                   unknown=u.unknown, **base)


def _inline_fields(mod, body, instr: Instr, trips,
                   n_devices: int) -> dict:
    """Region + cross-boundary alias info for an inlined body."""
    if body is None:
        return dict(region=None, param_map=None, root_name=None)
    region = _lower_comp(mod, body, trips, n_devices, boundary=False)
    pmap = {}
    for idx, p in enumerate(params_in_order(body)):
        if idx < len(instr.operands):
            pmap[p.name] = instr.operands[idx]
    return dict(region=region, param_map=pmap,
                root_name=body.root.name)
