"""The paper's Fig. 3 harness: relative-prediction-error validation of the
in-core port model vs the naive baseline over 13 streaming kernels x 8
lowering variants x 4 sizes = 416 test blocks.

The paper's variants were {Armclang, GCC, oneAPI, Clang} x {-O1..-Ofast}
(416 tests, 290 unique assembly bodies); a single-compiler JAX stack
varies the *lowering* instead: dtype, chunking, loop style, donation,
strided views, Pallas-interpret. Degenerate duplicates are faithful —
the paper had them too.

RPE convention (matches the paper's histogram): rpe = (t_meas - t_pred)
/ t_meas. Positive => prediction FASTER than measurement (the lower-bound
side, right of the red line); negative => prediction slower; <= -1.0 =>
off by more than 2x (the left bucket).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baseline as baseline_lib
from repro.core import portmodel
from repro.core.ubench import calibrated_host_model, host_peaks
from repro.kernels.stream import ref as R

SIZES = {                   # streaming-regime working sets (f32 elements)
    "S": 1 << 18,           # 1 MiB
    "M": 1 << 20,           # 4 MiB
    "L": 1 << 22,           # 16 MiB
    "XL": 1 << 23,          # 32 MiB
}
# NOTE (DESIGN.md §7): the paper validates the pure in-core (L1-resident)
# bound with hardware counters and sub-microsecond timing; this container
# has neither (jax dispatch overhead ~15us). We therefore validate the
# ECM-style holistic bound max(in-core, memory) at streaming sizes — the
# downstream use the paper itself names for its model (§I.A, §II). The
# lower-bound acceptance criterion (errors right of zero) is unchanged.

VARIANTS = ("jnp", "bf16", "chunked", "unroll2", "fori", "donated",
            "reversed", "pallas")


def _dims2(n):
    rows = max(8, int(np.sqrt(n)) // 128 * 128)
    return rows, max(128, n // rows)


def _dims3(n):
    side = max(8, int(round(n ** (1 / 3))))
    return side, side, max(8, n // (side * side))


def make_inputs(kernel: str, n: int, dtype=jnp.float32):
    """Deterministic input arrays for one suite kernel at size n."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 3)
    if kernel in ("jacobi_2d5pt", "gauss_seidel_2d5pt"):
        h, w = _dims2(n)
        return (jax.random.normal(ks[0], (h, w), dtype),)
    if kernel in ("jacobi_3d7pt", "jacobi_3d11pt", "jacobi_3d27pt"):
        d, h, w = _dims3(n)
        return (jax.random.normal(ks[0], (d, h, w), dtype),)
    if kernel == "pi_integration":
        return (n,)
    vecs = {"init": 0, "copy": 1, "update": 1, "sum_reduction": 1,
            "add": 2, "stream_triad": 2, "schoenauer_triad": 3}[kernel]
    return tuple(jax.random.normal(ks[i], (n,), dtype)
                 for i in range(vecs))


def base_fn(kernel: str, n: int):
    """The reference (unjitted) callable for one suite kernel."""
    if kernel == "init":
        return lambda: R.init((n,))
    if kernel == "pi_integration":
        return lambda: R.pi_integration(n)
    return getattr(R, kernel)


def build_variant(kernel: str, variant: str, n: int):
    """Returns (jitted_fn, args) for one test block."""
    fn = base_fn(kernel, n)
    args = make_inputs(kernel, n)
    if kernel in ("init", "pi_integration"):
        args = ()

    if variant == "bf16":
        args = tuple(a.astype(jnp.bfloat16) if hasattr(a, "astype") else a
                     for a in args)
        if kernel == "init":
            return jax.jit(lambda: R.init((n,), dtype=jnp.bfloat16)), ()
    if variant == "chunked" and args and args[0].ndim == 1:
        def chunked(*xs):
            parts = [tuple(x[i::4] for x in xs) for i in range(4)]
            return jnp.concatenate([fn(*p) if not jnp.isscalar(fn(*p))
                                    else fn(*p)[None] for p in parts]) \
                if kernel != "sum_reduction" else \
                sum(fn(*p) for p in parts)
        return jax.jit(chunked), args
    if variant == "unroll2" and args and args[0].ndim == 1:
        def unroll2(*xs):
            h = xs[0].shape[0] // 2
            lo = fn(*(x[:h] for x in xs))
            hi = fn(*(x[h:] for x in xs))
            if lo.ndim == 0:
                return lo + hi
            return jnp.concatenate([lo, hi])
        return jax.jit(unroll2), args
    if variant == "fori" and args and args[0].ndim == 1:
        rows = 64
        def fori(*xs):
            xs2 = tuple(x[: (x.shape[0] // rows) * rows].reshape(rows, -1)
                        for x in xs)
            def body(i, acc):
                y = fn(*(x[i] for x in xs2))
                if y.ndim == 0:
                    return acc + y
                return jax.lax.dynamic_update_index_in_dim(acc, y, i, 0)
            y0 = fn(*(x[0] for x in xs2))
            init = (jnp.zeros((), y0.dtype) if y0.ndim == 0 else
                    jnp.zeros((rows,) + y0.shape, y0.dtype))
            return jax.lax.fori_loop(0, rows, body, init)
        return jax.jit(fori), args
    if variant == "donated" and args and kernel in ("update",):
        return jax.jit(lambda a: R.update(a), donate_argnums=(0,)), args
    if variant == "reversed" and args and args[0].ndim >= 1:
        def rev(*xs):
            out = fn(*(jnp.flip(x, axis=0) for x in xs))
            return jnp.flip(out, axis=0) if out.ndim else out
        return jax.jit(rev), args
    if variant == "pallas":
        from repro.kernels.stream import ops as K
        name = {"init": None, "pi_integration": None}.get(kernel, kernel)
        if kernel == "init":
            return jax.jit(lambda: K.init((_dims2(n)), impl="ref")), ()
        if hasattr(K, kernel):
            return jax.jit(partial(getattr(K, kernel), impl="ref")), args
    # default: plain jnp
    return jax.jit(fn), args


def measure(fn, args, reps: int = 5, inner: int = 3,
            consumes_args: bool = False) -> float:
    """Best-of-`reps` wall time of one jitted call (seconds).

    `consumes_args` handles donated buffers: they are dead after one
    call, so fresh clones are made outside the timed region and the
    inner-loop amortization is skipped.
    """
    if consumes_args:
        # donated buffers are dead after one call: re-clone outside timing
        best = float("inf")
        for _ in range(reps + 1):
            fresh = tuple(a + 0 if hasattr(a, "dtype") else a for a in args)
            jax.block_until_ready(fresh)
            t0 = time.perf_counter()
            out = fn(*fresh)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


@dataclasses.dataclass
class RpeRecord:
    """One Fig. 3 data point: measured vs per-backend predicted runtimes.

    ``t_port`` is the analytical ``tp_bound`` backend (the OSACA side of
    the paper's comparison), ``t_mca`` the ``mca_sched`` cycle simulator
    (the LLVM-MCA side), ``t_naive`` the cost_analysis roofline baseline.
    Records cached before the backend split lack ``t_mca`` and load as
    NaN (the fig3 harness re-runs them)."""

    kernel: str
    variant: str
    size: str
    t_meas: float
    t_port: float
    t_naive: float
    t_mca: float = float("nan")

    @property
    def rpe_port(self) -> float:
        """Relative prediction error of the port model (+ = under)."""
        return (self.t_meas - self.t_port) / self.t_meas

    @property
    def rpe_naive(self) -> float:
        """Relative prediction error of the naive baseline (+ = under)."""
        return (self.t_meas - self.t_naive) / self.t_meas

    @property
    def rpe_mca(self) -> float:
        """Relative prediction error of the MCA simulator (+ = under)."""
        return (self.t_meas - self.t_mca) / self.t_meas


def record_from_dict(d: dict) -> RpeRecord:
    """Rebuild a record from JSON, mapping null timings back to NaN."""
    return RpeRecord(**{k: (float("nan")
                            if v is None and k.startswith("t_") else v)
                        for k, v in d.items()})


def load_records(path: str) -> list:
    """Load cached records; a corrupt/truncated cache reads as empty
    (it is regenerable) rather than wedging every later run."""
    import json
    try:
        with open(path) as f:
            recs = [record_from_dict(d) for d in json.load(f)]
    except (json.JSONDecodeError, TypeError, KeyError):
        return []
    return [r for r in recs
            if all(isinstance(getattr(r, k), str)
                   for k in ("kernel", "variant", "size"))]


def save_records(records: list, path: str) -> None:
    """Persist records as strict JSON (non-finite floats become null).
    Writes atomically so an interrupted run cannot truncate the cache."""
    import json
    import os
    rows = []
    for r in records:
        d = dict(r.__dict__)
        for k, v in d.items():
            if isinstance(v, float) and not np.isfinite(v):
                d[k] = None
        rows.append(d)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rows, f, indent=1, allow_nan=False)
    os.replace(tmp, path)


def run_block(kernel: str, variant: str, size: str) -> RpeRecord:
    """Measure + model one (kernel, variant, size) block on the host."""
    from repro.core.ubench import tier_bw
    n = SIZES[size]
    fn, args = build_variant(kernel, variant, n)
    machine = calibrated_host_model()
    peak, bw = host_peaks()
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    t_meas = measure(fn, args, consumes_args=(variant == "donated"))
    text = compiled.as_text()
    # one mca_sched report carries BOTH predictions: the simulator runs
    # the analytic walk first and keeps its TP/LCD fields intact
    # (pinned equal to a tp_bound run by tests/test_trace_backends.py),
    # so fig3 pays one trace walk + one simulation per block, not two
    # walks.
    rep = portmodel.analyze(text, machine, backend="mca_sched")
    # ECM bound: in-core TP/LCD + memory term at the working set's tier
    ws = sum(4 * (a.size if hasattr(a, "size") else 1) for a in args) or 4 * n
    t_mem = rep.bytes_hbm / tier_bw(float(ws))
    t_incore_tp = max(rep.tp_incore_cycles,
                      rep.serial_cycles) / machine.clock_hz
    t_port = max(t_incore_tp, t_mem)
    t_mca = max(rep.seconds_incore(machine), t_mem)
    ca = compiled.cost_analysis()   # predict() normalizes old-jax lists
    t_naive = baseline_lib.predict(ca, machine, peak, bw).seconds
    return RpeRecord(kernel, variant, size, t_meas, t_port, t_naive,
                     t_mca)


def run_suite(kernels=None, variants=VARIANTS, sizes=tuple(SIZES),
              progress=None) -> list:
    """Run the whole Fig. 3 grid; failures become NaN records."""
    kernels = kernels or R.KERNELS_13
    out = []
    for k in kernels:
        for v in variants:
            for s in sizes:
                try:
                    out.append(run_block(k, v, s))
                except Exception as e:  # noqa: BLE001 — suite must finish
                    out.append(RpeRecord(k, v, s, float("nan"),
                                         float("nan"), float("nan")))
                if progress:
                    progress(out[-1])
    return out


def summarize(records: list) -> dict:
    """Fig. 3 summary stats per prediction engine (NaN-safe).

    Keys: ``port_model`` (tp_bound backend), ``mca_sched`` (cycle
    simulator backend), ``naive_baseline`` (cost_analysis roofline).
    Non-finite RPEs (failed blocks, legacy caches without ``t_mca``)
    are filtered per engine before any mean, so one NaN record cannot
    poison a summary (see DESIGN.md §7)."""
    def stats(rpes):
        r = np.array([x for x in rpes if np.isfinite(x)])
        if r.size == 0:
            return {}
        return {
            "n": int(r.size),
            "right_of_zero_pct": float((r >= 0).mean() * 100),
            "within10_pct": float(((r >= 0) & (r < 0.10)).mean() * 100),
            "within20_pct": float(((r >= 0) & (r < 0.20)).mean() * 100),
            "abs_within10_pct": float((np.abs(r) < 0.10).mean() * 100),
            "factor2_off": int((r <= -1.0).sum()),
            "mean_rpe": float(r.mean()),
            "mean_underpred_rpe": float(r[r >= 0].mean()) if (r >= 0).any()
            else float("nan"),
            "mean_abs_rpe": float(np.abs(r).mean()),
        }
    return {
        "port_model": stats([x.rpe_port for x in records]),
        "mca_sched": stats([x.rpe_mca for x in records]),
        "naive_baseline": stats([x.rpe_naive for x in records]),
        "n_blocks": len(records),
    }


_HIST_WHICH = {"port": "rpe_port", "mca": "rpe_mca", "naive": "rpe_naive"}


def histogram(records: list, which: str = "port", width: float = 0.10):
    """Bucketized RPE histogram (paper Fig. 3 bars) for one engine
    (``port`` / ``mca`` / ``naive``)."""
    vals = [getattr(r, _HIST_WHICH.get(which, "rpe_naive"))
            for r in records]
    vals = [v for v in vals if np.isfinite(v)]
    buckets: dict = {}
    for v in vals:
        if v <= -1.0:
            key = "<=-1.0"
        else:
            b = np.floor(v / width) * width
            key = f"{b:+.1f}"
        buckets[key] = buckets.get(key, 0) + 1
    return dict(sorted(buckets.items()))
