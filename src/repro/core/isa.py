"""HLO opcode -> TPU µ-op decomposition (the paper's instruction tables).

Each HLO instruction becomes a list of (µ-op class, units) pairs against
the machine model's port table, plus flop/byte side accounting. Unknown
opcodes degrade to VPU-class elementwise with a warning counter — never a
crash (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.core.hloparse import Instr

VPU_BLOCK = 8 * 128      # elements per (8,128) vector register block

# Every machine file must provide an OpEntry for each of these classes —
# repro.core.machine.register() validates completeness against this tuple.
# (`gather4`/`sc` have universal fallbacks but all shipped models define
# them explicitly; `ici` doubles as the cross-socket/C2C class on CPUs.)
UOP_CLASSES = ("mxu", "vpu", "xlu", "vdiv", "vlsu", "gather4", "sc",
               "dma", "ici")

XLU_OPS = {
    "exponential", "exponential-minus-one", "log", "log-plus-one",
    "logistic", "tanh", "tan", "sine", "cosine", "rsqrt", "sqrt", "cbrt",
    "power", "atan2", "erf", "rng", "rng-bit-generator",
    "rng-get-and-update-state",
}
DIV_OPS = {"divide", "remainder"}
CHEAP_EW = {
    "add", "subtract", "multiply", "maximum", "minimum", "abs", "negate",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "compare", "select", "and", "or", "xor", "not", "clamp", "convert",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "popcnt", "clz", "is-finite", "stochastic-convert", "real", "imag",
    "atan", "expm1", "log1p",
}
DATA_MOVE = {
    "copy", "broadcast", "reshape", "transpose", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "gather",
    "scatter", "iota", "copy-start", "copy-done", "reduce-window",
    "select-and-scatter", "sort", "map", "set-dimension-size",
}
FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "domain", "opt-barrier",
    "get-dimension-size", "partition-id", "replica-id", "token",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "all-reduce-done", "all-gather-done",
    "collective-permute-done",
}


@dataclasses.dataclass
class Uops:
    """Decomposition result + side accounting for one instruction."""
    uops: list            # [(class, units)]
    flops: float = 0.0
    bytes_hbm: float = 0.0
    coll_bytes: float = 0.0
    coll_kind: str = ""
    unknown: bool = False


def _dot_mnkb(instr: Instr, shapes_of: dict) -> tuple:
    """(B, M, N, K) for a dot from operand shapes + dim numbers."""
    lhs = shapes_of.get(instr.operands[0]) if instr.operands else None
    rhs = shapes_of.get(instr.operands[1]) if len(instr.operands) > 1 else None
    if lhs is None or rhs is None:
        # fall back: assume square-ish from output
        e = instr.shape.elems
        s = max(1.0, e ** 0.5)
        return 1, s, s, s
    lc = set(instr.attr_dims("lhs_contracting_dims"))
    rc = set(instr.attr_dims("rhs_contracting_dims"))
    lb = set(instr.attr_dims("lhs_batch_dims"))
    rb = set(instr.attr_dims("rhs_batch_dims"))
    if not lc:
        lc = {len(lhs.dims) - 1} if lhs.dims else set()
    if not rc:
        rc = {0} if rhs.dims else set()
    bsz = math.prod(lhs.dims[i] for i in lb) if lb else 1
    k = math.prod(lhs.dims[i] for i in lc) if lc else 1
    m = math.prod(d for i, d in enumerate(lhs.dims) if i not in lc | lb)
    n = math.prod(d for i, d in enumerate(rhs.dims) if i not in rc | rb)
    return bsz, max(1, m), max(1, n), max(1, k)


def _group_size(instr: Instr, n_devices: int) -> int:
    """Participants per replica group of a collective."""
    a = instr.attrs
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", a)
    if m:                      # iota format [G,S]<=[N]...: S per group
        return max(1, int(m.group(2)))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", a)
    if m:
        return max(1, len(m.group(1).split(",")))
    return n_devices


def _vpu_blocks(elems: int) -> float:
    return max(1.0, math.ceil(elems / VPU_BLOCK))


def operand_bytes(instr: Instr, shapes_of: dict) -> float:
    """Total byte size of an instruction's resolvable operands."""
    tot = 0.0
    for op in instr.operands:
        s = shapes_of.get(op)
        if s is not None:
            tot += s.bytes
    return tot


def decompose(instr: Instr, shapes_of: dict, n_devices: int = 1) -> Uops:
    """µ-ops for one (non-fusion, non-control-flow) instruction."""
    op = instr.opcode
    out = instr.shape
    e = sum(s.elems for s in instr.shapes)

    if op in FREE_OPS:
        return Uops([("sc", 1)])

    if op == "dot":
        bsz, m, n, k = _dot_mnkb(instr, shapes_of)
        passes = bsz * math.ceil(m / 128) * math.ceil(n / 128) * \
            math.ceil(k / 128)
        return Uops([("mxu", passes)], flops=2.0 * bsz * m * n * k)

    if op == "convolution":
        # flops from out elems x kernel size (approx); map to MXU passes
        kb = shapes_of.get(instr.operands[1]) \
            if len(instr.operands) > 1 else None
        ksize = kb.elems if kb is not None else 9
        flops = 2.0 * e * ksize
        passes = max(1.0, flops / (2 * 128 ** 3))
        return Uops([("mxu", passes)], flops=flops)

    if op in ("reduce", "reduce-precision"):
        src = shapes_of.get(instr.operands[0]) if instr.operands else None
        n_in = src.elems if src is not None else e
        return Uops([("vpu", 2 * _vpu_blocks(n_in))], flops=float(n_in))

    if op in COLLECTIVES:
        base = op.replace("-start", "").replace("-done", "")
        if op.endswith("-done"):
            return Uops([("sc", 1)])
        g = _group_size(instr, n_devices)
        payload = sum(s.bytes for s in instr.shapes)
        if base == "all-reduce":
            wire = 2.0 * (g - 1) / g * payload
        elif base in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = (g - 1) / g * payload
        else:                  # collective-permute
            wire = float(payload)
        u = [("ici", wire)]
        if base in ("all-reduce", "reduce-scatter"):
            u.append(("vpu", _vpu_blocks(e)))
        return Uops(u, coll_bytes=wire, coll_kind=base)

    if op in XLU_OPS:
        return Uops([("xlu", _vpu_blocks(e))], flops=float(e))

    if op in DIV_OPS:
        return Uops([("vdiv", _vpu_blocks(e))], flops=float(e))

    if op in CHEAP_EW:
        return Uops([("vpu", _vpu_blocks(e))], flops=float(e))

    if op in ("gather", "scatter"):
        if op == "scatter" and len(instr.operands) > 2:
            upd = shapes_of.get(instr.operands[2])
            if upd is not None:
                e = upd.elems
        return Uops([("gather4", _vpu_blocks(e))])

    if op == "dynamic-update-slice":
        # work scales with the UPDATE region, not the full buffer
        upd = shapes_of.get(instr.operands[1]) \
            if len(instr.operands) > 1 else None
        ue = upd.elems if upd is not None else e
        return Uops([("vlsu", _vpu_blocks(ue))])

    if op in DATA_MOVE:
        return Uops([("vlsu", _vpu_blocks(e))])

    if op == "custom-call":
        tgt = ""
        m = re.search(r'custom_call_target="([^"]+)"', instr.attrs)
        if m:
            tgt = m.group(1).lower()
        if "matmul" in tgt or "dot" in tgt or "gemm" in tgt:
            bsz, mm, nn, kk = _dot_mnkb(instr, shapes_of)
            passes = bsz * math.ceil(mm / 128) * math.ceil(nn / 128) * \
                math.ceil(kk / 128)
            return Uops([("mxu", passes)], flops=2.0 * bsz * mm * nn * kk)
        if "topk" in tgt or "sort" in tgt:
            return Uops([("vlsu", 4 * _vpu_blocks(e))])
        return Uops([("vpu", _vpu_blocks(e))], unknown=True)

    # unknown opcode: degrade to elementwise
    return Uops([("vpu", _vpu_blocks(e))], flops=float(e), unknown=True)
