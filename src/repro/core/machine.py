"""In-core machine models — the TPU analogue of the paper's Table II.

A :class:`MachineModel` is the OSACA "machine file": a set of ports
(functional-unit groups visible to the scheduler) plus, per µ-op class,
which ports may execute it, how many cycles one *unit* of work occupies a
port, and the result latency (for CP/LCD analysis).

µ-op classes (units in parentheses):
  mxu      — one 128x128x128 systolic pass (unit = pass, 128 cy/port)
  vpu      — elementwise vector op (unit = one (8,128) register block)
  xlu      — transcendental (exp/log/tanh/...) — multi-cycle VPU-class
  vdiv     — vector divide/sqrt (slowest VPU-class, mirrors paper Table III)
  vlsu     — VMEM load/store/shuffle (unit = (8,128) block moved)
  sc       — scalar core op (loop bookkeeping, unit = 1 op)
  dma      — HBM<->VMEM transfer (unit = byte)
  ici      — inter-chip transfer (unit = byte)

Three shipped TPU generations mirror the paper's three CPUs; `host_cpu`
is calibrated at runtime by repro.core.ubench (the paper's
microbenchmark-driven entries).
"""

from __future__ import annotations

import dataclasses
import math

from repro.utils.hw import CHIPS, ChipSpec


@dataclasses.dataclass(frozen=True)
class OpEntry:
    ports: tuple          # which ports can execute this µ-op class
    cycles_per_unit: float
    latency: float        # cycles until result usable


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    clock_hz: float
    ports: tuple
    table: dict           # class name -> OpEntry
    chip: ChipSpec | None = None
    # paper-style metadata (Table II row)
    simd_width_bytes: int = 0
    notes: str = ""

    def entry(self, cls: str) -> OpEntry:
        return self.table[cls]

    def seconds(self, cycles: float) -> float:
        return cycles / self.clock_hz


def _tpu_model(chip: ChipSpec, mxu_lat: float = 192.0) -> MachineModel:
    mxus = tuple(f"MXU{i}" for i in range(chip.n_mxu))
    vpus = tuple(f"VPU{i}" for i in range(chip.n_vpu))
    vlsus = ("VLSU0", "VLSU1")
    dmas = ("DMA0", "DMA1")
    icis = ("ICI",)
    sc = ("SC",)
    bytes_per_cy = chip.hbm_bw / chip.clock_hz          # both DMA queues
    ici_bytes_per_cy = chip.ici_link_bw * chip.ici_links / chip.clock_hz
    table = {
        # one pass = stream 128 rows through the 128x128 array
        "mxu": OpEntry(mxus, 128.0, mxu_lat),
        "vpu": OpEntry(vpus, 1.0, 4.0),      # one (8,128) block per cy/port
        "xlu": OpEntry(vpus, 4.0, 12.0),     # transcendental ~1/4 rate
        "vdiv": OpEntry(vpus, 8.0, 24.0),
        "vlsu": OpEntry(vlsus, 1.0, 6.0),    # (8,128) block load/store
        "gather4": OpEntry(vlsus, 4.0, 12.0),  # random-index gather
        "sc": OpEntry(sc, 1.0, 1.0),
        "dma": OpEntry(dmas, 2.0 / bytes_per_cy, 500.0),   # per byte, split 2q
        "ici": OpEntry(icis, 1.0 / ici_bytes_per_cy, 2000.0),
    }
    return MachineModel(
        name=chip.name, clock_hz=chip.clock_hz,
        ports=mxus + vpus + vlsus + dmas + icis + sc, table=table, chip=chip,
        simd_width_bytes=8 * 128 * 4,
        notes=f"{chip.n_mxu} MXU / {chip.n_vpu} VPU lanesets, "
              f"{chip.hbm_bw/1e9:.0f} GB/s HBM")


TPU_V5E = _tpu_model(CHIPS["tpu_v5e"])
TPU_V5P = _tpu_model(CHIPS["tpu_v5p"])
TPU_V4 = _tpu_model(CHIPS["tpu_v4"])

MACHINES = {m.name: m for m in (TPU_V5E, TPU_V5P, TPU_V4)}


def host_cpu_model(calib: dict | None = None) -> MachineModel:
    """Host-CPU machine model; entries overridden by ubench calibration.

    Units are normalized to a nominal 1 GHz clock so `cycles` == ns; the
    calibration dict maps class -> units/second measured on this host.
    """
    clock = 1e9
    default_rates = {           # units/s, conservative one-core defaults
        "mxu": 2.0e7,           # ~84 GFLOP/s f32 matmul
        "vpu": 1.2e9,           # (8,128)-blocks/s ~ 1.2e12 elem-ops/s? no:
                                # 1024 elems/block -> ~1.2e12 elem/s is too
                                # high for 1 core; calibration will fix.
        "xlu": 1.5e8,
        "vdiv": 2.0e8,
        "vlsu": 1.0e9,
        "gather4": 2.5e8,
        "sc": 1.0e9,
        "dma": 2.0e10,          # bytes/s main-memory stream
        "ici": 1.0e10,
    }
    if calib:
        default_rates.update(calib)
    ports = ("P0", "MEM")       # one compute pipe + one memory pipe
    table = {cls: OpEntry(("MEM",) if cls in ("dma", "ici") else ("P0",),
                          clock / rate, 4.0)
             for cls, rate in default_rates.items()}
    return MachineModel(name="host_cpu", clock_hz=clock, ports=ports,
                        table=table, notes="ubench-calibrated host model")
