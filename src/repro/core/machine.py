"""Cross-vendor machine models — the paper's Table II as machine files.

A :class:`MachineModel` is the OSACA "machine file": a set of ports
(functional-unit groups visible to the scheduler) plus, per µ-op class,
which ports may execute it, how many cycles one *unit* of work occupies
the port group, and the result latency (for CP/LCD analysis). Port sets
may be asymmetric per class (e.g. `vdiv` pinned to one divider pipe) and
weighted per port (`OpEntry.port_weights`) to express per-port issue
widths — see DESIGN.md §4.

µ-op classes (units in parentheses, canonical list in isa.UOP_CLASSES):
  mxu      — one 128x128x128 matmul pass (TPU: systolic pass; CPU: the
             FMA-pipe pair executing the equivalent FMA stream)
  vpu      — elementwise vector op (unit = one (8,128) register block)
  xlu      — transcendental (exp/log/tanh/...) — multi-cycle VPU-class
  vdiv     — vector divide/sqrt (slowest VPU-class, paper Table III)
  vlsu     — load/store/shuffle (unit = (8,128) block moved)
  sc       — scalar op (loop bookkeeping, unit = 1 op)
  dma      — off-core memory transfer (unit = byte; HBM or DDR/LPDDR)
  ici      — inter-chip/cross-socket transfer (unit = byte)

Shipped machines: three TPU generations (spec-derived), the paper's three
CPUs (`zen4`, `golden_cove`, `neoverse_v2` — Table II ports, Table III
latencies mapped onto the µ-op classes), and `host_cpu` (calibrated at
runtime by repro.core.ubench, which registers it here). Each machine is
tagged with its write-allocate mode so repro.core.wa selects the Fig. 4
behavioural mode per machine.
"""

from __future__ import annotations

import dataclasses

from repro.core import isa
from repro.utils.hw import CHIPS, CPU_CHIPS, ChipSpec, CpuSpec

#: f32 bytes in one vpu/vlsu unit — the (8,128) register block.
BLOCK_BYTES = 8 * 128 * 4
#: multiply-accumulates in one mxu unit — a 128x128x128 pass.
PASS_MACS = 128 ** 3


@dataclasses.dataclass(frozen=True)
class OpEntry:
    """Machine-file row for one µ-op class: ports, throughput, latency."""

    ports: tuple          # which ports can execute this µ-op class
    cycles_per_unit: float
    latency: float        # cycles until result usable
    # relative issue capacity of each admissible port (None = symmetric).
    # Expresses per-port issue widths: e.g. store pipes that absorb only
    # the store share of `vlsu` traffic get a smaller weight.
    port_weights: tuple | None = None


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """An OSACA-style machine file: ports, µ-op table, WA mode, memory
    ladder (see the module docstring and DESIGN.md §4)."""

    name: str
    clock_hz: float
    ports: tuple
    table: dict           # class name -> OpEntry
    chip: ChipSpec | None = None
    # paper-style metadata (Table II row)
    simd_width_bytes: int = 0
    notes: str = ""
    vendor: str = ""
    isa_name: str = ""
    issue_width: int = 0          # front-end µops/cycle (0 = unmodeled)
    wa_mode: str = "auto_claim"   # write-allocate behaviour (core/wa.py)
    # memory hierarchy (ECM ladder, innermost first — core/memtier.py)
    mem_tiers: tuple = ()
    cores: int = 1                # cores per socket driving shared tiers

    def entry(self, cls: str) -> OpEntry:
        """The OpEntry of one µ-op class."""
        return self.table[cls]

    def seconds(self, cycles: float) -> float:
        """Convert a cycle count to seconds at this machine's clock."""
        return cycles / self.clock_hz


# --- registry ---------------------------------------------------------------

#: name -> MachineModel. Mutated only through register(); kept as a plain
#: dict under its historical name so existing call sites keep working.
MACHINES: dict = {}

_WA_MODES = ("auto_claim", "saturation_gated", "explicit_only")


class MachineValidationError(ValueError):
    """A machine file failed `validate_model`'s sanity checks."""


def validate_model(model: MachineModel) -> None:
    """A machine file must cover every µ-op class with sane numbers."""
    known = set(model.ports)
    for cls in isa.UOP_CLASSES:
        e = model.table.get(cls)
        if e is None:
            raise MachineValidationError(
                f"{model.name}: missing µ-op class {cls!r}")
        if not e.ports:
            raise MachineValidationError(
                f"{model.name}/{cls}: empty port set")
        if not set(e.ports) <= known:
            raise MachineValidationError(
                f"{model.name}/{cls}: ports {set(e.ports) - known} not "
                f"declared in machine.ports")
        if not e.cycles_per_unit > 0:
            raise MachineValidationError(
                f"{model.name}/{cls}: cycles_per_unit must be > 0")
        if e.latency < 0:
            raise MachineValidationError(
                f"{model.name}/{cls}: negative latency")
        if e.port_weights is not None:
            if len(e.port_weights) != len(e.ports):
                raise MachineValidationError(
                    f"{model.name}/{cls}: {len(e.port_weights)} weights "
                    f"for {len(e.ports)} ports")
            if any(w <= 0 for w in e.port_weights):
                raise MachineValidationError(
                    f"{model.name}/{cls}: non-positive port weight")
    if model.wa_mode not in _WA_MODES:
        raise MachineValidationError(
            f"{model.name}: unknown wa_mode {model.wa_mode!r} "
            f"(expected one of {_WA_MODES})")
    if not model.clock_hz > 0:
        raise MachineValidationError(f"{model.name}: clock_hz must be > 0")
    prev_cap = 0.0
    for t in model.mem_tiers:
        if t.capacity_bytes < 0:
            raise MachineValidationError(
                f"{model.name}/{t.name}: negative tier capacity")
        if t.capacity_bytes > 0:        # zero-capacity = disabled level
            if t.capacity_bytes < prev_cap:
                raise MachineValidationError(
                    f"{model.name}/{t.name}: tier capacities must be "
                    f"non-decreasing outward")
            prev_cap = t.capacity_bytes
        if not (t.load_bw > 0 and t.store_bw > 0):
            raise MachineValidationError(
                f"{model.name}/{t.name}: tier bandwidths must be > 0")
        if t.shared_bw < 0:
            raise MachineValidationError(
                f"{model.name}/{t.name}: negative shared_bw")
        if not 0.0 <= t.wa_residue <= 1.0:
            raise MachineValidationError(
                f"{model.name}/{t.name}: wa_residue must be in [0, 1]")
    if model.mem_tiers and \
            model.mem_tiers[-1].capacity_bytes != float("inf"):
        raise MachineValidationError(
            f"{model.name}: outermost tier must have infinite capacity "
            f"(the backing DRAM/HBM level)")


def register(model: MachineModel, *, replace: bool = False) -> MachineModel:
    """Validate and add a machine to the registry; returns the model."""
    validate_model(model)
    if model.name in MACHINES and not replace:
        raise ValueError(f"machine {model.name!r} already registered "
                         f"(pass replace=True to recalibrate)")
    MACHINES[model.name] = model
    return model


def get_machine(machine) -> MachineModel:
    """Resolve a machine by name or pass a MachineModel through."""
    if isinstance(machine, MachineModel):
        return machine
    try:
        return MACHINES[machine]
    except KeyError:
        raise KeyError(f"unknown machine {machine!r}; registered: "
                       f"{sorted(MACHINES)}") from None


def registered_names() -> tuple:
    """Names of every registered machine, in registration order."""
    return tuple(MACHINES)


def registered_models() -> tuple:
    """Every registered MachineModel, in registration order."""
    return tuple(MACHINES.values())


def machine_fingerprint(machine) -> str:
    """Content hash of one machine file (name or model).

    Stable across processes for identically-built models: the hash
    covers the full dataclass repr — ports, µ-op table, WA mode,
    memory ladder, core count. Two registrations of the *same name*
    with different specs (ubench recalibration, test re-registration)
    therefore fingerprint differently, which is what lets plan caches
    and the persisted plan DB (repro.serve.plandb) key on machine
    *content* instead of machine *names*.
    """
    import hashlib
    m = get_machine(machine)
    return hashlib.sha256(repr(m).encode()).hexdigest()[:16]


def registry_fingerprint() -> tuple:
    """(name, content-hash) pairs of the whole registry, in order.

    The plan memo (repro.serve.planner) and the tile autotuner
    (repro.kernels.tuning) key on this instead of the bare name tuple:
    re-registering a machine under an existing name (``replace=True``)
    changes the fingerprint, so a plan priced against the old spec can
    never be served after a recalibration.
    """
    return tuple((name, machine_fingerprint(m))
                 for name, m in MACHINES.items())


# --- TPU machine files ------------------------------------------------------

def _tpu_model(chip: ChipSpec, mxu_lat: float = 192.0) -> MachineModel:
    mxus = tuple(f"MXU{i}" for i in range(chip.n_mxu))
    vpus = tuple(f"VPU{i}" for i in range(chip.n_vpu))
    vlsus = ("VLSU0", "VLSU1")
    dmas = ("DMA0", "DMA1")
    icis = ("ICI",)
    sc = ("SC",)
    bytes_per_cy = chip.hbm_bw / chip.clock_hz          # both DMA queues
    ici_bytes_per_cy = chip.ici_link_bw * chip.ici_links / chip.clock_hz
    table = {
        # one pass = stream 128 rows through the 128x128 array
        "mxu": OpEntry(mxus, 128.0, mxu_lat),
        "vpu": OpEntry(vpus, 1.0, 4.0),      # one (8,128) block per cy/port
        "xlu": OpEntry(vpus, 4.0, 12.0),     # transcendental ~1/4 rate
        "vdiv": OpEntry(vpus, 8.0, 24.0),
        "vlsu": OpEntry(vlsus, 1.0, 6.0),    # (8,128) block load/store
        "gather4": OpEntry(vlsus, 4.0, 12.0),  # random-index gather
        "sc": OpEntry(sc, 1.0, 1.0),
        "dma": OpEntry(dmas, 2.0 / bytes_per_cy, 500.0),   # per byte, 2q
        "ici": OpEntry(icis, 1.0 / ici_bytes_per_cy, 2000.0),
    }
    return MachineModel(
        name=chip.name, clock_hz=chip.clock_hz,
        ports=mxus + vpus + vlsus + dmas + icis + sc, table=table, chip=chip,
        simd_width_bytes=BLOCK_BYTES, vendor="Google", isa_name="TPU",
        issue_width=0, wa_mode="auto_claim",
        mem_tiers=tuple(chip.mem_tiers), cores=1,
        notes=f"{chip.n_mxu} MXU / {chip.n_vpu} VPU lanesets, "
              f"{chip.hbm_bw/1e9:.0f} GB/s HBM")


# --- CPU machine files (paper Table II / Table III) -------------------------

def _cpu_ports(spec: CpuSpec) -> dict:
    """Scheduler-visible port groups for one paper CPU."""
    simd = tuple(f"FP{i}" for i in range(spec.n_simd))
    loads = tuple(f"LD{i}" for i in range(spec.n_load))
    stores = tuple(f"ST{i}" for i in range(spec.n_store))
    return {
        "fma": simd[:spec.n_fma],   # FMA-capable subset (the mxu pair)
        "simd": simd,
        "div": simd[:1],            # divider lives on the first FP pipe
        "load": loads,
        "store": stores,
        "alu": ("ALU",),
        "mem": ("MEM",),            # off-core memory interface
        "xs": ("ICI",),             # cross-socket / C2C link
    }


def _cpu_model(spec: CpuSpec) -> MachineModel:
    """Map a paper CPU onto the µ-op classes.

    Units stay TPU-shaped so one HLO analysis is comparable across
    vendors: a `vpu` unit is one (8,128) f32 block (4 KiB of lanes), an
    `mxu` unit is one 128^3 pass. Per class, `cycles_per_unit` is the
    total port-group occupation of one unit assuming one full-width op
    per port per cycle — the Table III reciprocal-throughput model.
    """
    p = _cpu_ports(spec)
    # full-width vector ops needed to touch one (8,128) f32 block
    vec_ops = BLOCK_BYTES / spec.simd_width_bytes
    # FMAs for one 128^3 pass at simd_width/4 f32 lanes per FMA
    fma_ops = PASS_MACS / (spec.simd_width_bytes / 4)
    # loads are ~2 of every 3 accesses in streaming code; store pipes
    # only absorb the store share -> weight them at half a load pipe
    ls_weights = (1.0,) * spec.n_load + (0.5,) * spec.n_store
    cy_per_byte = spec.clock_hz / spec.mem_bw
    mem_lat_cy = 100e-9 * spec.clock_hz        # ~100 ns DRAM latency
    table = {
        "mxu": OpEntry(p["fma"], fma_ops, spec.fma_latency),
        "vpu": OpEntry(p["simd"], vec_ops, spec.fma_latency),
        # vectorized transcendental: ~8-term polynomial of FMA-class ops
        "xlu": OpEntry(p["simd"], 8.0 * vec_ops, 8.0 * spec.fma_latency),
        # divider: single pipe, barely pipelined (Table III)
        "vdiv": OpEntry(p["div"], spec.fdiv_recip_tput * vec_ops,
                        spec.fdiv_latency),
        "vlsu": OpEntry(p["load"] + p["store"], vec_ops, spec.load_latency,
                        port_weights=ls_weights),
        # gathers crack into scalar-ish loads: ~4x block cost, loads only
        "gather4": OpEntry(p["load"], 4.0 * vec_ops,
                           2.0 * spec.load_latency),
        "sc": OpEntry(p["alu"], 1.0, 1.0),
        "dma": OpEntry(p["mem"], cy_per_byte, mem_lat_cy),
        "ici": OpEntry(p["xs"], spec.clock_hz / spec.xsocket_bw,
                       4.0 * mem_lat_cy),
    }
    all_ports = (p["simd"] + p["load"] + p["store"] + p["alu"] + p["mem"]
                 + p["xs"])
    return MachineModel(
        name=spec.name, clock_hz=spec.clock_hz, ports=all_ports,
        table=table, chip=None, simd_width_bytes=spec.simd_width_bytes,
        vendor=spec.vendor, isa_name=spec.isa,
        issue_width=spec.issue_width, wa_mode=spec.wa_mode,
        mem_tiers=tuple(spec.mem_tiers), cores=spec.cores,
        notes=f"{spec.uarch}: {spec.n_fma}xFMA/{spec.n_simd}xSIMD "
              f"{spec.simd_width_bytes * 8}b, {spec.n_load}L/{spec.n_store}S, "
              f"{spec.mem_bw/1e9:.0f} GB/s socket")


TPU_V5E = _tpu_model(CHIPS["tpu_v5e"])
TPU_V5P = _tpu_model(CHIPS["tpu_v5p"])
TPU_V4 = _tpu_model(CHIPS["tpu_v4"])

ZEN4 = _cpu_model(CPU_CHIPS["zen4"])
GOLDEN_COVE = _cpu_model(CPU_CHIPS["golden_cove"])
NEOVERSE_V2 = _cpu_model(CPU_CHIPS["neoverse_v2"])

for _m in (TPU_V5E, TPU_V5P, TPU_V4, ZEN4, GOLDEN_COVE, NEOVERSE_V2):
    register(_m)
del _m


def host_cpu_model(calib: dict | None = None,
                   mem_tiers: tuple = ()) -> MachineModel:
    """Host-CPU machine model; entries overridden by ubench calibration.

    Units are normalized to a nominal 1 GHz clock so `cycles` == ns; the
    calibration dict maps class -> units/second measured on this host.
    ``mem_tiers`` is the measured cache ladder (repro.core.ubench builds
    both and registers the result as `host_cpu`).
    """
    clock = 1e9
    default_rates = {           # units/s, conservative one-core defaults
        "mxu": 2.0e7,           # ~84 GFLOP/s f32 matmul
        "vpu": 1.2e9,           # (8,128)-blocks/s; calibration will fix
        "xlu": 1.5e8,
        "vdiv": 2.0e8,
        "vlsu": 1.0e9,
        "gather4": 2.5e8,
        "sc": 1.0e9,
        "dma": 2.0e10,          # bytes/s main-memory stream
        "ici": 1.0e10,
    }
    if calib:
        default_rates.update(calib)
    ports = ("P0", "MEM")       # one compute pipe + one memory pipe
    table = {cls: OpEntry(("MEM",) if cls in ("dma", "ici") else ("P0",),
                          clock / rate, 4.0)
             for cls, rate in default_rates.items()}
    return MachineModel(name="host_cpu", clock_hz=clock, ports=ports,
                        table=table, wa_mode="auto_claim",
                        mem_tiers=tuple(mem_tiers), cores=1,
                        notes="ubench-calibrated host model")
