"""The comparison model — our LLVM-MCA stand-in (DESIGN.md §2).

LLVM-MCA predicts from a generic scheduling model without measured port
data; the XLA analogue is ``compiled.cost_analysis()``: raw FLOPs and
bytes pushed through peak-rate ceilings, with no port structure, no
latency chains, and no loop-trip awareness. We expose it with the same
Report-like interface so the RPE harness (paper Fig. 3) can score both
models on identical inputs.

Old-jax compatibility contract
------------------------------
This container pins jax 0.4.37, where ``compiled.cost_analysis()``
returns a **list of dicts** (one per executable; in practice a
one-element list for a single-device jit) and spells the traffic key
``"bytes accessed"`` with a space. Newer jax releases return a plain
dict. Every consumer in this repo therefore feeds the raw value through
:func:`normalize_cost_analysis` instead of calling ``.get`` on it
directly — the PR-1 review found that skipping this crashed
``predict`` on 0.4.37 and poisoned the Fig. 3 cache with NaN records
(CHANGES.md). The contract:

* accept a dict, a (possibly empty) list/tuple of dicts, or ``None``;
* collapse a non-empty list to its first entry (the host executable);
* collapse empty/None input to ``{}`` so lookups degrade to 0.0
  instead of raising.

``predict``/``dryrun``/``quickstart`` all route through this module, so
the old-jax shape never leaks past it.
"""

from __future__ import annotations

import dataclasses

from repro.core.machine import MachineModel


@dataclasses.dataclass
class BaselineReport:
    """Naive two-term roofline prediction from raw XLA cost analysis."""

    flops: float
    bytes_hbm: float
    transcendentals: float
    t_compute: float
    t_memory: float

    @property
    def seconds(self) -> float:
        """Predicted runtime: the slower of the two roofline terms."""
        return max(self.t_compute, self.t_memory)

    def bottleneck(self) -> str:
        """Which term dominates — "compute" or "memory"."""
        return "compute" if self.t_compute >= self.t_memory else "memory"


def normalize_cost_analysis(cost_analysis: dict | list | None) -> dict:
    """Collapse any ``compiled.cost_analysis()`` shape to a plain dict.

    jax 0.4.37 (this container) returns a list of dicts — one entry per
    executable, the first being the host executable we want; newer jax
    returns the dict directly. ``None`` (cost analysis unavailable, e.g.
    AOT paths on some backends) and the empty list both collapse to
    ``{}``, so downstream ``.get(key, 0.0)`` lookups yield zeros rather
    than raising. See the module docstring for the full compatibility
    contract; keys inside the dict are *not* renamed (old and new jax
    agree on ``"flops"`` / ``"bytes accessed"`` / ``"transcendentals"``).
    """
    if isinstance(cost_analysis, (list, tuple)):
        cost_analysis = cost_analysis[0] if cost_analysis else {}
    return cost_analysis or {}


def predict(cost_analysis: dict | list | None, machine: MachineModel,
            peak_flops: float | None = None,
            mem_bw: float | None = None) -> BaselineReport:
    """Naive roofline from XLA cost analysis (per-device numbers)."""
    cost_analysis = normalize_cost_analysis(cost_analysis)
    chip = machine.chip
    if peak_flops is None:
        peak_flops = chip.bf16_flops if chip else 1e11
    if mem_bw is None:
        mem_bw = chip.hbm_bw if chip else 2e10
    flops = float(cost_analysis.get("flops", 0.0) or 0.0)
    byts = float(cost_analysis.get("bytes accessed", 0.0) or 0.0)
    trans = float(cost_analysis.get("transcendentals", 0.0) or 0.0)
    return BaselineReport(
        flops=flops, bytes_hbm=byts, transcendentals=trans,
        t_compute=flops / peak_flops, t_memory=byts / mem_bw)


def predict_from_counts(flops: float, byts: float, machine: MachineModel,
                        peak_flops: float | None = None,
                        mem_bw: float | None = None) -> BaselineReport:
    """`predict` for callers that already hold raw FLOP/byte counts."""
    return predict({"flops": flops, "bytes accessed": byts}, machine,
                   peak_flops, mem_bw)
