"""The comparison model — our LLVM-MCA stand-in (DESIGN.md §2).

LLVM-MCA predicts from a generic scheduling model without measured port
data; the XLA analogue is ``compiled.cost_analysis()``: raw FLOPs and
bytes pushed through peak-rate ceilings, with no port structure, no
latency chains, and no loop-trip awareness. We expose it with the same
Report-like interface so the RPE harness (paper Fig. 3) can score both
models on identical inputs.
"""

from __future__ import annotations

import dataclasses

from repro.core.machine import MachineModel


@dataclasses.dataclass
class BaselineReport:
    flops: float
    bytes_hbm: float
    transcendentals: float
    t_compute: float
    t_memory: float

    @property
    def seconds(self) -> float:
        return max(self.t_compute, self.t_memory)

    def bottleneck(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def normalize_cost_analysis(cost_analysis: dict | list | None) -> dict:
    """compiled.cost_analysis() returns a list-of-dicts on older jax
    (one entry per executable) and a plain dict on newer releases;
    collapse both (and None) to a dict."""
    if isinstance(cost_analysis, (list, tuple)):
        cost_analysis = cost_analysis[0] if cost_analysis else {}
    return cost_analysis or {}


def predict(cost_analysis: dict | list | None, machine: MachineModel,
            peak_flops: float | None = None,
            mem_bw: float | None = None) -> BaselineReport:
    """Naive roofline from XLA cost analysis (per-device numbers)."""
    cost_analysis = normalize_cost_analysis(cost_analysis)
    chip = machine.chip
    if peak_flops is None:
        peak_flops = chip.bf16_flops if chip else 1e11
    if mem_bw is None:
        mem_bw = chip.hbm_bw if chip else 2e10
    flops = float(cost_analysis.get("flops", 0.0) or 0.0)
    byts = float(cost_analysis.get("bytes accessed", 0.0) or 0.0)
    trans = float(cost_analysis.get("transcendentals", 0.0) or 0.0)
    return BaselineReport(
        flops=flops, bytes_hbm=byts, transcendentals=trans,
        t_compute=flops / peak_flops, t_memory=byts / mem_bw)


def predict_from_counts(flops: float, byts: float, machine: MachineModel,
                        peak_flops: float | None = None,
                        mem_bw: float | None = None) -> BaselineReport:
    return predict({"flops": flops, "bytes accessed": byts}, machine,
                   peak_flops, mem_bw)
