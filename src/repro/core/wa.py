"""Write-allocate / read-modify-write traffic analysis (paper §III).

On a cache-line CPU, a store miss reads the line before overwriting it
(write-allocate) unless the core claims the line (Grace), SpecI2M kicks in
(SPR, only near bandwidth saturation), or the code uses non-temporal
stores (Zen 4). The TPU analogue (DESIGN.md §2): HBM writes land in
(8,128)-element tiles (fp32; (16,128) bf16 packed) — a store that does not
overwrite a full tile forces the memory system to read the tile first.
System-level analogues: a non-donated buffer that XLA must copy before a
dynamic-update-slice (full write-allocate of the whole buffer), and
unaligned Pallas output BlockSpecs.

This module provides:
 * tile-level RMW classification for a store given shape/offset/donation
 * the three behavioural machine modes of paper Fig. 4 so the
   cross-vendor comparison is reproducible as a model:
     - auto_claim        (Grace / TPU): RMW elided whenever a full tile is
                          provably overwritten
     - saturation_gated  (SPR SpecI2M): evasion only on the fraction of
                          stores issued while the memory interface is
                          >= `gate` saturated — the gate is modeled from
                          the machine's memory ladder (core/memtier.py)
                          when a working-set size is supplied; NT stores
                          leave ~10% residue
     - explicit_only     (Zen 4): standard stores always allocate;
                          NT stores evade fully
 * module-level scan: WA-adjusted store traffic for a parsed HLO module.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.hloparse import HloModule, parse_hlo
from repro.utils.hw import dtype_bytes


def native_tile(dtype: str) -> tuple:
    """The (sublane, lane) HBM tile granule for a dtype (packed for
    sub-32-bit types: bf16 -> (16,128), int8 -> (32,128))."""
    packing = {"f32": 1, "s32": 1, "u32": 1,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
               "s8": 4, "u8": 4, "f8e4m3fn": 4, "f8e5m2": 4}.get(dtype, 1)
    return (8 * packing, 128)


@dataclasses.dataclass(frozen=True)
class StoreProfile:
    """Tile-level classification of one store region (RMW accounting)."""

    stored_bytes: float           # payload the program wants to write
    rmw_read_bytes: float         # extra reads forced by partial tiles
    copy_bytes: float = 0.0       # whole-buffer copies (missing donation)

    @property
    def traffic(self) -> float:
        """Total memory traffic: write + forced reads + copy (r+w)."""
        return self.stored_bytes + self.rmw_read_bytes + 2 * self.copy_bytes

    @property
    def ratio(self) -> float:
        """Traffic / stored payload (1.0 = perfect, 2.0 = full WA)."""
        return self.traffic / max(self.stored_bytes, 1.0)


def store_profile(shape_dims: tuple, dtype: str, *,
                  offset_aligned: bool = True,
                  donated: bool = True,
                  full_overwrite: bool = True,
                  buffer_bytes: float | None = None) -> StoreProfile:
    """Classify one store region against the native tile grid.

    shape_dims: dims of the written region. offset_aligned: region start is
    tile-aligned (False for unknown dynamic offsets). donated: the target
    buffer aliases an input (in-place); if False and the write is partial
    (full_overwrite=False at buffer granularity), XLA materializes a copy
    of the whole buffer first.
    """
    st, sl = native_tile(dtype)
    eb = dtype_bytes(dtype)
    elems = math.prod(shape_dims) if shape_dims else 1
    stored = float(elems * eb)
    if len(shape_dims) == 0:
        return StoreProfile(stored, 0.0)
    rows = math.prod(shape_dims[:-1]) if len(shape_dims) > 1 else 1
    cols = shape_dims[-1]
    sub = shape_dims[-2] if len(shape_dims) > 1 else 1

    # tiles touched along the minor-2 dims
    if offset_aligned:
        col_tiles = math.ceil(cols / sl)
        row_tiles = math.ceil(sub / st)
        frac_full_cols = (cols // sl) / col_tiles if col_tiles else 1.0
        frac_full_rows = (sub // st) / row_tiles if row_tiles else 1.0
        full_frac = frac_full_cols * frac_full_rows
    else:
        col_tiles = math.ceil(cols / sl) + 1
        row_tiles = math.ceil(sub / st) + 1
        full_frac = max(0.0, (cols - sl) / (col_tiles * sl)) * \
            max(0.0, (sub - st) / (row_tiles * st))
    touched = (rows // max(sub, 1)) * row_tiles * col_tiles if sub else 1
    tile_bytes = st * sl * eb
    touched_bytes = max(stored, touched * tile_bytes)
    rmw = (1.0 - full_frac) * touched_bytes

    copy = 0.0
    if not donated and not full_overwrite and buffer_bytes:
        copy = float(buffer_bytes)
    return StoreProfile(stored, rmw, copy)


# --- the paper's three machines as behavioural modes (Fig. 4) -------------

def machine_traffic_ratio(mode: str, *, nt_stores: bool = False,
                          bw_utilization: float = 1.0,
                          tile_full_frac: float = 1.0,
                          residue: float | None = None) -> float:
    """Memory-traffic / stored-data ratio for a store-only kernel.

    Mirrors Fig. 4: 1.0 = perfect WA evasion, 2.0 = full write-allocate.

    ``residue`` is the per-tier WA-evasion residue from the memory
    ladder (`MemTier.wa_residue`, core/memtier.py): the allocate-read
    fraction surviving the machine's evasion mechanism at one tier
    boundary. When omitted, the legacy Fig. 4 calibration constants
    apply (auto-claim 0, SpecI2M/NT ~0.1, NT-on-Zen4 0, and a
    conservative 0.25 maximum SpecI2M evasion for standard stores).
    """
    partial_extra = 1.0 - tile_full_frac          # RMW share from tiling
    if mode == "auto_claim":            # Grace & TPU
        return 1.0 + (residue or 0.0) + partial_extra
    if mode == "saturation_gated":      # Sapphire Rapids SpecI2M
        if nt_stores:
            # residual allocate traffic (~10% in the paper's Fig. 4)
            return 1.0 + (0.1 if residue is None else residue) \
                + partial_extra
        gate = max(0.0, min(1.0, (bw_utilization - 0.5) / 0.5))
        # evasion depth at full gate: legacy 0.25, or down to the
        # tier's residue when the ladder supplies one
        evade = gate * (0.25 if residue is None else 1.0 - residue)
        return 2.0 - evade + partial_extra
    if mode == "explicit_only":         # Zen 4
        if nt_stores:
            return 1.0 + (residue or 0.0) + partial_extra
        return 2.0 + partial_extra      # standard stores always allocate
    raise ValueError(mode)


# --- per-machine mode selection ---------------------------------------------
#
# Every registered MachineModel is tagged with its wa_mode
# (repro.core.machine), so the Fig. 4 behavioural mode is a property of
# the machine file instead of an ad-hoc argument at each call site.

def wa_mode_of(machine) -> str:
    """WA behavioural mode of a machine (model or registered name)."""
    if isinstance(machine, str):
        from repro.core.machine import get_machine
        machine = get_machine(machine)
    return getattr(machine, "wa_mode", "") or "auto_claim"


def modeled_saturation_for(machine, ws_bytes: float,
                           cores_active: int | None = None) -> float:
    """Ladder-modeled interface saturation for a working set, 0..1.

    Thin forwarding wrapper over `memtier.modeled_saturation` (imported
    lazily — memtier imports this module for the Fig. 4 ratio model).
    """
    from repro.core import memtier
    return memtier.modeled_saturation(machine, ws_bytes, cores_active)


def traffic_ratio_for(machine, *, nt_stores: bool = False,
                      bw_utilization: float | None = None,
                      tile_full_frac: float = 1.0,
                      ws_bytes: float | None = None,
                      cores_active: int | None = None) -> float:
    """`machine_traffic_ratio` with the mode taken from the machine tag.

    The SpecI2M saturation gate is no longer a caller-supplied constant:
    pass ``ws_bytes`` (and optionally ``cores_active``) and the gate is
    *modeled* from the machine's memory ladder — the home tier of the
    working set must actually saturate its shared interface for the
    evasion to engage. An explicit ``bw_utilization`` still overrides
    (sweeps like benchmarks/fig4_wa.py plot against it); with neither
    supplied, full saturation is assumed (the legacy default).
    """
    if bw_utilization is None:
        bw_utilization = (modeled_saturation_for(machine, ws_bytes,
                                                 cores_active)
                          if ws_bytes is not None else 1.0)
    return machine_traffic_ratio(wa_mode_of(machine), nt_stores=nt_stores,
                                 bw_utilization=bw_utilization,
                                 tile_full_frac=tile_full_frac)


def ladder_traffic_ratio(machine, *, nt_stores: bool = False,
                         bw_utilization: float | None = None,
                         tile_full_frac: float = 1.0,
                         ws_bytes: float | None = None,
                         cores_active: int | None = None) -> float:
    """`machine_traffic_ratio` with the residue taken from the ladder.

    The per-tier WA-evasion residue comes from the machine's `MemTier`
    ladder instead of the legacy Fig. 4 calibration constants: the
    working set's home tier (the backing tier when ``ws_bytes`` is
    omitted — store streams that evade WA are DRAM-bound by nature)
    supplies ``wa_residue``, and the SpecI2M gate is modeled from the
    same ladder unless an explicit ``bw_utilization`` overrides. This
    is the single pricing path `benchmarks/fig4_wa.py`,
    `benchmarks/fig4b_ntstore.py`, and the store-flavor selector
    (`repro.kernels.stores`) share, so the Fig. 4 curves, the fig4b
    gate, and the flavor decision can never disagree on a ratio.
    """
    if isinstance(machine, str):
        from repro.core.machine import get_machine
        machine = get_machine(machine)
    from repro.core import memtier
    tiers = memtier.tiers_of(machine)
    home = tiers[-1] if ws_bytes is None \
        else memtier.resolve_home(tiers, ws_bytes)
    if bw_utilization is None:
        bw_utilization = (memtier.modeled_saturation(machine, ws_bytes,
                                                     cores_active)
                          if ws_bytes is not None else 1.0)
    return machine_traffic_ratio(wa_mode_of(machine), nt_stores=nt_stores,
                                 bw_utilization=bw_utilization,
                                 tile_full_frac=tile_full_frac,
                                 residue=home.wa_residue)


def priced_store_traffic(profile: StoreProfile, machine, *,
                         nt_stores: bool = False,
                         ws_bytes: float | None = None,
                         cores_active: int | None = None,
                         flavor: str | None = None) -> float:
    """Total memory traffic (bytes) of one StoreProfile on one machine.

    The stored payload is priced at the machine's Fig. 4 ratio evaluated
    at the profile's tile fullness (``tile_full_frac`` = 1 - rmw/stored,
    which may go negative for badly misaligned stores — the ratio then
    correctly exceeds the mode's base). A donation-copy term
    (``profile.copy_bytes``: the whole-buffer copy XLA materializes for a
    partial write into a non-donated buffer) is priced as one full read
    plus a full-overwrite write at the machine's ratio — the copy streams
    whole tiles, so only the machine's base WA behaviour applies to it.
    Used by repro.serve.kv_traffic to report the per-machine
    donated-vs-copied KV-update delta.

    ``flavor`` opts into store-flavor pricing: ``"standard"`` / ``"nt"``
    (or ``"auto"``, resolved by the per-machine selector in
    ``repro.kernels.stores``) prices through the memory ladder's
    per-tier residues (:func:`ladder_traffic_ratio`) instead of the
    legacy Fig. 4 constants, so the result matches what the selected
    store kernel actually generates. The legacy ``nt_stores`` keyword
    keeps the historical constants when ``flavor`` is None.
    """
    if flavor is not None:
        from repro.kernels.stores import resolve_flavor
        nt_stores = resolve_flavor(flavor, machine, ws_bytes=ws_bytes,
                                   cores_active=cores_active) == "nt"
        ratio_fn = ladder_traffic_ratio
    else:
        ratio_fn = traffic_ratio_for
    stored = profile.stored_bytes
    full_frac = 1.0 - profile.rmw_read_bytes / stored if stored > 0 else 1.0
    ratio = ratio_fn(machine, nt_stores=nt_stores,
                     tile_full_frac=full_frac,
                     ws_bytes=ws_bytes, cores_active=cores_active)
    traffic = stored * ratio
    if profile.copy_bytes:
        ratio_full = ratio_fn(machine, nt_stores=nt_stores,
                              tile_full_frac=1.0,
                              ws_bytes=ws_bytes,
                              cores_active=cores_active)
        traffic += profile.copy_bytes * (1.0 + ratio_full)
    return traffic


def apply_wa_mode(scan: dict, machine, *, nt_stores: bool = False,
                  bw_utilization: float | None = None,
                  ws_bytes: float | None = None,
                  cores_active: int | None = None) -> dict:
    """Apply one machine's WA mode to a (machine-independent) store scan.

    `scan` is an `analyze_module_stores` result. The scan's RMW reads
    become the partial-tile term: tile_full_frac = 1 - rmw/stored, which
    may go negative for badly misaligned stores (rmw > stored) — the
    ratio then correctly exceeds the mode's base. Returns the scan dict
    extended with `wa_mode` and `traffic_bytes` = stored x machine ratio
    + the donation-copy term; the machine ratio replaces `wa_ratio` (the
    scan's tile-level value is preserved as `tile_wa_ratio`).
    """
    stored = scan["stored_bytes"]
    full_frac = 1.0 - scan["rmw_read_bytes"] / stored if stored > 0 else 1.0
    ratio = traffic_ratio_for(machine, nt_stores=nt_stores,
                              bw_utilization=bw_utilization,
                              tile_full_frac=full_frac,
                              ws_bytes=ws_bytes, cores_active=cores_active)
    out = dict(scan)
    out["wa_mode"] = wa_mode_of(machine)
    out["tile_wa_ratio"] = scan.get("wa_ratio")
    out["wa_ratio"] = ratio
    # missing-donation copies (read+write the whole buffer) happen on
    # every machine regardless of WA mode
    out["traffic_bytes"] = stored * ratio + 2.0 * scan.get("copy_bytes", 0.0)
    return out


def machine_store_traffic(hlo, machine, *, nt_stores: bool = False,
                          bw_utilization: float | None = None,
                          ws_bytes: float | None = None,
                          cores_active: int | None = None) -> dict:
    """WA-adjusted store traffic of one module on one machine.

    Combines the tile-level module scan (which stores exist, and what
    fraction overwrites full tiles) with the machine's behavioural mode
    (what a partial-tile / missed store costs there). Pass ``ws_bytes``
    to let the memory ladder model the SpecI2M saturation gate instead
    of assuming full saturation. When comparing many machines on one
    module, run the scan once and call `apply_wa_mode` per machine
    instead.
    """
    base = analyze_module_stores(hlo) if isinstance(hlo, HloModule) \
        else analyze_text_stores(hlo)
    return apply_wa_mode(base, machine, nt_stores=nt_stores,
                         bw_utilization=bw_utilization,
                         ws_bytes=ws_bytes, cores_active=cores_active)


# --- module-level scan ------------------------------------------------------

_STORED_OPS = {"dynamic-update-slice", "scatter"}


def analyze_module_stores(mod: HloModule) -> dict:
    """Scan a parsed module for store-like ops and donation structure.

    Returns aggregate stored/RMW/copy bytes across the entry computation
    (fusion outputs are treated as full-overwrite aligned stores — XLA
    lays fusion outputs on tile boundaries; dynamic-update-slices with
    non-literal offsets are classified offset-unaligned).
    """
    stored = rmw = copy = 0.0
    comps = [mod.entry]
    seen = set()
    while comps:
        comp = comps.pop()
        if comp.name in seen:
            continue
        seen.add(comp.name)
        by_name = comp.by_name()
        for i in comp.instrs:
            for key in ("calls", "body", "condition", "to_apply"):
                t = i.attr_comp(key)
                if t and t in mod.computations:
                    comps.append(mod.computations[t])
            if i.opcode in _STORED_OPS:
                upd = by_name.get(i.operands[1]) if len(i.operands) > 1 \
                    else None
                dims = upd.shape.dims if upd is not None else i.shape.dims
                buf_dims = i.shape.dims
                # A dus whose update spans the buffer's full minor-2 dims
                # (scan ys / KV-cache row writes) only slides along leading
                # dims — tile-aligned by construction. Only truly partial
                # minor-dim updates with dynamic offsets are RMW.
                minor_full = (len(dims) >= 2 and len(buf_dims) >= 2 and
                              dims[-1] == buf_dims[-1] and
                              dims[-2] == buf_dims[-2])
                if minor_full:
                    # whole (padded) tiles by construction: no RMW
                    prof = store_profile(dims, i.shape.dtype)
                    stored += prof.stored_bytes
                else:
                    prof = store_profile(dims, i.shape.dtype,
                                         offset_aligned=False, donated=True,
                                         full_overwrite=False)
                    stored += prof.stored_bytes
                    rmw += prof.rmw_read_bytes
            elif i.opcode == "fusion":
                # fresh outputs land in tile-padded buffers with no live
                # cotenants: stores never read-modify-write (unlike CPU
                # cache lines, which is the paper's whole point — the TPU
                # behaves like Grace's cache-line claim by construction)
                for s in i.shapes:
                    stored += float(s.bytes)
    return {"stored_bytes": stored, "rmw_read_bytes": rmw,
            "copy_bytes": copy,
            "wa_ratio": (stored + rmw + 2 * copy) / max(stored, 1.0)}


def analyze_text_stores(hlo_text: str) -> dict:
    """`analyze_module_stores` straight from compiled HLO text."""
    return analyze_module_stores(parse_hlo(hlo_text))
