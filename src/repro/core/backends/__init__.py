"""Pluggable scheduling backends over the µ-op trace IR.

A backend turns one machine-independent :class:`repro.core.trace.Trace`
into a :class:`repro.core.report.Report` for one machine:

    class Backend(Protocol):
        name: str
        def run(self, trace, machine, warn=True) -> Report: ...

Shipped backends:

 * ``tp_bound``  — the analytical OSACA-style port-occupation bound
   (TP/CP/LCD); optimistic/lower bound, the default everywhere.
 * ``mca_sched`` — an LLVM-MCA-style cycle simulator (in-order
   dispatch, bounded scheduler window, out-of-order issue with port
   contention); pessimistic-or-equal by construction.

Both run over the *same* trace, so a registry-wide
``portmodel.compare`` decomposes each module exactly once. Register
additional engines with :func:`register_backend`; short aliases
(``tp``, ``mca``, ``osaca``) resolve through :func:`get_backend`.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

from repro.core.report import Report
from repro.core.trace import Trace, TraceOp, TraceRegion


@runtime_checkable
class Backend(Protocol):
    """The backend protocol: a name and a ``run(trace, machine)``."""

    name: str

    def run(self, trace: Trace, machine, warn: bool = True) -> Report:
        """Schedule one trace on one machine; returns a Report."""
        ...


#: name -> Backend instance. Mutated only through register_backend().
BACKENDS: dict = {}

#: short/paper spellings accepted anywhere a backend name is
ALIASES = {"tp": "tp_bound", "osaca": "tp_bound", "mca": "mca_sched",
           "llvm-mca": "mca_sched"}


def register_backend(backend: Backend, *, replace: bool = False) -> Backend:
    """Add a backend to the registry; returns it for chaining."""
    if not backend.name:
        raise ValueError("backend needs a non-empty name")
    if backend.name in BACKENDS and not replace:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"(pass replace=True)")
    BACKENDS[backend.name] = backend
    return backend


def get_backend(backend) -> Backend:
    """Resolve a backend by name/alias, or pass an instance through."""
    if not isinstance(backend, str):
        if isinstance(backend, Backend):
            return backend
        raise TypeError(f"not a backend: {backend!r}")
    name = ALIASES.get(backend, backend)
    try:
        return BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown backend {backend!r}; registered: "
                       f"{sorted(BACKENDS)}") from None


def registered_backends() -> tuple:
    """Names of every registered backend, in registration order."""
    return tuple(BACKENDS)


def uops_seconds(machine, uops, backend="tp_bound") -> float:
    """Price a raw µ-op list on one machine through a backend.

    Builds a one-op trace from ``uops`` (``[(class, units), ...]``) and
    returns the backend's in-core estimate in seconds. With the default
    ``tp_bound`` this equals the closed-form balanced-port arithmetic
    the kernel autotuner historically used; a simulator backend adds
    its dispatch/latency pessimism. Degradation of unknown classes is
    silent here (the caller is pricing a hypothetical, not a module).
    """
    from repro.core.machine import get_machine
    op = TraceOp(name="uops", opcode="priced", kind="op",
                 uops=tuple(uops), lat_cls="vpu")
    tr = Trace("uops", TraceRegion("uops", False, [op]))
    model = get_machine(machine)
    rep = get_backend(backend).run(tr, model, warn=False)
    return rep.seconds_incore(model)


def _register_builtin() -> None:
    from repro.core.backends.mca_sched import McaSchedBackend
    from repro.core.backends.tp_bound import TpBoundBackend
    register_backend(TpBoundBackend())
    register_backend(McaSchedBackend())


_register_builtin()
