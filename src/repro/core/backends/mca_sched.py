"""LLVM-MCA-style cycle-simulator backend over the µ-op trace IR.

Where ``tp_bound`` assumes perfect ILP (every port busy whenever work
exists — an optimistic lower bound), this backend *schedules*: µ-ops
are dispatched in program order through a finite front end, wait in a
bounded scheduler window, and issue out of order onto concrete ports.
Three effects the analytical bound cannot see are modeled, mirroring
what llvm-mca's dispatch/scheduler/retire stages add over a pure
reciprocal-throughput sum (the paper's Fig. 3 comparison):

 * **dispatch stalls** — at most ``issue_width`` µ-ops enter the
   scheduler per cycle (machines that leave ``issue_width`` unmodeled
   get a generous default so the front end is never the artificial
   bottleneck);
 * **bounded window** — µ-op *j* cannot dispatch until µ-op
   *j - window* has completed, approximating reservation-station /
   ROB pressure;
 * **port contention** — each µ-op occupies exactly one admissible
   port for its reciprocal-throughput cycles; the scheduler picks the
   earliest-free port (the oldest-ready heuristic, since µ-ops are
   visited in program order), so imbalance shows up as real stalls
   instead of being averaged away.

Inlined fusion/call regions are flattened into the parent stream with
dependency edges stitched across the call boundary (the trace's
``param_map`` / ``root_name``); ``while`` loops are simulated once and
contribute ``trips x`` their steady-state makespan as macro-ops, the
same LCD treatment the analytical backend applies.

The reported estimate is **pessimistic-or-equal by construction**:
``sim_cycles = max(simulated makespan, TP in-core bound, LCD floors)``
— a simulator approximation can therefore never report an infeasible
cycle count below the provable lower bound (pinned per machine by
tests/test_trace_backends.py).
"""

from __future__ import annotations

from repro.core.machine import get_machine
from repro.core.report import Report
from repro.core.trace import Trace, TraceRegion
from repro.core.backends.tp_bound import _Walk

#: scheduler-window default (µ-ops in flight), roughly an out-of-order
#: reservation station of the size llvm-mca assumes for modern cores
DEFAULT_WINDOW = 64
#: front-end width used when a machine leaves issue_width unmodeled (0)
DEFAULT_ISSUE_WIDTH = 6
#: µ-op classes the in-core scheduler does not see (off-core engines)
_OFFCORE = ("dma", "ici")


class _SimOp:
    """One flattened schedulable record."""

    __slots__ = ("deps", "pairs", "macro")

    def __init__(self, deps, pairs=(), macro=None):
        self.deps = deps        # indices of producer _SimOps
        self.pairs = pairs      # ((class, units), ...) port µ-ops
        self.macro = macro      # fixed duration (loop floors), or None


class McaSchedBackend:
    """The cycle-simulator backend (``Backend.run`` protocol)."""

    name = "mca_sched"

    def __init__(self, window: int = DEFAULT_WINDOW,
                 issue_width: int | None = None):
        self.window = max(1, window)
        self.issue_width = issue_width

    def run(self, trace: Trace, machine, warn: bool = True) -> Report:
        """Simulate one trace on one machine; returns a Report.

        The analytical walk runs first (same trace) to fill the
        occupation/traffic/CP/LCD fields; the simulation then sets
        ``sim_cycles``, which the Report's backend-resolved accessors
        (``incore_cycles`` and the bounds) prefer.
        """
        model = get_machine(machine)
        walk = _Walk(model, warn=warn)
        rep = walk.run(trace, self.name)
        raw = self._simulate(trace.entry, model, walk)
        rep.sim_cycles = max(raw, rep.tp_incore_cycles, rep.serial_cycles)
        return rep

    # -- flattening ----------------------------------------------------------
    def _flatten(self, region: TraceRegion, alias: dict, out: list,
                 model, walk) -> dict:
        """Append region ops to ``out``; returns {local name: op index}.

        ``alias`` maps body parameter names to producer indices in the
        enclosing stream (dependency stitching across inlining).
        """
        local: dict = {}

        def resolve(op):
            ids = [local[d] for d in op.deps if d in local]
            if op.opcode == "parameter" and op.name in alias:
                ids.append(alias[op.name])
            return tuple(ids)

        for op in region.ops:
            if op.kind == "elided":
                out.append(_SimOp(resolve(op)))
                local[op.name] = len(out) - 1
            elif op.kind == "inline":
                deps = resolve(op)
                if op.region is None:
                    out.append(_SimOp(deps))
                    local[op.name] = len(out) - 1
                    continue
                inner_alias = {}
                for pname, opnd in (op.param_map or {}).items():
                    if opnd in local:
                        inner_alias[pname] = local[opnd]
                inner = self._flatten(op.region, inner_alias, out,
                                      model, walk)
                root = inner.get(op.root_name)
                if root is None:        # degenerate body: barrier op
                    out.append(_SimOp(deps))
                    root = len(out) - 1
                local[op.name] = root
            elif op.kind == "loop":
                floor = 0.0
                if op.region is not None:
                    body = self._simulate(op.region, model, walk)
                    floor = op.trips * body
                out.append(_SimOp(resolve(op), macro=floor))
                local[op.name] = len(out) - 1
            else:
                pairs = tuple((c, u) for c, u in op.uops
                              if c not in _OFFCORE)
                out.append(_SimOp(resolve(op), pairs=pairs))
                local[op.name] = len(out) - 1
        return local

    # -- scheduling ----------------------------------------------------------
    def _simulate(self, region: TraceRegion, model, walk) -> float:
        ops: list = []
        self._flatten(region, {}, ops, model, walk)
        width = self.issue_width or model.issue_width or \
            DEFAULT_ISSUE_WIDTH
        step = 1.0 / width
        window = self.window
        free: dict = {}                 # port -> busy-until (cycles)
        comp = [0.0] * len(ops)
        t_disp = 0.0
        makespan = 0.0
        for j, op in enumerate(ops):
            if j >= window:             # RS entry frees at completion
                t_disp = max(t_disp, comp[j - window])
            ready = max((comp[i] for i in op.deps), default=0.0)
            if op.macro is not None:
                end = max(t_disp, ready) + op.macro
            elif not op.pairs:
                end = max(t_disp, ready)
            else:
                end = 0.0
                for cls, units in op.pairs:
                    entry = model.table.get(cls)
                    if entry is None:
                        entry = walk.fallback_entry(cls)
                    occ = units * entry.cycles_per_unit
                    port = min(entry.ports,
                               key=lambda p: free.get(p, 0.0))
                    start = max(t_disp, ready, free.get(port, 0.0))
                    free[port] = start + occ
                    end = max(end, start + max(entry.latency, occ))
            comp[j] = end
            makespan = max(makespan, end)
            t_disp += step
        return makespan
