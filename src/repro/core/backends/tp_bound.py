"""Analytical TP/CP/LCD backend — OSACA semantics over the trace IR.

Reproduces the paper's three analyses (the pre-refactor monolithic
analyzer, bit-for-bit — pinned by tests/test_golden_compare.py):

 * TP  — every µ-op's port occupation is distributed evenly over its
         admissible ports; the block lower bound is the maximum per-port
         sum (perfect ILP assumption -> optimistic/lower bound).
 * CP  — longest latency path through the dataflow DAG.
 * LCD — for `while` loops (layer scans, decode loops, optimizer loops),
         the body's carried-dependency path sets the per-iteration floor:
         cycles(loop) = trips * max(TP_body, LCD_body).

The walk also re-accumulates FLOPs / HBM bytes / collective bytes with
loop-trip multipliers — XLA's own cost_analysis visits while bodies
once, which under-counts a scanned N-layer model by N x (DESIGN.md
§3.1). The walk order mirrors the trace's lowering order exactly so
floating-point accumulation is reproducible.
"""

from __future__ import annotations

import warnings
from collections import defaultdict

from repro.core.machine import get_machine
from repro.core.report import Report, is_mem_port
from repro.core.trace import Trace, TraceRegion


class _Acc:
    """Mutable per-region accumulator (ports, traffic, counters)."""

    def __init__(self):
        self.ports = defaultdict(float)
        self.flops = 0.0
        self.bytes_hbm = 0.0
        self.coll = defaultdict(float)
        self.n = 0
        self.unknown = 0
        self.fallback = 0
        self.serial = 0.0
        self.cp = 0.0
        self.trips_seen = {}
        self.loop_bytes = {}


class TpBoundBackend:
    """The default analytical backend (``Backend.run`` protocol)."""

    name = "tp_bound"

    def run(self, trace: Trace, machine, warn: bool = True) -> Report:
        """Walk one trace against one machine model; returns a Report."""
        return _Walk(get_machine(machine), warn).run(trace, self.name)


class _Walk:
    """One (trace, machine) walk; holds the per-run warning dedupe."""

    def __init__(self, model, warn: bool = True):
        self.model = model
        self.warn = warn
        self._warned_classes: set = set()
        self._fallback_classes: set = set()

    def run(self, trace: Trace, backend_name: str) -> Report:
        """Accumulate the whole trace and assemble the Report."""
        acc = _Acc()
        self.region(trace.entry, acc)
        tp = max(acc.ports.values()) if acc.ports else 0.0
        return Report(
            tp_cycles=tp, cp_cycles=acc.cp, serial_cycles=acc.serial,
            port_occupation=dict(acc.ports), flops=acc.flops,
            bytes_hbm=acc.bytes_hbm, coll_bytes=dict(acc.coll),
            n_instrs=acc.n, unknown_ops=acc.unknown,
            trips_seen=dict(acc.trips_seen),
            loop_bytes=dict(acc.loop_bytes),
            fallback_uops=acc.fallback,
            fallback_classes=tuple(sorted(self._fallback_classes)),
            backend=backend_name)

    # -- machine-file access -------------------------------------------------
    def fallback_entry(self, cls: str):
        """Entry for a µ-op class the machine file does not cover.

        Prefers `vpu` (the historical fallback); a machine registered
        without one (e.g. injected straight into the MACHINES dict,
        bypassing validate_model) degrades to the cheapest available
        non-memory class instead of raising KeyError. Warns once per
        missing class per walk (suppressed under ``compare()``, which
        warns once in the parent); occurrences are counted on the
        report (`Report.fallback_uops` / `fallback_classes`).
        """
        entry = self.model.table.get("vpu")
        if entry is None:
            cands = {c: e for c, e in self.model.table.items()
                     if c not in ("dma", "ici")} or dict(self.model.table)
            if not cands:
                raise KeyError(
                    f"machine {self.model.name!r} has an empty µ-op table")
            entry = min(cands.values(), key=lambda e: e.cycles_per_unit)
        self._fallback_classes.add(cls)
        if self.warn and cls not in self._warned_classes:
            self._warned_classes.add(cls)
            warnings.warn(
                f"machine {self.model.name!r} has no entry for µ-op "
                f"class {cls!r}; degrading to the cheapest available "
                f"class (counted in Report.fallback_uops)",
                RuntimeWarning, stacklevel=3)
        return entry

    def _occupy(self, acc, cls: str, units: float) -> float:
        entry = self.model.table.get(cls)
        if entry is None:
            entry = self.fallback_entry(cls)
            acc.fallback += 1
        cyc = units * entry.cycles_per_unit
        if entry.port_weights is None:
            share = cyc / len(entry.ports)
            for p in entry.ports:
                acc.ports[p] += share
        else:
            wsum = sum(entry.port_weights)
            for p, w in zip(entry.ports, entry.port_weights):
                acc.ports[p] += cyc * (w / wsum)
        return cyc

    # -- walk ----------------------------------------------------------------
    def _op_cost(self, op, acc) -> float:
        """Occupies ports; returns the op's own min-cycles (CP/LCD
        edge weight)."""
        if op.kind == "inline":
            if op.region is None:
                return 0.0
            return self.region(op.region, acc)
        if op.kind == "loop":
            n = op.trips
            acc.trips_seen[op.name] = n
            if op.region is None:
                return 0.0
            sub = _Acc()
            body_cp = self.region(op.region, sub)
            body_tp = max((c for p, c in sub.ports.items()
                           if not is_mem_port(p)), default=0.0)
            floor = n * max(body_tp, body_cp, sub.serial)
            # merge: occupation scaled by trips
            for p, c in sub.ports.items():
                acc.ports[p] += c * n
            acc.flops += sub.flops * n
            acc.bytes_hbm += sub.bytes_hbm * n
            for k, v in sub.coll.items():
                acc.coll[k] += v * n
            acc.n += sub.n
            acc.unknown += sub.unknown
            acc.fallback += sub.fallback
            acc.serial += floor
            acc.trips_seen.update(sub.trips_seen)
            acc.loop_bytes.update(sub.loop_bytes)
            acc.loop_bytes[op.name] = (n, sub.bytes_hbm, sub.flops)
            return floor

        own = 0.0
        for cls, units in op.uops:
            cyc = self._occupy(acc, cls, units)
            if cls not in ("dma", "ici"):
                own += cyc      # CP/LCD chains are in-core (prefetchable
                                # memory traffic is not a dependency)
        acc.flops += op.flops
        if op.coll_bytes:
            acc.coll[op.coll_kind] += op.coll_bytes
        acc.n += 1
        acc.unknown += int(op.unknown)
        return own

    def _latency(self, op, own_cycles: float) -> float:
        if op.lat_cls is None:          # while / fusion
            base = 0.0
        else:
            entry = self.model.table.get(op.lat_cls)
            if entry is None:
                entry = self.fallback_entry(op.lat_cls)
            base = entry.latency
        if op.free:
            base = 0.0
        # a consumer needing the full result also waits for throughput
        return base + own_cycles

    def region(self, region: TraceRegion, acc) -> float:
        """Walk one region; returns its CP length (cycles)."""
        depth: dict = {}
        cp = 0.0
        for op in region.ops:
            if op.kind == "elided":      # alias-elided carry copy: free
                d = max((depth.get(o, 0.0) for o in op.deps),
                        default=0.0)
                depth[op.name] = d
                continue
            own = self._op_cost(op, acc)
            lat = self._latency(op, own)
            d = lat + max((depth.get(o, 0.0) for o in op.deps),
                          default=0.0)
            depth[op.name] = d
            cp = max(cp, d)
            if op.dma_bytes is not None:
                acc.bytes_hbm += op.dma_bytes
                self._occupy(acc, "dma", op.dma_bytes)
        acc.cp = max(acc.cp, cp)
        return cp
