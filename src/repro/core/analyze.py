"""Single-module analysis CLI over the prediction-engine frontend.

Analyze one compiled HLO text file on any registered machines with any
scheduling backends and print a per-(machine, backend) report table:

    python -m repro.core.analyze step.hlo --machine zen4 --backend tp
    python -m repro.core.analyze step.hlo --machine all \\
        --backend tp,mca

``--machine`` takes registered names (comma-separated and/or repeated)
or ``all``; ``--backend`` takes backend names or aliases (``tp``,
``mca``, ``osaca``, ``llvm-mca``, or the canonical ``tp_bound`` /
``mca_sched``). The table reuses exactly the ``portmodel.compare``
fan-out the serve planner and benchmarks consume.
"""

from __future__ import annotations

import argparse

from repro.core import portmodel
from repro.core.backends import get_backend, registered_backends
from repro.core.machine import get_machine, registered_names


def _split_multi(values, default: tuple, every: tuple) -> tuple:
    """Flatten repeated/comma-separated option values.

    No value -> ``default``; an explicit ``all`` -> ``every`` (the full
    registry, which for backends is wider than the default).
    """
    if not values:
        return default
    out: list = []
    for v in values:
        out.extend(x.strip() for x in v.split(",") if x.strip())
    if "all" in out:
        return every
    return tuple(dict.fromkeys(out))


def format_table(reports: dict, backends: tuple) -> str:
    """Render a nested ``{machine: {backend: Report}}`` as a table."""
    hdr = (f"{'machine':<13} {'backend':<10} {'bound cy':>12} "
           f"{'in-core cy':>12} {'sim cy':>12} {'t_bound':>10} "
           f"{'t_tier':>10} {'bottleneck':>12} {'tier':>5} "
           f"{'fallback':>8}")
    lines = [hdr, "-" * len(hdr)]
    for name, per in reports.items():
        m = get_machine(name)
        for bname in backends:
            rep = per[bname]
            sim = (f"{rep.sim_cycles:>12.3e}"
                   if rep.sim_cycles is not None else f"{'-':>12}")
            lines.append(
                f"{name:<13} {bname:<10} {rep.bound_cycles:>12.3e} "
                f"{rep.bound_incore_cycles:>12.3e} {sim} "
                f"{rep.seconds(m)*1e6:>8.1f}us "
                f"{rep.tier_bound_seconds(m)*1e6:>8.1f}us "
                f"{rep.bottleneck():>12} "
                f"{rep.bottleneck_tier or 'n/a':>5} "
                f"{rep.fallback_uops:>8}")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI entry point; returns a process exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.analyze",
        description="Analyze one compiled HLO module across machines "
                    "and scheduling backends.")
    ap.add_argument("hlo", help="path to a compiled HLO text file "
                               "(jax: compiled.as_text())")
    ap.add_argument("--machine", action="append", default=None,
                    metavar="NAME[,NAME...]",
                    help="registered machine name(s); repeatable; "
                         "'all' (default) = every registered machine")
    ap.add_argument("--backend", action="append", default=None,
                    metavar="NAME[,NAME...]",
                    help="scheduling backend(s): tp|mca or canonical "
                         "names; repeatable (default: tp)")
    ap.add_argument("--n-devices", type=int, default=1,
                    help="device count for collective sizing")
    args = ap.parse_args(argv)

    machines = _split_multi(args.machine, registered_names(),
                            registered_names())
    backends = _split_multi(args.backend, ("tp_bound",),
                            registered_backends())
    # canonicalize aliases, then dedupe (tp + osaca are one backend)
    backends = tuple(dict.fromkeys(get_backend(b).name
                                   for b in backends))
    for m in machines:
        get_machine(m)          # fail fast with the registry's message
    with open(args.hlo) as f:
        hlo_text = f.read()

    reports = portmodel.compare(hlo_text, machines=machines,
                                n_devices=args.n_devices,
                                backends=backends)
    first = reports[next(iter(reports))][backends[0]]
    print(f"module: {args.hlo}  (instrs={first.n_instrs}, "
          f"unknown={first.unknown_ops}, "
          f"backends={'/'.join(backends)}, "
          f"registered backends={'/'.join(registered_backends())})")
    print(format_table(reports, backends))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
