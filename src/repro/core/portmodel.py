"""OSACA-semantics in-core analysis of compiled HLO: throughput (TP),
critical path (CP), and loop-carried dependencies (LCD).

Reproduces the paper's three analyses on the TPU port model:

 * TP  — every µ-op's port occupation is distributed evenly over its
         admissible ports; the block lower bound is the maximum per-port
         sum (perfect ILP assumption -> optimistic/lower bound).
 * CP  — longest latency path through the dataflow DAG.
 * LCD — for `while` loops (layer scans, decode loops, optimizer loops),
         the body's carried-dependency path sets the per-iteration floor:
         cycles(loop) = trips * max(TP_body, LCD_body).

The analyzer also re-accumulates FLOPs / HBM bytes / collective bytes with
loop-trip multipliers — XLA's own cost_analysis visits while bodies once,
which under-counts a scanned N-layer model by N x (see DESIGN.md §3.1).
"""

from __future__ import annotations

import dataclasses
import functools
import multiprocessing
import os
import pickle
import re
import warnings
from collections import defaultdict
from concurrent.futures import ProcessPoolExecutor

from repro.core import isa
from repro.core.hloparse import (Computation, HloModule, Instr,
                                 parse_hlo, trip_counts_from_text,
                                 while_trip_count)
from repro.core.machine import (MachineModel, get_machine,
                                registered_names)


_MEM_PORTS = ("DMA", "ICI", "MEM")


def _params_in_order(comp) -> list:
    """Parameter instructions sorted by their declared parameter index
    (HLO text lists them in dataflow order, not index order)."""
    ps = [i for i in comp.instrs if i.opcode == "parameter"]

    def key(i):
        m = re.search(r"parameter_index=(\d+)", i.attrs)
        return int(m.group(1)) if m else 1 << 30
    return sorted(ps, key=key)


def _is_mem_port(p: str) -> bool:
    return p.startswith(_MEM_PORTS)


@dataclasses.dataclass
class Report:
    """Result of analyzing one HLO module on one machine: TP/CP/LCD
    cycles, per-port occupation, trip-multiplied traffic accounting,
    and (once resolved) the memory-ladder fields."""

    tp_cycles: float              # max per-port occupation (incl. DMA/ICI)
    cp_cycles: float              # latency-critical path (in-core)
    serial_cycles: float          # sum of sequential loop floors
    port_occupation: dict         # port -> cycles
    flops: float
    bytes_hbm: float
    coll_bytes: dict              # kind -> wire bytes
    n_instrs: int
    unknown_ops: int
    trips_seen: dict              # loop name -> trips
    loop_bytes: dict = dataclasses.field(default_factory=dict)
    # loop name -> (trips, bytes/iter, flops/iter) for bottleneck attribution
    # µ-ops whose class had no machine-file entry and were degraded to the
    # cheapest available class (see Analyzer._occupy)
    fallback_uops: int = 0
    # memory-ladder resolution (filled by compare()/resolve_tiers — the
    # analyzer itself is tier-agnostic): ECM memory term in seconds and
    # the slowest / home tier of the module's traffic on this machine.
    t_mem_tier: float | None = None
    bottleneck_tier: str | None = None
    home_tier: str | None = None

    @property
    def tp_incore_cycles(self) -> float:
        """OSACA semantics: the in-core bound assumes operands resident
        (L1 on CPU, VMEM on TPU) — memory/interconnect ports excluded."""
        vals = [c for p, c in self.port_occupation.items()
                if not _is_mem_port(p)]
        return max(vals) if vals else 0.0

    @property
    def bound_cycles(self) -> float:
        """ECM-style full bound: all ports + sequential loop floors."""
        return max(self.tp_cycles, self.serial_cycles)

    @property
    def bound_incore_cycles(self) -> float:
        """In-core bound: TP without memory ports vs the loop floors."""
        return max(self.tp_incore_cycles, self.serial_cycles)

    def seconds(self, machine: MachineModel) -> float:
        """Full ECM-style bound (all ports + loop floors) in seconds."""
        return self.bound_cycles / machine.clock_hz

    def seconds_incore(self, machine: MachineModel) -> float:
        """In-core bound (operands resident; no memory ports) in seconds."""
        return self.bound_incore_cycles / machine.clock_hz

    def tier_bound_seconds(self, machine: MachineModel) -> float:
        """Tier-resolved bound: in-core time vs the memory-ladder term.

        Falls back to the flat port-model bound when the tier fields
        have not been resolved (see `resolve_tiers`).
        """
        if self.t_mem_tier is None:
            return self.seconds(machine)
        return max(self.seconds_incore(machine), self.t_mem_tier)

    def bottleneck(self) -> str:
        """Dominant limiter: the busiest port, or 'LCD(serial)' when
        the sequential loop floors exceed every port."""
        if not self.port_occupation:
            return "none"
        if self.serial_cycles > self.tp_cycles:
            return "LCD(serial)"
        return max(self.port_occupation, key=self.port_occupation.get)


class Analyzer:
    """Analyzes one HLO module against one machine model.

    `machine` may be a MachineModel or the name of any registered machine
    (see repro.core.machine.register).
    """

    def __init__(self, machine, n_devices: int = 1):
        self.machine = get_machine(machine)
        self.n_devices = n_devices
        self._warned_classes: set = set()

    # -- public ------------------------------------------------------------
    def analyze_text(self, hlo_text: str) -> Report:
        """Parse (memoized) and analyze one compiled HLO text."""
        mod, trips = _parse_cached(hlo_text)
        return self.analyze_module(mod, trips)

    def analyze_module(self, mod: HloModule, trips: dict) -> Report:
        """Analyze an already-parsed module with explicit trip counts."""
        acc = _Acc()
        self._comp(mod, mod.entry, trips, acc, mult=1.0)
        tp = max(acc.ports.values()) if acc.ports else 0.0
        return Report(
            tp_cycles=tp, cp_cycles=acc.cp, serial_cycles=acc.serial,
            port_occupation=dict(acc.ports), flops=acc.flops,
            bytes_hbm=acc.bytes_hbm, coll_bytes=dict(acc.coll),
            n_instrs=acc.n, unknown_ops=acc.unknown,
            trips_seen=dict(acc.trips_seen),
            loop_bytes=dict(acc.loop_bytes),
            fallback_uops=acc.fallback)

    # -- internals ----------------------------------------------------------
    def _fallback_entry(self, cls: str):
        """Entry for a µ-op class the machine file does not cover.

        Prefers `vpu` (the historical fallback); a machine registered
        without one (e.g. injected straight into the MACHINES dict,
        bypassing validate_model) degrades to the cheapest available
        non-memory class instead of raising KeyError. Warns once per
        missing class per analyzer; occurrences are counted on the
        report (`Report.fallback_uops`).
        """
        entry = self.machine.table.get("vpu")
        if entry is None:
            cands = {c: e for c, e in self.machine.table.items()
                     if c not in ("dma", "ici")} or dict(self.machine.table)
            if not cands:
                raise KeyError(
                    f"machine {self.machine.name!r} has an empty µ-op table")
            entry = min(cands.values(), key=lambda e: e.cycles_per_unit)
        if cls not in self._warned_classes:
            self._warned_classes.add(cls)
            warnings.warn(
                f"machine {self.machine.name!r} has no entry for µ-op "
                f"class {cls!r}; degrading to the cheapest available "
                f"class (counted in Report.fallback_uops)",
                RuntimeWarning, stacklevel=3)
        return entry

    def _occupy(self, acc, cls: str, units: float, mult: float):
        entry = self.machine.table.get(cls)
        if entry is None:
            entry = self._fallback_entry(cls)
            acc.fallback += 1
        cyc = units * entry.cycles_per_unit * mult
        if entry.port_weights is None:
            share = cyc / len(entry.ports)
            for p in entry.ports:
                acc.ports[p] += share
        else:
            wsum = sum(entry.port_weights)
            for p, w in zip(entry.ports, entry.port_weights):
                acc.ports[p] += cyc * (w / wsum)
        return cyc

    _SLICE_LIKE = frozenset({"slice", "dynamic-slice", "gather"})
    _FUSIBLE = frozenset({"fusion", "reduce", "broadcast", "transpose",
                          "copy", "convert", "reshape", "bitcast"}) | \
        isa.CHEAP_EW | isa.XLU_OPS | isa.DIV_OPS

    def _internal_edges(self, comp) -> set:
        """Values that XLA:TPU would keep in VMEM: produced by a fusible
        op with ALL consumers fusible in the same computation. The CPU
        backend (which we parse) fuses at different granularity; without
        this projection scan-body elementwise chains are charged one HBM
        round-trip per op. Diamonds (<=4 fusible consumers, e.g. the
        online-softmax p -> {sum, dot}) fuse on TPU via producer
        duplication, so they are internal too (DESIGN.md §7)."""
        cons: dict = {}
        for i in comp.instrs:
            for o in i.operands:
                cons.setdefault(o, []).append(i)
        internal = set()
        for i in comp.instrs:
            if i.opcode not in self._FUSIBLE or i.is_root:
                continue
            if len(i.shapes) != 1:
                continue
            cs = cons.get(i.name, [])
            if not cs or len(cs) > 4:
                continue
            # NOTE: a `dot` consumer does NOT make an edge internal — MXU
            # operands are materialized (that is exactly what the Pallas
            # flash kernel eliminates, see EXPERIMENTS.md §Perf).
            if all(c.opcode in self._FUSIBLE for c in cs):
                internal.add(i.name)
        return internal

    def _hbm_bytes(self, mod, instr: Instr, shapes_of,
                   internal: set = frozenset()) -> float:
        """HBM traffic of one op boundary, slice-aware: a (dynamic-)slice
        or gather reads only the slice, not its (possibly scan-stacked)
        operand; a dynamic-update-slice touches only the update region."""
        op = instr.opcode
        res = sum(s.bytes for s in instr.shapes)
        if instr.name in internal:
            res = 0.0           # stays in VMEM (fused into its consumer)
        if op == "convert":
            return 0.0          # native-bf16 projection (see fusion case)
        if op in self._SLICE_LIKE:
            return 2.0 * res
        if op in ("dynamic-update-slice", "scatter"):
            upd = shapes_of.get(instr.operands[1]) \
                if len(instr.operands) > 1 else None
            ub = upd.bytes if upd is not None else res
            return 2.0 * ub

        def op_bytes(opnd: str) -> float:
            if opnd in internal:
                return 0.0
            s = shapes_of.get(opnd)
            return float(s.bytes) if s is not None else 0.0

        if op == "fusion":
            body = mod.computations.get(instr.attr_comp("calls") or "")
            total = float(res)
            if body is None:
                return total + sum(op_bytes(o) for o in instr.operands)
            # fusion rooted in a dynamic-update-slice updates in place:
            # traffic = the update region, not the full carried buffer
            by_name = body.by_name()
            root = body.root
            for _ in range(4):      # unwrap trivial roots (incl. the
                # XLA:CPU float-normalization converts, DESIGN.md §7)
                if root.opcode in ("bitcast", "copy", "reshape",
                                   "transpose", "convert") and root.operands:
                    nxt = by_name.get(root.operands[0])
                    if nxt is None:
                        break
                    root = nxt
                else:
                    break
            # pure dtype-convert fusion: does not exist on native-bf16 TPUs
            # (CPU backend upcasts bf16 ops to f32 and materializes copies)
            if body.root.opcode == "convert" and root.opcode == "parameter":
                return 0.0
            dus_root = False
            res_elems = sum(s.elems for s in instr.shapes)
            if root.opcode == "dynamic-update-slice" and res > 0:
                dus_root = True
                b_shapes = {i.name: i.shape for i in body.instrs}
                upd = b_shapes.get(root.operands[1]) \
                    if len(root.operands) > 1 else None
                if upd is not None:
                    total = 2.0 * upd.bytes
            params = _params_in_order(body)
            for idx, opnd in enumerate(instr.operands):
                if dus_root:
                    # in-place update fusion: any operand with the target
                    # buffer's element count is a (possibly dtype-
                    # normalized) version of the buffer being updated —
                    # physically only the update region is touched.
                    s_op = shapes_of.get(opnd)
                    if s_op is not None and s_op.elems == res_elems:
                        continue
                full = op_bytes(opnd)
                pname = params[idx].name if idx < len(params) else None
                if pname is None or full == 0.0:
                    total += full
                    continue
                cons = [i for i in body.instrs if pname in i.operands]
                if cons and all(c.opcode in self._SLICE_LIKE for c in cons):
                    total += sum(sum(sh.bytes for sh in c.shapes)
                                 for c in cons)
                else:
                    total += full
            return total
        return float(res) + sum(op_bytes(o) for o in instr.operands)

    def _instr_cost(self, mod, instr: Instr, shapes_of, trips, acc,
                    mult: float) -> float:
        """Occupies ports; returns this instruction's own min-cycles
        (used for CP/LCD edge weights)."""
        op = instr.opcode
        if op == "fusion":
            body = mod.computations.get(instr.attr_comp("calls") or "")
            own = 0.0
            if body is not None:
                own = self._comp(mod, body, trips, acc, mult,
                                 hbm_boundary=False)
            return own
        if op in ("while",):
            body = mod.computations.get(instr.attr_comp("body") or "")
            n = while_trip_count(mod, instr, trips)
            acc.trips_seen[instr.name] = n
            if body is None:
                return 0.0
            sub = _Acc()
            body_cp = self._comp(mod, body, trips, sub, 1.0)
            body_tp = max((c for p, c in sub.ports.items()
                           if not _is_mem_port(p)), default=0.0)
            floor = n * max(body_tp, body_cp, sub.serial)
            # merge: occupation scaled by trips
            for p, c in sub.ports.items():
                acc.ports[p] += c * n * mult
            acc.flops += sub.flops * n * mult
            acc.bytes_hbm += sub.bytes_hbm * n * mult
            for k, v in sub.coll.items():
                acc.coll[k] += v * n * mult
            acc.n += sub.n
            acc.unknown += sub.unknown
            acc.fallback += sub.fallback
            acc.serial += floor * mult
            acc.trips_seen.update(sub.trips_seen)
            acc.loop_bytes.update(sub.loop_bytes)
            acc.loop_bytes[instr.name] = (n, sub.bytes_hbm, sub.flops)
            return floor
        if op in ("conditional", "call", "async-start"):
            tgt = instr.attr_comp("calls") or instr.attr_comp("to_apply")
            body = mod.computations.get(tgt or "")
            if body is not None:
                return self._comp(mod, body, trips, acc, mult,
                                  hbm_boundary=False)
            return 0.0

        u = isa.decompose(instr, shapes_of, self.n_devices)
        own = 0.0
        for cls, units in u.uops:
            cyc = self._occupy(acc, cls, units, mult) / mult
            if cls not in ("dma", "ici"):
                own += cyc      # CP/LCD chains are in-core (prefetchable
                                # memory traffic is not a dependency)
        acc.flops += u.flops * mult
        if u.coll_bytes:
            acc.coll[u.coll_kind] += u.coll_bytes * mult
        acc.n += 1
        acc.unknown += int(u.unknown)
        return own

    def _comp(self, mod, comp: Computation, trips, acc, mult: float,
              hbm_boundary: bool = True) -> float:
        """Analyze a computation; returns its CP length (cycles)."""
        shapes_of = {i.name: i.shape for i in comp.instrs}
        internal = self._internal_edges(comp) if hbm_boundary else frozenset()
        # union cap: N slices of one source stream the source once
        slice_budget: dict = {}
        # carry double-buffer copies feeding only the root tuple are
        # removed by XLA copy elision -> free
        n_cons: dict = {}
        for i in comp.instrs:
            for o in i.operands:
                n_cons[o] = n_cons.get(o, 0) + 1
        root = comp.root
        elided = {
            i.name for i in comp.instrs
            if i.opcode == "copy" and n_cons.get(i.name, 0) <= 1 and
            root.opcode == "tuple" and i.name in root.operands}

        depth: dict = {}
        cp = 0.0
        for instr in comp.instrs:
            if instr.name in elided:     # alias-elided carry copy: free
                d = max((depth.get(o, 0.0) for o in instr.operands),
                        default=0.0)
                depth[instr.name] = d
                continue
            own = self._instr_cost(mod, instr, shapes_of, trips, acc, mult)
            lat = self._latency(instr, own)
            d = lat + max((depth.get(o, 0.0) for o in instr.operands),
                          default=0.0)
            depth[instr.name] = d
            cp = max(cp, d)
            if hbm_boundary and instr.opcode != "while" and \
                    instr.opcode not in isa.FREE_OPS:
                b = self._hbm_bytes(mod, instr, shapes_of, internal)
                if instr.opcode in self._SLICE_LIKE and instr.operands:
                    src = instr.operands[0]
                    s = shapes_of.get(src)
                    if s is not None:
                        left = slice_budget.setdefault(src, float(s.bytes))
                        read = min(b / 2.0, left)
                        slice_budget[src] = left - read
                        b = read + b / 2.0        # capped read + write
                acc.bytes_hbm += b * mult
                self._occupy(acc, "dma", b, mult)
        acc.cp = max(acc.cp, cp)
        return cp

    def _latency(self, instr: Instr, own_cycles: float) -> float:
        if instr.opcode in ("while", "fusion"):
            base = 0.0
        else:
            cls = ("mxu" if instr.opcode == "dot" else
                   "xlu" if instr.opcode in isa.XLU_OPS else
                   "vdiv" if instr.opcode in isa.DIV_OPS else "vpu")
            entry = self.machine.table.get(cls)
            if entry is None:
                entry = self._fallback_entry(cls)
            base = entry.latency
        if instr.opcode in isa.FREE_OPS:
            base = 0.0
        # a consumer needing the full result also waits for throughput
        return base + own_cycles


class _Acc:
    def __init__(self):
        self.ports = defaultdict(float)
        self.flops = 0.0
        self.bytes_hbm = 0.0
        self.coll = defaultdict(float)
        self.n = 0
        self.unknown = 0
        self.fallback = 0
        self.serial = 0.0
        self.cp = 0.0
        self.trips_seen = {}
        self.loop_bytes = {}


@functools.lru_cache(maxsize=4)
def _parse_cached(hlo_text: str) -> tuple:
    """Memoized (module, trip-counts) for one HLO text.

    The parse products are read-only after construction, so one parse can
    be shared by every machine in a `compare()` fan-out (and by repeated
    `analyze()` calls on the same text). Deliberately small: each entry
    pins the raw HLO text plus its parse tree for the process lifetime."""
    return parse_hlo(hlo_text), trip_counts_from_text(hlo_text)


def analyze(hlo_text: str, machine, n_devices: int = 1) -> Report:
    """Analyze one HLO text on one machine (name or MachineModel)."""
    return Analyzer(machine, n_devices).analyze_text(hlo_text)


def resolve_tiers(report: Report, machine) -> Report:
    """Fill a report's memory-ladder fields against one machine.

    Resolves the report's trip-multiplied HBM/DRAM traffic through the
    machine's MemTier ladder (core/memtier.py) and writes `t_mem_tier`,
    `bottleneck_tier`, and `home_tier` in place (returning the report
    for chaining). The working set is approximated by the traffic
    itself — whole-module analyses land on the backing tier, which is
    the flat pre-ladder behaviour.
    """
    from repro.core import memtier  # local: memtier imports machine too

    model = get_machine(machine)
    res = memtier.memory_seconds(model, report.bytes_hbm,
                                 cores_active=model.cores or 1)
    report.t_mem_tier = res.seconds
    report.bottleneck_tier = res.bottleneck_tier
    report.home_tier = res.home
    return report


#: HLO text of the in-flight compare() fan-out, set once per worker by the
#: pool initializer so per-task IPC ships only the (small) machine model.
_WORKER_HLO: str | None = None


def _pool_init(hlo_text: str) -> None:
    global _WORKER_HLO
    _WORKER_HLO = hlo_text


def _compare_worker(model, n_devices: int) -> Report:
    """One machine's analysis, run in a pool worker process.

    With the (default on Linux) fork start method the parent's memoized
    parse (`_parse_cached`) is inherited copy-on-write, so workers skip
    re-parsing; under spawn they re-parse once per process — correct,
    just slower.
    """
    rep = Analyzer(model, n_devices).analyze_text(_WORKER_HLO)
    return resolve_tiers(rep, model)


def compare(hlo_text: str, machines=None, n_devices: int = 1,
            max_workers: int | None = None, parallel: str = "auto") -> dict:
    """Analyze one HLO module across several registered machines.

    `machines`: iterable of names and/or MachineModels; defaults to every
    registered machine. The module is parsed once (memoized) and every
    report comes back with its memory-ladder fields resolved
    (`resolve_tiers`), so callers can read the tier-resolved bound
    (`Report.tier_bound_seconds`) and bottleneck tier directly. Returns
    {machine name: Report} preserving the requested order.

    The analyses are pure Python, so the fan-out runs on a **process**
    pool (a thread pool would be GIL-bound — its own docstring used to
    concede it bought almost nothing). `parallel`: "auto" (pool when the
    estimated analysis work amortizes the fork/IPC overhead, fork is
    available, and the models pickle), "serial" (in-process loop), or
    "process" (force the pool). Ad-hoc unpicklable models and pool
    failures degrade to the serial loop, so results never depend on the
    execution mode.
    """
    if machines is None:
        machines = registered_names()
    models = [get_machine(m) for m in machines]
    mod, trips = _parse_cached(hlo_text)

    def run_serial():
        out = []
        for model in models:
            rep = Analyzer(model, n_devices).analyze_module(mod, trips)
            out.append(resolve_tiers(rep, model))
        return out

    workers = min(max_workers or 8, len(models),
                  max(1, os.cpu_count() or 1))
    # ~17 µs/instr·machine analysis vs a few hundred ms of pool setup:
    # the pool only pays off when the serial fan-out is >~ 1 s of work
    n_instr = sum(len(c.instrs) for c in mod.computations.values())
    big_enough = n_instr * len(models) > 50_000
    use_pool = parallel == "process" or (
        parallel == "auto" and workers > 1 and big_enough
        and "fork" in multiprocessing.get_all_start_methods())
    if use_pool:
        try:
            pickle.dumps(models)
        except Exception:
            use_pool = False        # ad-hoc model: serial fallback
    reports = None
    if use_pool:
        try:
            ctx = multiprocessing.get_context("fork")
            with warnings.catch_warnings():
                # the workers never touch XLA; silence jax's blanket
                # fork-after-threads warning for this short-lived pool
                warnings.filterwarnings(
                    "ignore", message=".*os.fork.*", category=RuntimeWarning)
                with ProcessPoolExecutor(max_workers=workers,
                                         mp_context=ctx,
                                         initializer=_pool_init,
                                         initargs=(hlo_text,)) as ex:
                    chunk = max(1, len(models) // workers)
                    reports = list(ex.map(
                        _compare_worker, models,
                        [n_devices] * len(models), chunksize=chunk))
        except Exception:
            reports = None          # broken pool: serial fallback
    if reports is None:
        reports = run_serial()
    return {m.name: r for m, r in zip(models, reports)}
