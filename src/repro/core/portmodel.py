"""Frontend of the in-core prediction engine.

The analysis stack is a pipeline (DESIGN.md §3):

    hloparse -> trace.lower (machine-independent µ-op trace IR, once
    per module) -> a scheduling backend per (machine, backend) pair
    (core/backends/: analytical ``tp_bound``, simulated ``mca_sched``)
    -> Report (core/report.py) -> resolve_tiers (memory ladder).

This module is the thin entry point everything downstream uses:
``analyze`` (one machine, one backend), ``compare`` (fan one module's
trace across machines x backends on a process pool), and
``resolve_tiers`` (fill a report's memory-ladder fields). The heavy
lifting lives in ``repro.core.trace`` and ``repro.core.backends``.
"""

from __future__ import annotations

import dataclasses
import functools
import multiprocessing
import os
import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor

from repro.core import backends as backends_lib
from repro.core import trace as trace_lib
from repro.core.backends.mca_sched import McaSchedBackend
from repro.core.backends.tp_bound import TpBoundBackend
from repro.core.hloparse import parse_hlo, trip_counts_from_text
from repro.core.machine import get_machine, registered_names
from repro.core.report import Report  # noqa: F401  (public re-export)


@functools.lru_cache(maxsize=4)
def _parse_cached(hlo_text: str) -> tuple:
    """Memoized (module, trip-counts) for one HLO text.

    The parse products are read-only after construction, so one parse can
    be shared by every machine in a `compare()` fan-out (and by repeated
    `analyze()` calls on the same text). Deliberately small: each entry
    pins the raw HLO text plus its parse tree for the process lifetime."""
    return parse_hlo(hlo_text), trip_counts_from_text(hlo_text)


@functools.lru_cache(maxsize=4)
def _trace_cached(hlo_text: str, n_devices: int) -> trace_lib.Trace:
    """Memoized lowered trace for one HLO text.

    Decomposition (µ-ops, HBM byte math, loop structure) is machine-
    independent, so one lowering serves every (machine, backend) pair
    of a ``compare()`` fan-out — the old analyzer re-decomposed once
    per machine."""
    mod, trips = _parse_cached(hlo_text)
    return trace_lib.lower(mod, trips, n_devices)


class Analyzer:
    """Analyzes HLO against one machine model with one backend.

    Compatibility wrapper over the trace/backend pipeline: `machine`
    may be a MachineModel or the name of any registered machine, and
    `backend` any registered backend name or alias (``tp``/``mca``).
    """

    def __init__(self, machine, n_devices: int = 1,
                 backend="tp_bound"):
        self.machine = get_machine(machine)
        self.n_devices = n_devices
        self.backend = backends_lib.get_backend(backend)

    def analyze_text(self, hlo_text: str) -> Report:
        """Parse + lower (memoized) and analyze one compiled HLO text."""
        return self.backend.run(_trace_cached(hlo_text, self.n_devices),
                                self.machine)

    def analyze_module(self, mod, trips: dict) -> Report:
        """Analyze an already-parsed module with explicit trip counts."""
        tr = trace_lib.lower(mod, trips, self.n_devices)
        return self.backend.run(tr, self.machine)


def analyze(hlo_text: str, machine, n_devices: int = 1,
            backend="tp_bound") -> Report:
    """Analyze one HLO text on one machine (name or MachineModel) with
    one scheduling backend (name, alias, or Backend instance)."""
    return Analyzer(machine, n_devices, backend).analyze_text(hlo_text)


def resolve_tiers(report: Report, machine) -> Report:
    """Fill a report's memory-ladder fields against one machine.

    Resolves the report's trip-multiplied HBM/DRAM traffic through the
    machine's MemTier ladder (core/memtier.py) and writes `t_mem_tier`,
    `bottleneck_tier`, and `home_tier` in place (returning the report
    for chaining). The working set is approximated by the traffic
    itself — whole-module analyses land on the backing tier, which is
    the flat pre-ladder behaviour.
    """
    from repro.core import memtier  # local: memtier imports machine too

    model = get_machine(machine)
    res = memtier.memory_seconds(model, report.bytes_hbm,
                                 cores_active=model.cores or 1)
    report.t_mem_tier = res.seconds
    report.bottleneck_tier = res.bottleneck_tier
    report.home_tier = res.home
    return report


#: HLO text of the in-flight compare() fan-out, set once per worker by the
#: pool initializer so per-task IPC ships only the (small) machine model.
_WORKER_HLO: str | None = None


def _pool_init(hlo_text: str) -> None:
    global _WORKER_HLO
    _WORKER_HLO = hlo_text


def _compare_worker(model, backend, n_devices: int) -> Report:
    """One (machine, backend) analysis, run in a pool worker process.

    ``backend`` is the Backend *instance* (pickled per task), so ad-hoc
    instances with custom configuration run as-is — never swapped for
    the registry's default. With the (default on Linux) fork start
    method the parent's memoized trace (`_trace_cached`) is inherited
    copy-on-write, so workers skip re-lowering; under spawn they lower
    once per process — correct, just slower. Degradation warnings are
    suppressed here and re-raised once by the parent (``compare``) from
    the returned counts, so a missing µ-op class warns once per fan-out
    instead of once per worker.
    """
    tr = _trace_cached(_WORKER_HLO, n_devices)
    rep = backend.run(tr, model, warn=False)
    return resolve_tiers(rep, model)


def _warn_degraded_once(tasks, reports) -> None:
    """Single parent-side warning for µ-op-class degradation.

    Workers (and the serial loop) analyze with warnings suppressed and
    route occurrences through ``Report.fallback_uops`` /
    ``fallback_classes``; this aggregates them so one fan-out warns
    once, not once per (machine, backend, process)."""
    degraded: dict = {}
    total = 0
    for (model, _bname), rep in zip(tasks, reports):
        if rep.fallback_uops:
            total += rep.fallback_uops
            degraded.setdefault(model.name, set()).update(
                rep.fallback_classes)
    if not degraded:
        return
    detail = "; ".join(f"{m}: missing {sorted(cs)}"
                       for m, cs in degraded.items())
    warnings.warn(
        f"{total} µ-ops degraded to fallback classes during compare() "
        f"({detail}); counts are on Report.fallback_uops",
        RuntimeWarning, stacklevel=3)


def compare(hlo_text: str, machines=None, n_devices: int = 1,
            max_workers: int | None = None, parallel: str = "auto",
            backends=None) -> dict:
    """Analyze one HLO module across machines (and backends).

    `machines`: iterable of names and/or MachineModels; defaults to every
    registered machine. The module is parsed and lowered to the µ-op
    trace IR exactly once (memoized); every (machine, backend) pair
    replays that trace, and every report comes back with its
    memory-ladder fields resolved (`resolve_tiers`), so callers can
    read the tier-resolved bound (`Report.tier_bound_seconds`) and
    bottleneck tier directly.

    `backends`: None or a single name keeps the legacy shape
    ``{machine name: Report}`` (default backend: the analytical
    ``tp_bound``). An iterable of names returns ``{machine name:
    {backend name: Report}}`` — e.g. ``backends=("tp", "mca")`` for
    the paper's OSACA-vs-MCA comparison. Order is preserved.

    The analyses are pure Python, so the fan-out runs on a **process**
    pool. `parallel`: "auto" (pool when the estimated analysis work
    amortizes the fork/IPC overhead, fork is available, and the models
    pickle), "serial" (in-process loop), or "process" (force the pool).
    Ad-hoc unpicklable models and pool failures degrade to the serial
    loop, so results never depend on the execution mode. Missing µ-op
    classes warn once here in the parent, not once per worker.
    """
    if machines is None:
        machines = registered_names()
    models = [get_machine(m) for m in machines]
    flat = backends is None or isinstance(backends, str) or \
        isinstance(backends, backends_lib.Backend)
    bspecs = ["tp_bound"] if backends is None else \
        ([backends] if flat else list(backends))
    # resolve to instances (names/aliases via the registry, instances
    # pass through untouched) and dedupe on the canonical name so
    # alias + canonical spellings don't double the fan-out
    bobjs, _seen = [], set()
    for b in bspecs:
        obj = backends_lib.get_backend(b)
        if obj.name not in _seen:
            _seen.add(obj.name)
            bobjs.append(obj)
    # the stock simulator runs the full analytical walk first and keeps
    # its fields intact, so an mca_sched report *contains* the tp_bound
    # one — when both stock engines are requested, run only the
    # simulator tasks and derive the tp reports (half the walks on the
    # documented OSACA-vs-MCA fan-out)
    by_name = {b.name: b for b in bobjs}
    derive_tp = (not flat and {"tp_bound", "mca_sched"} <= set(by_name)
                 and type(by_name["tp_bound"]) is TpBoundBackend
                 and isinstance(by_name["mca_sched"], McaSchedBackend))
    run_objs = [b for b in bobjs if b.name != "tp_bound"] \
        if derive_tp else bobjs
    tasks = [(model, obj) for model in models for obj in run_objs]
    tr = _trace_cached(hlo_text, n_devices)

    def run_serial():
        out = []
        for model, obj in tasks:
            rep = obj.run(tr, model, warn=False)
            out.append(resolve_tiers(rep, model))
        return out

    workers = min(max_workers or 8, len(tasks),
                  max(1, os.cpu_count() or 1))
    # ~17 µs/instr·machine analysis vs a few hundred ms of pool setup:
    # the pool only pays off when the serial fan-out is >~ 1 s of work
    big_enough = tr.n_ops() * len(tasks) > 50_000
    use_pool = parallel == "process" or (
        parallel == "auto" and workers > 1 and big_enough
        and "fork" in multiprocessing.get_all_start_methods())
    if use_pool:
        try:
            pickle.dumps((models, bobjs))
        except Exception:
            use_pool = False    # ad-hoc model/backend: serial fallback
    reports = None
    if use_pool:
        try:
            ctx = multiprocessing.get_context("fork")
            with warnings.catch_warnings():
                # the workers never touch XLA; silence jax's blanket
                # fork-after-threads warning for this short-lived pool
                warnings.filterwarnings(
                    "ignore", message=".*os.fork.*", category=RuntimeWarning)
                with ProcessPoolExecutor(max_workers=workers,
                                         mp_context=ctx,
                                         initializer=_pool_init,
                                         initargs=(hlo_text,)) as ex:
                    chunk = max(1, len(tasks) // workers)
                    reports = list(ex.map(
                        _compare_worker,
                        [m for m, _ in tasks], [b for _, b in tasks],
                        [n_devices] * len(tasks), chunksize=chunk))
        except Exception:
            reports = None          # broken pool: serial fallback
    if reports is None:
        reports = run_serial()
    _warn_degraded_once(tasks, reports)
    if flat:
        return {m.name: r for (m, _), r in zip(tasks, reports)}
    got = {(m.name, b.name): r for (m, b), r in zip(tasks, reports)}
    out: dict = {m.name: {} for m in models}
    for m in models:
        for b in bobjs:             # preserve the requested order
            if derive_tp and b.name == "tp_bound":
                out[m.name][b.name] = _derive_tp_report(
                    got[(m.name, "mca_sched")])
            else:
                out[m.name][b.name] = got[(m.name, b.name)]
    return out


def _derive_tp_report(mca_rep: Report) -> Report:
    """The tp_bound Report contained in a stock mca_sched Report.

    The simulator's analytic fields come from the same walk a tp_bound
    run would do (pinned equal by tests/test_trace_backends.py);
    clearing ``sim_cycles`` restores the analytical accessors. Dict
    fields are copied so the two reports never share mutable state.
    """
    return dataclasses.replace(
        mca_rep, backend="tp_bound", sim_cycles=None,
        port_occupation=dict(mca_rep.port_occupation),
        coll_bytes=dict(mca_rep.coll_bytes),
        trips_seen=dict(mca_rep.trips_seen),
        loop_bytes=dict(mca_rep.loop_bytes),
        fallback_classes=tuple(mca_rep.fallback_classes))
