"""Microbenchmark-driven machine-model calibration (paper §II).

ibench-style methodology: each op class is measured with a dependency-
chained loop (x = op(x, b)) over an L1-resident working set inside one
jit — dispatch overhead amortizes over K chained iterations and the chain
pins the op on its functional unit, exactly how the paper's
microbenchmarks extract per-instruction throughput. Streaming (DMA-class)
bandwidth is measured separately on a memory-sized copy.

The TPU machine files are spec-derived (no TPU in this container —
DESIGN.md §7); the host model produced here drives the RPE validation
(core/rpe.py, paper Fig. 3).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.machine import MachineModel, host_cpu_model, register
from repro.utils.hw import MemTier

N_SMALL = 8192             # 32 KiB f32 — L1/L2-resident (in-core regime)
N_BIG = 1 << 23            # 32 MiB — memory regime (DMA class)
MAT = 512
K_CHAIN = 256

#: (tier name, elements, declared capacity) for the cache-ladder sweep.
#: Working sets are sized to sit comfortably inside each level on any
#: recent x86/ARM host; the declared capacity is what the resolved
#: MemTier publishes (the level boundary, not the probe size).
TIER_PROBES = (
    ("L1", 1 << 13, 128e3),      # 32 KiB probe in a <=128 KiB L1
    ("L2", 1 << 16, 2e6),        # 256 KiB probe in a <=2 MiB L2
    ("L3", 1 << 20, 24e6),       # 4 MiB probe in a <=24 MiB L3 slice
)


def _chain(op, n_iter):
    def f(x, *consts):
        def body(_, x):
            return op(x, *consts)
        return jax.lax.fori_loop(0, n_iter, body, x)
    return jax.jit(f)


def _timeit(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_host_rates(n: int = N_SMALL) -> dict:
    """Measure per-class unit rates + the cache ladder on this host.

    Returns {µ-op class: units/second} ready for `host_cpu_model`, plus
    a `_raw` sub-dict with the underlying timings, peak numbers, and
    the measured `mem_tiers` MemTier ladder.
    """
    key = jax.random.PRNGKey(0)
    a = jnp.abs(jax.random.normal(key, (n,), jnp.float32)) + 0.5
    b = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,),
                                  jnp.float32)) + 0.5
    idx = jax.random.permutation(jax.random.PRNGKey(3), n)
    m1 = jax.random.normal(key, (MAT, MAT), jnp.float32) * 0.01
    big = jax.random.normal(key, (N_BIG,), jnp.float32)

    t_add = _timeit(_chain(lambda x, c: x + c, K_CHAIN), a, b) / K_CHAIN
    t_fma = _timeit(_chain(lambda x, c: x * 0.999 + c, K_CHAIN),
                    a, b) / K_CHAIN
    t_div = _timeit(_chain(lambda x, c: c / (x + 1.0), K_CHAIN),
                    a, b) / K_CHAIN
    t_exp = _timeit(_chain(lambda x: jnp.exp(-x), K_CHAIN), a) / K_CHAIN
    t_gat = _timeit(_chain(lambda x, i: x[i], K_CHAIN), a, idx) / K_CHAIN
    t_mov = _timeit(_chain(lambda x: jnp.roll(x, 1), K_CHAIN), a) / K_CHAIN
    t_mm = _timeit(_chain(lambda x, m: x @ m, 8), m1, m1) / 8
    t_cp = _timeit(jax.jit(lambda x: x + 0.0), big)
    t_tr = _timeit(jax.jit(lambda x, y: x + 2.0 * y), big, big * 0.5)

    # memory-tier calibration (ECM ladder): a chained streaming add
    # (2 reads + 1 write per element) at per-level working sets gives
    # each level's combined sustained bandwidth; loads and stores split
    # it 2:1, matching the kernel's access mix. The measured rates
    # already include whatever write-allocate traffic the host really
    # generates, so the resolved tiers carry wa_residue=0 — charging a
    # modeled allocate on top would double-count it (core/memtier.py).
    tiers = []
    for tname, n_t, cap in TIER_PROBES:
        at = jnp.abs(jax.random.normal(key, (n_t,), jnp.float32)) + 0.5
        bt = at * 0.5
        reps = max(16, K_CHAIN // max(1, n_t // 8192))
        t = _timeit(_chain(lambda x, c: x + c, reps), at, bt) / reps
        bw = 3 * 4 * n_t / t                   # 2 reads + 1 write
        tiers.append(MemTier(tname, cap, load_bw=bw * 2 / 3,
                             store_bw=bw / 3, shared_bw=0.0,
                             wa_residue=0.0))
    dram_bw = max(2 * 4 * N_BIG / t_cp, 3 * 4 * N_BIG / t_tr)
    tiers.append(MemTier("DRAM", float("inf"), load_bw=dram_bw * 2 / 3,
                         store_bw=dram_bw / 3, shared_bw=dram_bw,
                         wa_residue=0.0))
    # drop inverted levels (noisy containers can measure an outer level
    # faster than an inner one): keep the ladder monotone in bandwidth
    mono = []
    for t in tiers:
        while mono and mono[-1].load_bw < t.load_bw:
            mono.pop()
        mono.append(t)
    tiers = mono

    blocks = n / (8 * 128)
    mxu_passes = (MAT / 128) ** 3
    return {
        "vpu": blocks / t_fma,
        "xlu": blocks / t_exp,
        "vdiv": blocks / t_div,
        "vlsu": blocks / t_mov,
        "gather4": blocks / t_gat,
        "mxu": mxu_passes / t_mm,
        "dma": dram_bw,
        "sc": 1e9,
        "_raw": {"add_s": t_add, "fma_s": t_fma, "div_s": t_div,
                 "exp_s": t_exp, "gather_s": t_gat, "move_s": t_mov,
                 "matmul_s": t_mm, "copy_big_s": t_cp,
                 "flops_matmul": 2 * MAT ** 3 / t_mm,
                 "stream_bw": dram_bw,
                 "mem_tiers": tiers},
    }


_CAL_CACHE: dict = {}


def calibrated_host_model(refresh: bool = False) -> MachineModel:
    """Measure this host and publish the result into the machine registry
    (as `host_cpu`), so compare()/Analyzer can address it by name. The
    registered model carries the measured MemTier cache ladder, so the
    tier resolver (core/memtier.py) works on `host_cpu` like on the
    paper CPUs."""
    if "model" not in _CAL_CACHE or refresh:
        rates = measure_host_rates()
        raw = rates.pop("_raw")
        m = register(host_cpu_model(rates, mem_tiers=raw["mem_tiers"]),
                     replace=True)
        _CAL_CACHE["model"] = m
        _CAL_CACHE["raw"] = raw
    return _CAL_CACHE["model"]


def host_peaks() -> tuple:
    """(peak_flops, mem_bw) for the naive-baseline model on this host."""
    calibrated_host_model()
    raw = _CAL_CACHE["raw"]
    return raw["flops_matmul"], raw["stream_bw"]


def mem_tiers() -> tuple:
    """Measured MemTier ladder of this host, innermost first, DRAM last."""
    return tuple(calibrated_host_model().mem_tiers)


def tier_bw(ws_bytes: float) -> float:
    """Combined sustained bytes/s at the tier a working set resolves to.

    Kept as the historical scalar interface (rpe.py's ECM memory term,
    examples/quickstart.py); resolution semantics are memtier's
    (`resolve_home`), per-leg composition lives in
    `repro.core.memtier.transfer_time`.
    """
    from repro.core import memtier
    t = memtier.resolve_home(mem_tiers(), ws_bytes)
    return t.load_bw + t.store_bw
