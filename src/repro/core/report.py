"""The analysis result record shared by every scheduling backend.

A :class:`Report` is what ``portmodel.analyze`` / ``compare`` return:
TP/CP/LCD cycles, per-port occupation, trip-multiplied traffic
accounting, and (once resolved) the memory-ladder fields. Since the
backend split it also carries which engine produced it (``backend``)
and, for cycle-simulator backends, the simulated in-core makespan
(``sim_cycles``) — the per-backend accessors (:attr:`incore_cycles`
and the bounds built on it) resolve to whichever estimate the backend
filled, so downstream consumers (serve planner, roofline, benchmarks)
are backend-agnostic.

Defined in its own module so the backends can construct Reports
without importing the ``portmodel`` frontend (which imports them).
"""

from __future__ import annotations

import dataclasses

from repro.core.machine import MachineModel

_MEM_PORTS = ("DMA", "ICI", "MEM")


def is_mem_port(p: str) -> bool:
    """True for off-core ports (memory / interconnect interfaces)."""
    return p.startswith(_MEM_PORTS)


@dataclasses.dataclass
class Report:
    """Result of analyzing one HLO module on one machine with one
    scheduling backend (see the module docstring)."""

    tp_cycles: float              # max per-port occupation (incl. DMA/ICI)
    cp_cycles: float              # latency-critical path (in-core)
    serial_cycles: float          # sum of sequential loop floors
    port_occupation: dict         # port -> cycles
    flops: float
    bytes_hbm: float
    coll_bytes: dict              # kind -> wire bytes
    n_instrs: int
    unknown_ops: int
    trips_seen: dict              # loop name -> trips
    loop_bytes: dict = dataclasses.field(default_factory=dict)
    # loop name -> (trips, bytes/iter, flops/iter) for bottleneck attribution
    # µ-ops whose class had no machine-file entry and were degraded to the
    # cheapest available class (see backends.tp_bound)
    fallback_uops: int = 0
    # names of the µ-op classes that were degraded (for the one-shot
    # warning compare() emits in the parent process)
    fallback_classes: tuple = ()
    # which scheduling backend produced this report
    backend: str = "tp_bound"
    # cycle-simulator backends: simulated in-core makespan (dispatch
    # stalls + port contention + dep latencies); None for analytical
    # backends, whose in-core estimate is the TP bound
    sim_cycles: float | None = None
    # memory-ladder resolution (filled by compare()/resolve_tiers — the
    # backends themselves are tier-agnostic): ECM memory term in seconds
    # and the slowest / home tier of the module's traffic on this machine.
    t_mem_tier: float | None = None
    bottleneck_tier: str | None = None
    home_tier: str | None = None

    @property
    def tp_incore_cycles(self) -> float:
        """OSACA semantics: the in-core bound assumes operands resident
        (L1 on CPU, VMEM on TPU) — memory/interconnect ports excluded."""
        vals = [c for p, c in self.port_occupation.items()
                if not is_mem_port(p)]
        return max(vals) if vals else 0.0

    @property
    def incore_cycles(self) -> float:
        """Backend-resolved in-core estimate: the simulated makespan
        when this report came from a cycle simulator, else the
        analytical TP lower bound."""
        if self.sim_cycles is not None:
            return self.sim_cycles
        return self.tp_incore_cycles

    @property
    def bound_cycles(self) -> float:
        """ECM-style full bound: all ports + sequential loop floors
        (+ the simulated in-core makespan for simulator backends)."""
        return max(self.tp_cycles, self.incore_cycles, self.serial_cycles)

    @property
    def bound_incore_cycles(self) -> float:
        """In-core bound: the backend's in-core estimate vs the loop
        floors (no memory ports)."""
        return max(self.incore_cycles, self.serial_cycles)

    def seconds(self, machine: MachineModel) -> float:
        """Full ECM-style bound (all ports + loop floors) in seconds."""
        return self.bound_cycles / machine.clock_hz

    def seconds_incore(self, machine: MachineModel) -> float:
        """In-core bound (operands resident; no memory ports) in seconds."""
        return self.bound_incore_cycles / machine.clock_hz

    def tier_bound_seconds(self, machine: MachineModel) -> float:
        """Tier-resolved bound: in-core time vs the memory-ladder term.

        Falls back to the flat port-model bound when the tier fields
        have not been resolved (see `portmodel.resolve_tiers`).
        """
        if self.t_mem_tier is None:
            return self.seconds(machine)
        return max(self.seconds_incore(machine), self.t_mem_tier)

    def bottleneck(self) -> str:
        """Dominant limiter: the busiest port, or 'LCD(serial)' when
        the sequential loop floors exceed every port."""
        if not self.port_occupation:
            return "none"
        if self.serial_cycles > self.tp_cycles:
            return "LCD(serial)"
        return max(self.port_occupation, key=self.port_occupation.get)
