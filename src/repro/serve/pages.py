"""Paged KV cache: a fixed pool of physical pages, per-slot block
tables, refcounted prefix sharing, and copy-on-write.

The dense engine (repro.serve.engine) preallocates every slot at the
full decode horizon, so memory scales with ``slots x horizon`` no
matter how short the live requests are, and every admission zero-fills
a horizon's worth of cache rows — a system-scale write allocate. Here
the KV buffers are cut into fixed-size **pages** (vLLM-style): each
attention layer's K/V leaf becomes a physical pool ``(P, page, Hkv,
Dh)`` shared by all slots, and a per-slot **block table** maps logical
page ``i`` (cache rows ``i*page .. (i+1)*page-1``) to whichever
physical page holds it. Three WA-evasion-flavored consequences:

* **Memory scales with live tokens** — a slot holds exactly
  ``ceil(occupancy / page)`` pages, not a horizon.
* **Admission skips the zero-fill** — a recycled page is overwritten
  in place (stale rows are masked by position, exactly like the dense
  cache's unwritten horizon); only the pool's one-time init pays a
  zero store. The never-zero-a-page-you-overwrite rule is the paper's
  never-move-bytes-you-don't-need lesson applied to stores.
* **Common prefixes are shared** — full prompt pages are
  content-addressed (a hash chain over page token tuples), so two
  requests with the same system prompt map the same physical pages
  and admission copies zero pages; a divergent write to a shared page
  triggers copy-on-write (:meth:`PagePool.prepare_write`).

:class:`PagePool` is pure host-side bookkeeping (refcounts, free list,
prefix index); the device-side steps (:func:`make_paged_insert_step`,
:func:`make_page_copy_step`) are built here and jitted by the engine.
Pricing for the new traffic classes (page-gather reads, CoW copies,
recycled-vs-zero-fill admission) lives in ``repro.serve.kv_traffic``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serve.slots import SLOT_AXIS


def pages_per_slot(max_len: int, page_size: int) -> int:
    """Block-table width: logical pages covering the decode horizon."""
    return math.ceil(max_len / page_size)


def kv_leaf_flags(cfg: ModelConfig) -> dict:
    """Cache-structured tree of bools: True on paged (KV) leaves.

    KV leaves are identified the same way the cache dtype rule does it
    (``models.model``): their second logical axis is ``kv_seq``.
    Recurrent state (mamba/xLSTM) stays slot-batched — only attention
    KV is paged.
    """
    defs = M.cache_defs(cfg, 1, 1)
    return jax.tree.map(lambda d: d.axes[1] == "kv_seq", defs,
                        is_leaf=lambda x: isinstance(x, M.ParamDef))


def paged_cache_shapes(cfg: ModelConfig, max_slots: int, n_pages: int,
                       page_size: int) -> dict:
    """ShapeDtypeStruct tree of the paged decode cache.

    KV leaves become physical pools ``(n_pages, page, Hkv, Dh)``
    (scan-stacked ``(R, n_pages, page, Hkv, Dh)``) — their size is set
    by the *pool*, not by ``slots x horizon``. Recurrent leaves keep
    the dense slot-batched shapes.
    """
    flags = kv_leaf_flags(cfg)
    kv = M.cache_shapes(cfg, n_pages, page_size)
    slot = M.cache_shapes(cfg, max_slots, 1)
    return jax.tree.map(lambda f, a, b: a if f else b, flags, kv, slot)


def init_paged_cache(cfg: ModelConfig, max_slots: int, n_pages: int,
                     page_size: int) -> dict:
    """Zero-filled paged cache matching :func:`paged_cache_shapes`.

    This is the pool's *one-time* zero store; recycled pages are never
    re-zeroed (:class:`PagePool` hands them out stale, admission
    overwrites them in place).
    """
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_cache_shapes(cfg, max_slots, n_pages,
                                           page_size))


def paged_cache_pspecs(cfg: ModelConfig, rules: dict, mesh_sizes: dict,
                       max_slots: int, n_pages: int,
                       page_size: int) -> dict:
    """PartitionSpec tree matching :func:`paged_cache_shapes`.

    KV pool leaves ``(P, page, Hkv, Dh)`` keep the pool and page dims
    resident (every shard must see every block-table entry; the gather
    is the kernel's job) and put the TP split on ``kvheads`` — the
    ``batch``/``kv_seq`` rules are masked out so the dense cache rules
    can never claim the pool dims. Recurrent leaves keep their dense
    slot-batched specs.
    """
    flags = kv_leaf_flags(cfg)
    pool_rules = dict(rules, batch=(), kv_seq=())
    kv = M.cache_pspecs(cfg, pool_rules, mesh_sizes, n_pages, page_size)
    slot = M.cache_pspecs(cfg, rules, mesh_sizes, max_slots, 1)
    return jax.tree.map(lambda f, a, b: a if f else b, flags, kv, slot)


def paged_kv_bytes(cfg: ModelConfig, n_pages: int, page_size: int) -> int:
    """Total bytes of the KV page pools (fig8's peak-memory quantity)."""
    flags = kv_leaf_flags(cfg)
    shapes = M.cache_shapes(cfg, n_pages, page_size)
    tot = 0
    for f, s in zip(jax.tree.leaves(flags), jax.tree.leaves(shapes)):
        if f:
            tot += math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
    return tot


def dense_kv_bytes(cfg: ModelConfig, max_slots: int, max_len: int) -> int:
    """KV bytes of the dense slot cache at the same shapes (baseline)."""
    flags = kv_leaf_flags(cfg)
    shapes = M.cache_shapes(cfg, max_slots, max_len)
    tot = 0
    for f, s in zip(jax.tree.leaves(flags), jax.tree.leaves(shapes)):
        if f:
            tot += math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
    return tot


class PoolExhausted(RuntimeError):
    """Raised by :meth:`PagePool.allocate` when no page can be handed out.

    A ``RuntimeError`` subclass so existing callers keep working; the
    fault-tolerant serve path (``repro.serve.health``) catches it
    specifically and treats admission-time exhaustion as a transient,
    retryable overload signal — pages come back as requests retire.
    """


class PagePool:
    """Host-side physical page allocator with refcounted prefix sharing.

    Every page has a refcount: one per block-table entry holding it,
    plus one when the prefix index retains it as shareable. Full
    prompt pages are registered under a content hash chain —
    ``key_i = (key_{i-1}, tokens of page i)`` — so a later admission
    with the same prompt prefix maps the same physical pages
    (:meth:`match_prefix`, zero copies). Retained pages survive their
    last holder (an index cache) and are evicted LRU only when the
    free list runs dry, which is also where **recycling** happens:
    reallocated pages keep their stale contents (stale rows are masked
    by position), skipping the zero-fill a dense admission pays.

    Writes go through :meth:`prepare_write`: an exclusively-held page
    is written in place; a shared one is copy-on-wrote to a fresh page
    (the caller performs the device copy). ``stats`` counts the events
    fig8 gates on (shared maps, CoW copies, recycled vs fresh
    allocations, evictions).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("n_pages and page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.refcount = [0] * self.n_pages
        self._free = list(range(self.n_pages - 1, -1, -1))  # pop() -> 0,1,..
        self._used = [False] * self.n_pages   # ever allocated (recycling)
        self._chains: dict = {}               # chain key -> phys page
        self._page_key: dict = {}             # phys page -> chain key
        self._retained: dict = {}             # phys -> key, LRU order
        self.stats = {"shared_maps": 0, "cow_copies": 0,
                      "fresh_allocs": 0, "recycled_allocs": 0,
                      "evictions": 0}

    # -- allocation ---------------------------------------------------------
    def available(self) -> int:
        """Pages an ``allocate`` call could hand out right now."""
        evictable = sum(1 for p in self._retained if self.refcount[p] == 1)
        return len(self._free) + evictable

    def allocate(self, n: int) -> list:
        """Take ``n`` exclusive pages (refcount 1 each), recycling
        stale pages and evicting index-retained ones LRU if needed."""
        out = []
        for _ in range(int(n)):
            if self._free:
                p = self._free.pop()
            else:
                p = self._evict_retained()
            self.refcount[p] = 1
            key = "recycled_allocs" if self._used[p] else "fresh_allocs"
            self.stats[key] += 1
            self._used[p] = True
            out.append(p)
        return out

    def _evict_retained(self) -> int:
        for p in list(self._retained):        # insertion order = LRU
            if self.refcount[p] == 1:         # only the index holds it
                self._unregister(p)
                self.refcount[p] = 0
                self.stats["evictions"] += 1
                return p
        raise PoolExhausted(
            f"page pool exhausted ({self.n_pages} pages, none evictable)")

    def release(self, pages) -> None:
        """Drop one reference per page; refcount-0 pages go back to the
        free list (still registered pages stay retained instead)."""
        for p in pages:
            p = int(p)
            if self.refcount[p] <= 0:
                raise RuntimeError(f"release of unheld page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._unregister(p)
                self._free.append(p)

    # -- prefix sharing -----------------------------------------------------
    @staticmethod
    def _chain(prev, tokens) -> tuple:
        return (prev, tuple(tokens))

    def match_prefix(self, prompt) -> list:
        """Physical pages of the longest registered full-page prefix of
        ``prompt``; takes one reference per matched page (the caller
        owns them as the head of its block table)."""
        ps = self.page_size
        out, key = [], None
        for i in range(len(prompt) // ps):
            key = self._chain(key, prompt[i * ps:(i + 1) * ps])
            p = self._chains.get(key)
            if p is None:
                break
            out.append(p)
        for p in out:
            self.refcount[p] += 1
            if p in self._retained:           # refresh LRU recency
                k = self._retained.pop(p)
                self._retained[p] = k
        self.stats["shared_maps"] += len(out)
        return out

    def register_prefix(self, prompt, chain_pages) -> None:
        """Register a request's *full* prompt pages as shareable.

        ``chain_pages`` are the request's block-table head in logical
        order (matched + fresh). The index takes its own reference on
        each newly registered page, so the prefix stays shareable
        after the request retires — until pool pressure evicts it.
        """
        ps = self.page_size
        key = None
        for i, p in enumerate(chain_pages):
            key = self._chain(key, prompt[i * ps:(i + 1) * ps])
            if key in self._chains:
                continue                      # already shared
            self._chains[key] = p
            self._page_key[p] = key
            self._retained[p] = key
            self.refcount[p] += 1

    def _unregister(self, p: int) -> None:
        key = self._page_key.pop(p, None)
        if key is not None:
            self._chains.pop(key, None)
        self._retained.pop(p, None)

    # -- sharing / CoW ------------------------------------------------------
    def fork(self, pages) -> None:
        """Share every page of a live request with a clone (refcount++
        including partial pages — first divergent write CoWs)."""
        for p in pages:
            self.refcount[int(p)] += 1

    def prepare_write(self, phys: int) -> tuple:
        """Exclusive page for an in-place write: ``(page, copied)``.

        An exclusively-held page comes straight back. A page retained
        only by the prefix index is unregistered (its content is about
        to change) and written in place. A page with other live
        holders is copy-on-wrote: a fresh page is allocated, the
        caller's reference moves to it, and the caller must device-copy
        the old contents before writing (``copied=True``).
        """
        phys = int(phys)
        rc = self.refcount[phys]
        retained = phys in self._retained
        if rc <= 0:
            raise RuntimeError(f"prepare_write on unheld page {phys}")
        if rc == 1 and not retained:
            return phys, False
        if rc == 2 and retained:
            self._unregister(phys)
            self.refcount[phys] -= 1
            return phys, False
        new = self.allocate(1)[0]
        self.refcount[phys] -= 1
        self.stats["cow_copies"] += 1
        return new, True

    # -- invariants ---------------------------------------------------------
    def check_conservation(self, tables) -> None:
        """Assert pool conservation against the live block tables.

        ``tables`` is an iterable of per-request page lists. Every
        page's refcount must equal its live holders plus its index
        retention; free pages must be unheld and refcount 0; every
        page must be either free or referenced. Raises AssertionError
        with the offending page on violation.
        """
        held: dict = {}
        for t in tables:
            for p in t:
                held[int(p)] = held.get(int(p), 0) + 1
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate pages on the free list")
        for p in range(self.n_pages):
            want = held.get(p, 0) + (1 if p in self._retained else 0)
            if self.refcount[p] != want:
                raise AssertionError(
                    f"page {p}: refcount {self.refcount[p]} != "
                    f"{held.get(p, 0)} holders + "
                    f"{int(p in self._retained)} retained")
            if p in free and (self.refcount[p] != 0 or p in held):
                raise AssertionError(f"page {p} free but referenced")
            if p not in free and self.refcount[p] == 0:
                raise AssertionError(f"page {p} leaked (unreferenced, "
                                     "not free)")


# ---------------------------------------------------------------------------
# Device-side steps (jitted by the engine)
# ---------------------------------------------------------------------------

def make_paged_insert_step(cfg: ModelConfig, page_size: int):
    """Build ``insert(cache, one, slot, phys, logical) -> cache``.

    ``one`` is a batch-1 prefill cache built at *exactly* the prompt
    length (``make_prefill_step(cfg, cache_len=None)`` — no horizon
    zero-fill). Its KV rows are cut into pages and scattered to the
    ``phys`` physical pages named by the ``logical`` page indices
    (shared prefix pages are simply omitted from both arrays — zero
    copies for shared content). Recurrent leaves are slot-inserted as
    in the dense engine. Donate ``cache`` at the jit boundary.
    """
    flags = kv_leaf_flags(cfg)
    ps = int(page_size)

    def insert(cache, one, slot, phys, logical):
        out = {}
        for part, axis in SLOT_AXIS.items():
            if part not in cache:
                continue

            def upd(big, small, iskv, a=axis):
                if not iskv:
                    return jax.lax.dynamic_update_slice_in_dim(
                        big, small.astype(big.dtype), slot, axis=a)
                if a == 0:       # tail: small (1, S, Hkv, Dh)
                    s = small.shape[1]
                    npg = -(-s // ps)
                    rows = jnp.pad(small, [(0, 0), (0, npg * ps - s),
                                           (0, 0), (0, 0)])
                    rows = rows.reshape((npg, ps) + small.shape[2:])
                    return big.at[phys].set(
                        rows[logical].astype(big.dtype))
                # scan: small (R, 1, S, Hkv, Dh)
                s = small.shape[2]
                npg = -(-s // ps)
                rows = jnp.pad(small, [(0, 0), (0, 0), (0, npg * ps - s),
                                       (0, 0), (0, 0)])
                rows = rows.reshape((small.shape[0], npg, ps)
                                    + small.shape[3:])
                return big.at[:, phys].set(
                    rows[:, logical].astype(big.dtype))

            out[part] = jax.tree.map(upd, cache[part], one[part],
                                     flags[part])
        return out

    return insert


def make_page_copy_step(cfg: ModelConfig):
    """Build ``copy(cache, src, dst) -> cache`` — the CoW device copy.

    Copies physical page ``src`` to ``dst`` in every KV leaf (all
    attention layers, K and V); recurrent leaves pass through. ``src``
    and ``dst`` are traced scalars, so one compilation serves every
    copy. Donate ``cache`` at the jit boundary.
    """
    flags = kv_leaf_flags(cfg)

    def copy(cache, src, dst):
        out = {}
        for part, axis in SLOT_AXIS.items():
            if part not in cache:
                continue

            def upd(big, iskv, a=axis):
                if not iskv:
                    return big
                if a == 0:
                    return big.at[dst].set(big[src])
                return big.at[:, dst].set(big[:, src])

            out[part] = jax.tree.map(upd, cache[part], flags[part])
        return out

    return copy


def make_slot_copy_step(cfg: ModelConfig):
    """Build ``copy(cache, src, dst) -> cache`` for recurrent leaves.

    A fork shares KV via the block table, but slot-batched recurrent
    state (mamba/xLSTM) must be duplicated into the clone's slot row.
    KV page pools pass through untouched. Donate ``cache``.
    """
    flags = kv_leaf_flags(cfg)

    def copy(cache, src, dst):
        out = {}
        for part, axis in SLOT_AXIS.items():
            if part not in cache:
                continue

            def upd(big, iskv, a=axis):
                if iskv:
                    return big
                row = jax.lax.dynamic_slice_in_dim(big, src, 1, axis=a)
                return jax.lax.dynamic_update_slice_in_dim(big, row, dst,
                                                           axis=a)

            out[part] = jax.tree.map(upd, cache[part], flags[part])
        return out

    return copy
