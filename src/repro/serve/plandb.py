"""Persisted offline plan database for the serve path.

Every cold engine construction used to pay the full online planning
bill at admission time: lower the decode step to HLO, fan
``portmodel.compare`` across the machine registry, and autotune the
kernel tiles — hundreds of milliseconds of work whose answer depends
only on (machine, model config, sharding), none of which change
between serving runs. This module moves that work offline: ``sweep``
prices the (chunk size x tile x n_splits x store flavor x tp) space
with both the analytical ``tp_bound`` backend and the ``mca_sched``
cycle simulator, persists the winners as versioned JSON, and an
installed database turns ``plan_chunk_size`` / ``decode_tiles`` /
``flash_tiles`` into O(1) dictionary hits at engine construction.

Staleness is impossible by construction, not by discipline: every DB
key folds content fingerprints of the model config (sha256 of the
frozen dataclass repr) and of *every* registered machine
(``core.machine.machine_fingerprint``). Re-registering a machine with
different parameters, or editing a model config, changes the
fingerprint, the key misses, and the planner falls back to online
planning — bit-identically, since the DB stores exactly the object
online planning would have produced (``dataclasses.asdict`` through
JSON round-trips Python floats exactly).

The two backends do not always agree on a winner — ``mca_sched``'s
dispatch-width pessimism can push a machine to a smaller chunk or a
different split count than the balanced-port bound. That is signal,
not noise (the source paper's OSACA-vs-MCA comparison is exactly this
disagreement at basic-block scale): ``backend_disagreements`` reports
every swept point where the backends picked different winners, per
machine, so the fig11 benchmark can surface where simulator pessimism
changes the served configuration.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.core.machine import (machine_fingerprint, registered_names,
                                registry_fingerprint)
from repro.serve.planner import ChunkPlan

#: JSON format version; loading any other version is a hard error, not
#: a silent partial read — a format change must never half-apply.
PLANDB_VERSION = 1

#: the process-wide installed database consulted by the planner/tuner
_INSTALLED = None


def config_fingerprint(cfg) -> str:
    """Content fingerprint of a model config (frozen-dataclass repr).

    Any field change — vocab size, head count, dtype policy — changes
    the fingerprint and therefore every DB key derived from it.
    """
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def _digest(material) -> str:
    """Stable hash of hashable-ish key material (sorted-repr canonical)."""
    return hashlib.sha256(repr(material).encode()).hexdigest()


def _chunk_material(cfg, batch, max_len, *, machine, dispatch_overhead_s,
                    overhead_frac, max_chunk, occupancy, backend,
                    store_flavor, page_size, mesh_sizes, rules_fp, tp):
    """Canonical key material for one chunk-plan entry.

    Mirrors the planner's in-process memo key exactly, with the
    object-identity parts (cfg, registry) replaced by content
    fingerprints so the key survives serialization and process
    boundaries.
    """
    return ("chunk", config_fingerprint(cfg), batch, max_len,
            str(machine), float(dispatch_overhead_s),
            float(overhead_frac), int(max_chunk), occupancy, backend,
            store_flavor, page_size, tuple(sorted(mesh_sizes.items())),
            tuple(rules_fp), int(tp), registry_fingerprint())


def _tile_material(kind: str, machine: str, kwargs: dict):
    """Canonical key material for one tile-plan entry (flash/decode)."""
    return ("tile", kind, str(machine), machine_fingerprint(machine),
            tuple(sorted(kwargs.items())))


class PlanDB:
    """A keyed store of finished serve plans, JSON-persistable.

    ``chunks`` and ``tiles`` map key digests to entries of the form
    ``{"plan": <asdict>, "context": <human-readable provenance>}``.
    Lookups reconstruct the original frozen dataclass; a miss returns
    None and costs one dict probe.
    """

    def __init__(self, chunks: dict | None = None,
                 tiles: dict | None = None, meta: dict | None = None):
        self.chunks = chunks if chunks is not None else {}
        self.tiles = tiles if tiles is not None else {}
        self.meta = meta if meta is not None else {}

    # -- chunk plans --------------------------------------------------------
    def lookup_chunk(self, cfg, batch, max_len, **key) -> ChunkPlan | None:
        """The stored ChunkPlan for one planner key, or None."""
        hit = self.chunks.get(
            _digest(_chunk_material(cfg, batch, max_len, **key)))
        if hit is None:
            return None
        return ChunkPlan(**hit["plan"])

    def record_chunk(self, cfg, batch, max_len, *, plan: ChunkPlan,
                     **key) -> None:
        """Persist one finished chunk plan under its planner key."""
        self.chunks[_digest(_chunk_material(cfg, batch, max_len, **key))] = {
            "plan": dataclasses.asdict(plan),
            "context": {"machine": str(key["machine"]),
                        "backend": key["backend"], "tp": int(key["tp"]),
                        "occupancy": key["occupancy"],
                        "store_flavor": key["store_flavor"],
                        "page_size": key["page_size"],
                        "batch": batch, "max_len": max_len,
                        "chunk": plan.chunk},
        }

    # -- tile plans ---------------------------------------------------------
    def lookup_tiles(self, kind: str, machine: str, kwargs: dict):
        """The stored TilePlan for one tuner key, or None."""
        from repro.kernels.tuning import TilePlan
        hit = self.tiles.get(_digest(_tile_material(kind, machine, kwargs)))
        if hit is None:
            return None
        return TilePlan(**hit["plan"])

    def record_tiles(self, kind: str, machine: str, kwargs: dict,
                     plan) -> None:
        """Persist one autotuned tile plan under its tuner key."""
        self.tiles[_digest(_tile_material(kind, machine, kwargs))] = {
            "plan": dataclasses.asdict(plan),
            "context": dict(kwargs, kind=kind, machine=str(machine),
                            bk=plan.bk, n_splits=plan.n_splits),
        }

    # -- persistence --------------------------------------------------------
    def save(self, path) -> None:
        """Write the database as versioned JSON."""
        doc = {"format": "repro-plandb", "version": PLANDB_VERSION,
               "meta": self.meta, "chunks": self.chunks,
               "tiles": self.tiles}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path) -> "PlanDB":
        """Read a versioned JSON database; wrong versions are errors."""
        with open(path) as f:
            doc = json.load(f)
        if doc.get("format") != "repro-plandb":
            raise ValueError(f"{path}: not a repro plan database")
        if doc.get("version") != PLANDB_VERSION:
            raise ValueError(
                f"{path}: plan-DB version {doc.get('version')} != "
                f"supported {PLANDB_VERSION} — re-run the sweep")
        return cls(chunks=doc.get("chunks", {}),
                   tiles=doc.get("tiles", {}), meta=doc.get("meta", {}))

    def __len__(self) -> int:
        return len(self.chunks) + len(self.tiles)


def install(db: PlanDB | None) -> None:
    """Make ``db`` the process-wide plan database (None uninstalls).

    Clears the in-process plan/tile memos so the very next plan
    request consults the new database instead of a memoized answer
    computed under the old one.
    """
    global _INSTALLED
    _INSTALLED = db
    from repro.serve.planner import clear_plan_cache
    clear_plan_cache()


def installed() -> PlanDB | None:
    """The currently installed plan database, if any."""
    return _INSTALLED


def sweep(cfg, *, batches=(8,), max_lens=(1024,),
          machines=None, backends=("tp_bound", "mca_sched"),
          tps=(1, 2), store_flavors=("auto",),
          occupancies=(None,), page_sizes=(None,),
          dispatch_overhead_s: float = 2e-4, overhead_frac: float = 0.1,
          max_chunk: int = 32, decode_batch: int = 1,
          dtype: str = "bf16") -> PlanDB:
    """Price the serve plan space offline and return the database.

    Sweeps chunk plans over (batch x max_len x machine x backend x tp
    x store flavor x occupancy x page size) through the *online*
    planner — any installed DB is temporarily uninstalled so the sweep
    can never copy itself — and tile plans (flash prefill and split-KV
    decode) over (machine x backend) at the shapes the config serves.
    Both backends are swept so ``backend_disagreements`` has the full
    table to compare.
    """
    from repro.kernels.tuning import decode_tiles, flash_tiles
    from repro.serve.planner import plan_chunk_size
    from repro.utils.sharding import SERVE_ENGINE_RULES, rules_fingerprint
    if machines is None:
        machines = registered_names()
    db = PlanDB(meta={
        "config": {"name": getattr(cfg, "name", "?"),
                   "fingerprint": config_fingerprint(cfg)},
        "registry": dict(registry_fingerprint()),
    })
    prev = _INSTALLED
    install(None)
    try:
        for batch in batches:
            for max_len in max_lens:
                for machine in machines:
                    for backend in backends:
                        for tp in tps:
                            for flavor in store_flavors:
                                for occ in occupancies:
                                    for ps in page_sizes:
                                        _sweep_one(
                                            db, cfg, batch, max_len,
                                            machine=machine,
                                            backend=backend, tp=tp,
                                            store_flavor=flavor,
                                            occupancy=occ, page_size=ps,
                                            dispatch_overhead_s=(
                                                dispatch_overhead_s),
                                            overhead_frac=overhead_frac,
                                            max_chunk=max_chunk,
                                            plan_fn=plan_chunk_size,
                                            rules=SERVE_ENGINE_RULES,
                                            rules_fp=rules_fingerprint)
        dh = cfg.head_dim_eff
        for max_len in max_lens:
            for machine in machines:
                for backend in backends:
                    fkw = dict(s=max_len, dh=dh, h=cfg.n_heads,
                               hkv=cfg.n_kv_heads, dtype=dtype,
                               backend=backend)
                    db.record_tiles("flash", machine, fkw,
                                    flash_tiles(machine, **fkw))
                    dkw = dict(skv=max_len, dh=dh, h=cfg.n_heads,
                               hkv=cfg.n_kv_heads, batch=decode_batch,
                               dtype=dtype, backend=backend)
                    db.record_tiles("decode", machine, dkw,
                                    decode_tiles(machine, **dkw))
    finally:
        install(prev)
    return db


def _sweep_one(db, cfg, batch, max_len, *, machine, backend, tp,
               store_flavor, occupancy, page_size, dispatch_overhead_s,
               overhead_frac, max_chunk, plan_fn, rules, rules_fp):
    """Plan one swept point online and record it under its DB key."""
    plan = plan_fn(cfg, batch, max_len, machine=machine,
                   dispatch_overhead_s=dispatch_overhead_s,
                   overhead_frac=overhead_frac, max_chunk=max_chunk,
                   occupancy=occupancy, backend=backend,
                   store_flavor=store_flavor, page_size=page_size,
                   tp=tp)
    mesh_sizes = {"data": 1, "model": int(tp)} if tp > 1 else {}
    db.record_chunk(cfg, batch, max_len, plan=plan, machine=machine,
                    dispatch_overhead_s=dispatch_overhead_s,
                    overhead_frac=overhead_frac, max_chunk=max_chunk,
                    occupancy=occupancy, backend=plan.backend,
                    store_flavor=store_flavor, page_size=page_size,
                    mesh_sizes=mesh_sizes,
                    rules_fp=rules_fp(rules if tp > 1 else None),
                    tp=max(1, int(tp)))


def backend_disagreements(db: PlanDB) -> list:
    """Swept points where tp_bound and mca_sched picked different winners.

    Groups every entry by its context minus the backend and reports
    the groups whose winners differ — different chunk size for chunk
    plans, different (bk, n_splits) for tile plans. Each row carries
    both winners so the report reads as "on this machine, at this
    point, simulator pessimism changes the served configuration".
    """
    rows = []
    by_point: dict = {}
    for ent in db.chunks.values():
        ctx = dict(ent["context"])
        backend = ctx.pop("backend")
        chunk = ctx.pop("chunk")
        by_point.setdefault(tuple(sorted(ctx.items())),
                            {})[backend] = (chunk, ctx)
    for point, winners in by_point.items():
        picks = {b: w[0] for b, w in winners.items()}
        if len(set(picks.values())) > 1:
            ctx = next(iter(winners.values()))[1]
            rows.append(dict(kind="chunk", picks=picks, **ctx))
    by_point = {}
    for ent in db.tiles.values():
        ctx = dict(ent["context"])
        backend = ctx.pop("backend")
        win = (ctx.pop("bk"), ctx.pop("n_splits"))
        by_point.setdefault(tuple(sorted(ctx.items())),
                            {})[backend] = (win, ctx)
    for point, winners in by_point.items():
        picks = {b: w[0] for b, w in winners.items()}
        if len(set(picks.values())) > 1:
            ctx = next(iter(winners.values()))[1]
            rows.append(dict(kind="tiles",
                             picks={b: {"bk": w[0], "n_splits": w[1]}
                                    for b, w in picks.items()}, **ctx))
    return rows


#: Package-namespace aliases: ``install``/``installed``/``sweep`` are
#: the natural module-local names (``plandb.install(db)`` reads well)
#: but too generic to re-export bare from ``repro.serve``.
plandb_install = install
plandb_installed = installed
sweep_plans = sweep
