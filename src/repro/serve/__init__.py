"""Continuous-batching serving subsystem.

The engine holds a fixed number of KV **slots**: a slot-batched cache
preallocated once at the full decode horizon (``models.model.forward``'s
``cache_len`` plumbing — no ``jnp.pad`` regrow, no recompiles as batch
composition changes). Requests are admitted into free slots (per-request
prefill + in-place slot insert), decoded in in-graph multi-token chunks
with per-slot positions and in-graph temperature sampling, and retired
as they finish — new requests join mid-flight without disturbing the
streams already decoding.

``PagedServeEngine`` swaps the dense slot stripes for a paged KV cache
(``repro.serve.pages``): fixed-size physical pages mapped through
per-slot block tables, refcounted prefix sharing with copy-on-write,
lazy allocation as positions advance, and zero-fill-free page
recycling — memory scales with live tokens instead of
``slots x horizon``, and the avoided admission stores are the serve
path's write-allocate-evasion story.

The analytical stack is wired in: the scheduler picks its decode chunk
size from the port model's tier-resolved per-step cost
(``repro.serve.planner``, via ``portmodel.compare`` /
``Report.tier_bound_seconds``), and the per-step KV traffic — dense
updates, paged gathers, CoW copies, recycled admissions — is priced
through ``wa``/``memtier`` so every delta is reported per machine
(``repro.serve.kv_traffic``).

Both engines accept ``mesh=``/``rules=``: with a device mesh the
params and the KV cache (dense stripes or page pools) are laid out by
the logical-axis rules (``kvheads`` -> TP), the step functions trace
with ``sc()`` constraints live, and the planner prices the per-shard
KV stream plus the per-step activation all-reduce
(``kv_traffic.collective_traffic``). ``mesh=None`` is the bit-exact
single-device path. ``ReplicaRouter`` (``repro.serve.router``) scales
*traffic* instead: N replicas behind a round-robin / least-loaded
admission controller with per-replica queues and backpressure.

The overlapped runtime threads through all of it: ``pipeline=N`` on
either engine double-buffers the decode dispatch (round N+1 enqueued
while round N executes, token streams byte-identical to serial;
``stats()['mean_dispatch_gap_s']`` is the measured host gap),
``repro.serve.staging`` prefetches queued prompts to the device so
admission skips the H2D copy, and ``repro.serve.plandb`` persists an
offline planner sweep (both backends, chunk x tile x tp x flavor) so
admission planning at startup is an O(1) bit-identical DB hit.

The fault-tolerance layer rides on top: ``repro.serve.faults`` is the
seeded deterministic fault injector (``FaultyEngine`` wraps either
engine and injects step/admission failures on a schedule), and
``repro.serve.health`` is the consumer — per-replica health state
machines scored against the planner's per-round budget, request
rescue by prompt+prefix replay (priced via
``kv_traffic.rescue_traffic``), deadlines, and priced
keep/replan/shed degradation behind ``FaultTolerantRouter``.
"""

from repro.serve.decode import make_chunked_decode_step
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.faults import (FaultSpec, FaultyEngine, TransientFault,
                                chaos_schedule, poison_slot)
from repro.serve.health import (FaultTolerantRouter, HealthConfig,
                                NoHealthyReplica, ReplicaHealth,
                                deadline_for, priced_degradation)
from repro.serve.kv_traffic import (collective_traffic, cow_fork_traffic,
                                    decode_read_traffic, kv_update_traffic,
                                    page_admission_traffic,
                                    page_gather_traffic, rescue_traffic)
from repro.serve.pages import PagePool, PoolExhausted, paged_cache_pspecs
from repro.serve.plandb import (PlanDB, backend_disagreements,
                                plandb_install, plandb_installed,
                                sweep_plans)
from repro.serve.planner import (ChunkPlan, decode_step_hlo,
                                 kv_read_seconds, plan_chunk_size,
                                 plan_stats, planned_round_seconds,
                                 reset_plan_stats)
from repro.serve.router import QueueFull, ReplicaRouter
from repro.serve.staging import PromptStager

__all__ = [
    "ChunkPlan",
    "FaultSpec",
    "FaultTolerantRouter",
    "FaultyEngine",
    "HealthConfig",
    "NoHealthyReplica",
    "PagePool",
    "PagedServeEngine",
    "PlanDB",
    "PoolExhausted",
    "PromptStager",
    "QueueFull",
    "ReplicaHealth",
    "ReplicaRouter",
    "Request",
    "ServeEngine",
    "TransientFault",
    "backend_disagreements",
    "chaos_schedule",
    "collective_traffic",
    "cow_fork_traffic",
    "deadline_for",
    "decode_read_traffic",
    "decode_step_hlo",
    "kv_read_seconds",
    "kv_update_traffic",
    "make_chunked_decode_step",
    "page_admission_traffic",
    "page_gather_traffic",
    "paged_cache_pspecs",
    "plan_chunk_size",
    "plan_stats",
    "plandb_install",
    "plandb_installed",
    "planned_round_seconds",
    "poison_slot",
    "priced_degradation",
    "rescue_traffic",
    "reset_plan_stats",
    "sweep_plans",
]
