"""In-graph chunked decode: n tokens per dispatch, per-slot positions,
in-graph temperature sampling.

Generalizes ``train/serve.py:make_decode_loop_step`` (greedy, scalar
position) to the serve engine's needs: every slot decodes at its own
position (``pos`` is a (B,) vector), and sampling happens inside the
token scan so a temperature>0 engine still issues one dispatch per
chunk. The cache flows through the scan carry, so with the jit-level
donation the per-token dynamic-update-slice stays in place — the
NT-store analogue (DESIGN.md §2) at serve scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def make_chunked_decode_step(cfg: ModelConfig, n_tokens: int,
                             temperature: float = 0.0,
                             attn_impl: str | None = None,
                             kv_len: int | None = None,
                             store_flavor: str | None = None,
                             paged: bool = False,
                             guard: bool = False):
    """Build the n-token decode chunk: one dispatch, n in-graph steps.

    Returns ``step(params, cache, tokens, pos, key) -> (toks, cache, pos)``
    with ``tokens`` (B, 1) int32 (each slot's last emitted token), ``pos``
    a scalar or (B,) int32 (each slot's write position), and ``key`` a
    PRNG key consumed only when ``temperature > 0``. ``toks`` is
    (B, n_tokens): the next n tokens of every slot. Token-id models only.

    ``attn_impl`` routes decode attention through the split-KV kernel
    suite and ``kv_len`` is the *static* occupancy bound for the whole
    chunk — no slot may write past it, so it must cover
    ``max(pos) + n_tokens`` (the engine fixes one bound for its
    lifetime and rejects requests beyond it; each distinct ``kv_len``
    is its own compilation). Token ``i`` of the chunk reads at most
    ``kv_len`` cache rows instead of the full horizon — the split-KV
    traffic bound at dispatch granularity. ``store_flavor`` picks the
    KV-writer store path (repro.kernels.stores; None = standard).

    ``paged=True`` switches to the paged-cache step signature
    ``step(params, cache, block_tables, tokens, pos, key)``: attention
    KV leaves are physical page pools and ``block_tables`` (B, NB)
    int32 maps each slot's logical pages (repro.serve.pages). The
    cache stays positional argument 1 so the engine's donation hint is
    layout-independent.

    ``guard=True`` appends a per-slot non-finite guard to the return:
    the step yields ``(toks, cache, pos, ok)`` with ``ok`` a (B,) bool
    that is False for any slot whose logits went non-finite at *any*
    token of the chunk. The check is one ``jnp.isfinite`` reduce per
    in-graph step — jit-compatible, fused into the logits epilogue —
    and it is per-row, so one slot's NaN never condemns its batchmates
    (attention/recurrent mixers keep rows independent; see the MoE
    caveat in ``serve.engine``). Poisoned rows emit token 0 for the
    rest of the chunk so the self-fed garbage can't index out of the
    embedding; the serve engine quarantines the request and discards
    the chunk's tokens. ``guard=False`` (default) keeps the historical
    3-tuple and a bit-identical graph.
    """
    assert cfg.embed_inputs, "chunked decode needs a token embedding"
    assert n_tokens >= 1

    def step(params, cache, tokens, pos, key, block_tables=None):
        def body(carry, _):
            if guard:
                cache, tok, pos, key, ok = carry
            else:
                cache, tok, pos, key = carry
            logits, _, new_cache = M.forward(cfg, params, {"tokens": tok},
                                            mode="decode", cache=cache,
                                            pos=pos, attn_impl=attn_impl,
                                            kv_len=kv_len,
                                            store_flavor=store_flavor,
                                            block_tables=block_tables)
            # some mixers emit recurrent state in compute dtype (bf16);
            # the cache contract (model.cache_shapes) carries them f32 —
            # pin the scan carry to the contract's dtypes
            cache = jax.tree.map(lambda new, old: new.astype(old.dtype),
                                 new_cache, cache)
            lg = logits[:, 0]
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, lg / temperature, axis=-1)
                nxt = nxt.astype(jnp.int32)
            else:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            if guard:
                ok = ok & jnp.all(jnp.isfinite(lg), axis=-1)
                # a poisoned row's self-fed token is garbage: pin it to 0
                # so the next embedding lookup stays in range (the chunk's
                # tokens are discarded at quarantine anyway)
                nxt = jnp.where(ok, nxt, 0)
                return (cache, nxt[:, None], pos + 1, key, ok), nxt
            return (cache, nxt[:, None], pos + 1, key), nxt

        if guard:
            ok0 = jnp.ones((tokens.shape[0],), bool)
            (cache, _, pos, _, ok), toks = jax.lax.scan(
                body, (cache, tokens, pos, key, ok0), None, length=n_tokens)
            return jnp.swapaxes(toks, 0, 1), cache, pos, ok
        (cache, _, pos, _), toks = jax.lax.scan(
            body, (cache, tokens, pos, key), None, length=n_tokens)
        return jnp.swapaxes(toks, 0, 1), cache, pos

    if not paged:
        return step

    def paged_step(params, cache, block_tables, tokens, pos, key):
        return step(params, cache, tokens, pos, key,
                    block_tables=block_tables)

    return paged_step
