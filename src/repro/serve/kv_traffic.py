"""WA-priced KV-cache update traffic: donated (in-place) vs copied.

Each decode step writes one (Hkv, Dh) row per slot into every attention
layer's K and V buffers. With donation the dynamic-update-slice happens
in place — the traffic is the row itself plus whatever read-modify-write
the machine's write-allocate behaviour forces on the partial tiles it
touches (``wa.store_profile``). Without donation, XLA must first copy
the *whole* cache buffer — a system-scale write allocate, the failure
mode the paper's CloverLeaf WA study quantifies (arXiv:2311.04797) and
exactly what the old ``jnp.pad`` regrow in launch/serve.py used to do
every generation. The per-machine delta between the two is the serve
path's WA story in bytes.
"""

from __future__ import annotations

import math

from repro.configs.base import ModelConfig
from repro.core import wa
from repro.core.machine import get_machine, registered_names
from repro.utils.hw import dtype_bytes

_JAX_DTYPE = {"bfloat16": "bf16", "float32": "f32", "float16": "f16"}


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(blk.split(":")[0] in ("attn", "attn_local")
               for blk in cfg.layer_plan())


def decode_kv_profiles(cfg: ModelConfig, batch: int,
                       max_len: int) -> dict:
    """Per-decode-step KV-store profiles: ``donated`` and ``copied``.

    Aggregated over all attention layers and both K and V: one
    (Hkv, Dh) row per slot, dynamic (offset-unaligned) sequence offset.
    The ``copied`` profile adds the whole-buffer copy a non-donated
    update would force. Returns the two StoreProfiles plus the total
    cache bytes (the working set gating SpecI2M saturation).
    """
    n_attn = _attn_layers(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_eff
    dtype = _JAX_DTYPE.get(cfg.param_dtype, "f32")
    eb = dtype_bytes(dtype)
    row = wa.store_profile((hkv, dh), dtype, offset_aligned=False,
                           donated=True, full_overwrite=False)
    n_stores = 2 * n_attn * batch            # K and V, per layer, per slot
    leaf_bytes = float(batch * max_len * hkv * dh * eb)
    cache_bytes = 2 * n_attn * leaf_bytes
    donated = wa.StoreProfile(row.stored_bytes * n_stores,
                              row.rmw_read_bytes * n_stores)
    copied = wa.StoreProfile(donated.stored_bytes, donated.rmw_read_bytes,
                             copy_bytes=cache_bytes)
    return {"donated": donated, "copied": copied,
            "cache_bytes": cache_bytes, "n_attn_layers": n_attn}


def kv_update_traffic(cfg: ModelConfig, batch: int, max_len: int, *,
                      machines=None, nt_stores: bool = False) -> list:
    """Per-machine donated-vs-copied KV-update traffic, one dict per row.

    Rows carry the machine's WA mode, the per-decode-step traffic of the
    donated (in-place) update and of the non-donated (copy-first) update,
    and their delta — what cache donation saves on that machine, priced
    through its Fig. 4 behavioural mode with the SpecI2M gate modeled on
    the full cache working set.
    """
    profs = decode_kv_profiles(cfg, batch, max_len)
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        kw = dict(nt_stores=nt_stores, ws_bytes=profs["cache_bytes"],
                  cores_active=m.cores)
        donated = wa.priced_store_traffic(profs["donated"], m, **kw)
        copied = wa.priced_store_traffic(profs["copied"], m, **kw)
        rows.append({
            "machine": m.name, "wa_mode": m.wa_mode,
            "stored_bytes": profs["donated"].stored_bytes,
            "donated_bytes": donated, "copied_bytes": copied,
            "delta_bytes": copied - donated,
            "cache_bytes": profs["cache_bytes"],
            "n_attn_layers": profs["n_attn_layers"],
        })
    if not math.isfinite(sum(r["delta_bytes"] for r in rows)):
        raise AssertionError("non-finite KV traffic pricing")
    return rows
