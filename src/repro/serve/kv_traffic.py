"""WA-priced KV-cache update traffic: donated (in-place) vs copied.

Each decode step writes one (Hkv, Dh) row per slot into every attention
layer's K and V buffers. With donation the dynamic-update-slice happens
in place — the traffic is the row itself plus whatever read-modify-write
the machine's write-allocate behaviour forces on the partial tiles it
touches (``wa.store_profile``). Without donation, XLA must first copy
the *whole* cache buffer — a system-scale write allocate, the failure
mode the paper's CloverLeaf WA study quantifies (arXiv:2311.04797) and
exactly what the old ``jnp.pad`` regrow in launch/serve.py used to do
every generation. The per-machine delta between the two is the serve
path's WA story in bytes.

:func:`decode_read_traffic` prices the *read* side of the same story:
dense full-horizon KV streaming vs the split-KV kernel's
occupancy-bounded blocks, per machine (each machine's autotuned KV
block sets its rounding).

The paged-KV engine (repro.serve.pages / PagedServeEngine) adds three
traffic classes of its own, all priced through the same MemTier
ladder so the fig8 gates compare like with like:
:func:`page_gather_traffic` (block-table gather reads + the WA-priced
row store of the step), :func:`cow_fork_traffic` (the page copies
copy-on-write adds back), and :func:`page_admission_traffic`
(recycled-page admission vs the dense engine's horizon zero-fill).
"""

from __future__ import annotations

import math

from repro.configs.base import ModelConfig
from repro.core import wa
from repro.core.machine import get_machine, registered_names
from repro.utils.hw import dtype_bytes

_JAX_DTYPE = {"bfloat16": "bf16", "float32": "f32", "float16": "f16"}


def attn_layer_count(cfg: ModelConfig) -> int:
    """Number of attention blocks (the layers that own a KV cache)."""
    return sum(blk.split(":")[0] in ("attn", "attn_local")
               for blk in cfg.layer_plan())


def kv_row_bytes(cfg: ModelConfig, batch: int) -> float:
    """Bytes one cache *row* (one token position) holds across the whole
    stack: K and V, every attention layer, every slot."""
    eb = dtype_bytes(_JAX_DTYPE.get(cfg.param_dtype, "f32"))
    return 2.0 * attn_layer_count(cfg) * batch \
        * cfg.n_kv_heads * cfg.head_dim_eff * eb


def tp_reduce_count(cfg: ModelConfig) -> int:
    """All-reduces one token step issues under tensor parallelism.

    Every mixer ends in an output projection contracting over a
    TP-sharded inner dim (attention heads, mamba/xLSTM inner, sLSTM
    hidden), and every FFN/MoE block contracts over the sharded
    ``mlp``/``emlp`` dim — each contributes one partial-sum all-reduce
    of the (B, d_model) activation per step.
    """
    n = 0
    for blk in cfg.layer_plan():
        n += 1                                   # mixer output projection
        if blk.split(":")[1] != "none":
            n += 1                               # FFN down projection
    return n


def collective_traffic(cfg: ModelConfig, batch: int, tp: int, *,
                       machines=None, ws_bytes: float | None = None,
                       cores_active: int | None = None) -> list:
    """Per-machine traffic of the per-step activation all-reduces.

    With the serving stack TP-sharded over ``tp`` shards, every decode
    token pays :func:`tp_reduce_count` ring all-reduces of the
    (B, d_model) activation: each shard moves ``2 * (tp-1)/tp`` of the
    payload in (loads) and the same out again (allocating stores of
    the reduced chunks). The store side is WA-priced through each
    machine's MemTier ladder (``memtier.transfer_time``) — homed to
    the tier ``ws_bytes`` resolves to (callers pass the serve step's
    resident working set; default is the ring traffic itself) — so the
    per-shard collective bytes preserve the paper's Grace <= SPR <=
    Zen 4 store-traffic ordering exactly like every other serve-path
    traffic class. ``tp=1`` prices to zero on every machine (no mesh,
    no collectives).
    """
    from repro.core import memtier

    tp = max(1, int(tp))
    eb = dtype_bytes(_JAX_DTYPE.get(cfg.param_dtype, "f32"))
    n_red = tp_reduce_count(cfg)
    payload = float(batch * cfg.d_model * eb) * n_red
    ring = 2.0 * (tp - 1) / tp * payload
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        res = memtier.transfer_time(
            m, ws_bytes=float(ws_bytes) if ws_bytes is not None else
            max(ring, 1.0),
            load_bytes=ring, store_bytes=ring,
            cores_active=cores_active if cores_active is not None
            else m.cores)
        rows.append({
            "machine": m.name, "tp": tp, "n_reduces": n_red,
            "payload_bytes": payload, "ring_bytes": ring,
            "coll_bytes": res.traffic_bytes,
            "coll_seconds": res.seconds,
            "home_tier": res.home,
        })
    if not all(math.isfinite(r["coll_seconds"]) for r in rows):
        raise AssertionError("non-finite collective-traffic pricing")
    return rows


def bounded_decode_plan(cfg: ModelConfig, batch: int, max_len: int,
                        occupancy: int, machine) -> tuple:
    """(TilePlan, bounded rows) of the split-KV kernel at an occupancy.

    This is the single source of truth for what the kernel path
    actually runs: the tiling is autotuned at the *streamed* length
    (the occupancy bound — exactly what ``ops.flash_decode`` does with
    its ``kv_len``), and the bound is then rounded up to that plan's
    KV block. Reporters (:func:`decode_read_traffic`) and the planner
    (``serve.planner._kernel_adjusted``) both price through here so
    they can never describe a different plan than the kernel executes.
    """
    from repro.kernels import tuning

    occupancy = max(1, min(int(occupancy), max_len))
    plan = tuning.decode_tiles(
        get_machine(machine).name, skv=occupancy, dh=cfg.head_dim_eff,
        h=cfg.n_heads, hkv=cfg.n_kv_heads, batch=batch,
        dtype=cfg.param_dtype)
    bound = min(math.ceil(occupancy / plan.bk) * plan.bk, max_len)
    return plan, bound




def decode_kv_profiles(cfg: ModelConfig, batch: int,
                       max_len: int) -> dict:
    """Per-decode-step KV-store profiles: ``donated`` and ``copied``.

    Aggregated over all attention layers and both K and V: one
    (Hkv, Dh) row per slot, dynamic (offset-unaligned) sequence offset.
    The ``copied`` profile adds the whole-buffer copy a non-donated
    update would force. Returns the two StoreProfiles plus the total
    cache bytes (the working set gating SpecI2M saturation).
    """
    n_attn = attn_layer_count(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_eff
    dtype = _JAX_DTYPE.get(cfg.param_dtype, "f32")
    eb = dtype_bytes(dtype)
    row = wa.store_profile((hkv, dh), dtype, offset_aligned=False,
                           donated=True, full_overwrite=False)
    n_stores = 2 * n_attn * batch            # K and V, per layer, per slot
    leaf_bytes = float(batch * max_len * hkv * dh * eb)
    cache_bytes = 2 * n_attn * leaf_bytes
    donated = wa.StoreProfile(row.stored_bytes * n_stores,
                              row.rmw_read_bytes * n_stores)
    copied = wa.StoreProfile(donated.stored_bytes, donated.rmw_read_bytes,
                             copy_bytes=cache_bytes)
    return {"donated": donated, "copied": copied,
            "cache_bytes": cache_bytes, "n_attn_layers": n_attn}


def decode_read_traffic(cfg: ModelConfig, batch: int, max_len: int,
                        occupancy: int, *, machines=None) -> list:
    """Per-machine dense-vs-split-KV decode *read* traffic, per step.

    The dense decode path streams every ``max_len`` cache row of every
    attention layer for every slot on every token; the split-KV kernel's
    block early-out streams only the occupied prefix, rounded up to the
    machine's autotuned KV block (:func:`bounded_decode_plan` — so the
    rounding itself is per-machine, and identical to what the executed
    kernel path uses). Rows carry both byte counts and their ratio
    (> 1 whenever the cache is not full): the serve-scale version of
    the paper's never-move-bytes-you-don't-need WA lesson, in read
    traffic instead of allocate traffic.
    """
    occupancy = max(1, min(int(occupancy), max_len))
    row_bytes = kv_row_bytes(cfg, batch)
    dense = row_bytes * max_len
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        plan, bound = bounded_decode_plan(cfg, batch, max_len,
                                          occupancy, m.name)
        split = row_bytes * bound
        rows.append({
            "machine": m.name, "bk": plan.bk, "n_splits": plan.n_splits,
            "occupancy": occupancy, "max_len": max_len,
            "dense_read_bytes": dense, "split_read_bytes": split,
            "read_ratio": dense / split,
            "n_attn_layers": attn_layer_count(cfg),
        })
    if not all(math.isfinite(r["read_ratio"]) for r in rows):
        raise AssertionError("non-finite KV read-traffic pricing")
    return rows


def kv_update_traffic(cfg: ModelConfig, batch: int, max_len: int, *,
                      machines=None, nt_stores: bool = False,
                      flavor: str | None = None) -> list:
    """Per-machine donated-vs-copied KV-update traffic, one dict per row.

    Rows carry the machine's WA mode, the per-decode-step traffic of the
    donated (in-place) update and of the non-donated (copy-first) update,
    and their delta — what cache donation saves on that machine, priced
    through its Fig. 4 behavioural mode with the SpecI2M gate modeled on
    the full cache working set.

    ``flavor`` switches pricing to the store-flavor path
    (repro.kernels.stores): ``"auto"`` resolves each machine's cheaper
    flavor against the cache working set, the residues come from the
    MemTier ladder, and every row records the ``store_flavor`` it was
    priced with. ``flavor=None`` keeps the legacy ``nt_stores``
    calibration-constant pricing (and records the flavor that implies).
    """
    from repro.kernels.stores import resolve_flavor
    profs = decode_kv_profiles(cfg, batch, max_len)
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        kw = dict(ws_bytes=profs["cache_bytes"], cores_active=m.cores)
        if flavor is not None:
            resolved = resolve_flavor(flavor, m, **kw)
            kw["flavor"] = resolved
        else:
            resolved = "nt" if nt_stores else "standard"
            kw["nt_stores"] = nt_stores
        donated = wa.priced_store_traffic(profs["donated"], m, **kw)
        copied = wa.priced_store_traffic(profs["copied"], m, **kw)
        rows.append({
            "machine": m.name, "wa_mode": m.wa_mode,
            "store_flavor": resolved,
            "stored_bytes": profs["donated"].stored_bytes,
            "donated_bytes": donated, "copied_bytes": copied,
            "delta_bytes": copied - donated,
            "cache_bytes": profs["cache_bytes"],
            "n_attn_layers": profs["n_attn_layers"],
        })
    if not math.isfinite(sum(r["delta_bytes"] for r in rows)):
        raise AssertionError("non-finite KV traffic pricing")
    return rows


# --- paged-KV traffic classes (repro.serve.pages) -------------------------

def page_bytes(cfg: ModelConfig, page_size: int) -> float:
    """Bytes one physical page holds across the stack (K and V, every
    attention layer, one slot's worth of rows)."""
    return kv_row_bytes(cfg, 1) * page_size


def page_gather_traffic(cfg: ModelConfig, batch: int, max_len: int,
                        occupancy: int, page_size: int, *,
                        machines=None, flavor: str = "auto") -> list:
    """Per-machine decode traffic of the paged engine, per step.

    Read side: only the ``ceil(occupancy / page)`` *live* pages of each
    slot are gathered (the block-table clamp in
    ``ops.flash_decode_paged``), plus the table entries themselves —
    one int32 per live page per layer per K/V leaf, the dependent load
    the dense path never issues. The gather is pure loads, so it is
    machine-invariant in bytes; the machine ordering of the total rides
    on the store side — the step's KV row writes, WA-priced against the
    page-pool working set exactly like :func:`kv_update_traffic` prices
    the dense ones. ``read_ratio`` compares against the dense
    full-horizon stream (> 1 whenever slots are not full).

    Rows also carry ``gather_seconds``: the ladder-resolved time of the
    gather (``memtier.page_gather_time``) with the pool as working set.
    """
    from repro.core import memtier
    from repro.serve.pages import pages_per_slot

    occupancy = max(1, min(int(occupancy), max_len))
    ps = int(page_size)
    pps = pages_per_slot(max_len, ps)
    live = min(math.ceil(occupancy / ps), pps)
    row = kv_row_bytes(cfg, batch)
    gather = kv_row_bytes(cfg, 1) * live * ps * batch
    n_attn = attn_layer_count(cfg)
    table = 2.0 * n_attn * batch * live * 4.0      # int32 entries, K and V
    dense = row * max_len
    profs = decode_kv_profiles(cfg, batch, pps * ps)
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        store = wa.priced_store_traffic(
            profs["donated"], m, ws_bytes=profs["cache_bytes"],
            cores_active=m.cores, flavor=flavor)
        res = memtier.page_gather_time(
            m, n_pages=live * batch, page_bytes=page_bytes(cfg, ps),
            table_bytes=table, ws_bytes=profs["cache_bytes"],
            cores_active=m.cores)
        rows.append({
            "machine": m.name, "page_size": ps, "live_pages": live,
            "occupancy": occupancy, "max_len": max_len,
            "gather_read_bytes": gather, "table_read_bytes": table,
            "store_bytes": store,
            "total_bytes": gather + table + store,
            "dense_read_bytes": dense,
            "read_ratio": dense / (gather + table),
            "gather_seconds": res.seconds,
            "n_attn_layers": n_attn,
        })
    if not all(math.isfinite(r["total_bytes"]) for r in rows):
        raise AssertionError("non-finite page-gather pricing")
    return rows


def cow_fork_traffic(cfg: ModelConfig, page_size: int, *,
                     n_copies: int = 1, machines=None,
                     flavor: str = "auto") -> list:
    """Per-machine cost of ``n_copies`` copy-on-write page copies.

    A CoW copy reads the shared page and stores a fresh one — the store
    is an allocating streaming write, so it carries each machine's WA
    ratio (Zen 4 pays the destination read, Grace's claim mode does
    not). Rows carry both the WA-priced bytes and the ladder-resolved
    seconds (``memtier.page_copy_time``).
    """
    from repro.core import memtier

    pb = page_bytes(cfg, int(page_size))
    read = pb * n_copies
    prof = wa.StoreProfile(stored_bytes=pb * n_copies, rmw_read_bytes=0.0)
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        store = wa.priced_store_traffic(prof, m, ws_bytes=2.0 * pb,
                                        cores_active=m.cores, flavor=flavor)
        res = memtier.page_copy_time(m, page_bytes=pb, n_pages=n_copies,
                                     cores_active=m.cores)
        rows.append({
            "machine": m.name, "page_size": int(page_size),
            "n_copies": int(n_copies), "page_bytes": pb,
            "read_bytes": read, "store_bytes": store,
            "total_bytes": read + store,
            "copy_seconds": res.seconds,
        })
    if not all(math.isfinite(r["total_bytes"]) for r in rows):
        raise AssertionError("non-finite CoW pricing")
    return rows


def page_admission_traffic(cfg: ModelConfig, prompt_len: int, max_len: int,
                           page_size: int, *, shared_pages: int = 0,
                           machines=None, flavor: str = "auto") -> list:
    """Per-machine admission stores: paged recycling vs dense zero-fill.

    A dense admission stores the *whole horizon*: prompt rows plus a
    zero-fill out to ``max_len`` (``make_prefill_step``'s in-graph
    ``pad_to_horizon``). A paged admission stores only the prompt's
    unshared pages — a recycled page is overwritten in place with no
    zero-fill at all (stale rows are masked by position), and a fresh
    page additionally pays its share of the pool's one-time zero init.
    All three are WA-priced as streaming stores against the same
    horizon-sized working set. ``recycled_bytes`` is strictly below
    ``zero_fill_bytes`` on every machine whenever the prompt's pages
    cover less than the horizon — the admission-side WA gate fig8
    asserts.
    """
    ps = int(page_size)
    npg = math.ceil(max(1, int(prompt_len)) / ps)
    shared = max(0, min(int(shared_pages), npg))
    row1 = kv_row_bytes(cfg, 1)
    ws = row1 * max_len
    prof_zero = wa.StoreProfile(stored_bytes=row1 * max_len,
                                rmw_read_bytes=0.0)
    payload = row1 * (npg - shared) * ps
    prof_recycled = wa.StoreProfile(stored_bytes=payload,
                                    rmw_read_bytes=0.0)
    prof_fresh = wa.StoreProfile(stored_bytes=2.0 * payload,
                                 rmw_read_bytes=0.0)
    rows = []
    for name in (machines if machines is not None else registered_names()):
        m = get_machine(name)
        kw = dict(ws_bytes=ws, cores_active=m.cores, flavor=flavor)
        zero = wa.priced_store_traffic(prof_zero, m, **kw)
        recycled = wa.priced_store_traffic(prof_recycled, m, **kw)
        fresh = wa.priced_store_traffic(prof_fresh, m, **kw)
        rows.append({
            "machine": m.name, "page_size": ps, "prompt_len": prompt_len,
            "max_len": max_len, "prompt_pages": npg,
            "shared_pages": shared,
            "zero_fill_bytes": zero, "recycled_bytes": recycled,
            "fresh_bytes": fresh,
            "savings_ratio": zero / max(recycled, 1e-30),
        })
    if not all(math.isfinite(r["savings_ratio"]) for r in rows):
        raise AssertionError("non-finite admission pricing")
    return rows


def rescue_traffic(cfg: ModelConfig, prompt_len: int, prefix_len: int,
                   max_len: int, *, page_size: int | None = None,
                   shared_pages: int = 0, machines=None,
                   flavor: str = "auto") -> list:
    """Per-machine cost of rescuing one stream by prompt+prefix replay.

    A rescue resubmits an ejected request as a fresh admission whose
    prompt is the original prompt plus the ``prefix_len`` tokens
    already emitted — the replay prefill rebuilds exactly the KV rows
    the dead replica held. The store side is the same WA-priced
    admission as any other (:func:`page_admission_traffic`): paged
    rescues pay only the replayed rows' unshared pages (prefix sharing
    makes a rescue onto a replica that served a sibling prompt nearly
    free), dense rescues pay the full horizon zero-fill. Returned rows
    add ``replay_tokens`` and ``rescue_bytes`` (the layout's admission
    store: ``recycled_bytes`` when paged, ``zero_fill_bytes`` when
    dense) so the health layer can log a priced rescue decision.
    """
    replay = int(prompt_len) + int(prefix_len)
    if replay > max_len:
        raise ValueError(
            f"rescue replay of {replay} tokens exceeds horizon {max_len}")
    ps = int(page_size) if page_size is not None else int(max_len)
    rows = page_admission_traffic(cfg, replay, max_len, ps,
                                  shared_pages=shared_pages,
                                  machines=machines, flavor=flavor)
    for r in rows:
        r["replay_tokens"] = replay
        r["rescue_bytes"] = r["recycled_bytes"] if page_size is not None \
            else r["zero_fill_bytes"]
    return rows
