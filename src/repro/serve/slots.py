"""Slot-batched KV cache: preallocated once, updated in place per slot.

The engine's cache is the ordinary model cache (``models.model.init_cache``)
with the batch dimension reinterpreted as **slots**. Cache leaves under
``"scan"`` are layer-stacked — their slot axis is 1; ``"tail"`` leaves
carry the slot axis at 0. Admitting a request writes one prefilled
slot-row into every leaf with a dynamic-update-slice (donated, so the
multi-MB slot cache is never copied as batch composition changes — the
whole point of slot preallocation over ``jnp.pad`` regrow).
"""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig

#: slot (batch) axis of cache leaves per top-level cache part
SLOT_AXIS = {"scan": 1, "tail": 0}


def make_insert_step(cfg: ModelConfig):
    """Build ``insert(cache, one, slot) -> cache``.

    ``one`` is a single-request cache (slot dim of size 1, same horizon);
    ``slot`` a traced scalar int32, so one compilation serves every slot.
    Donate ``cache`` at the jit boundary to keep the update in place.
    """
    del cfg  # structure is carried by the trees themselves

    def insert(cache, one, slot):
        out = {}
        for part, axis in SLOT_AXIS.items():
            if part not in cache:
                continue
            out[part] = jax.tree.map(
                lambda big, small, a=axis: jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=a),
                cache[part], one[part])
        return out

    return insert
