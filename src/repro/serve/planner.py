"""Analytical decode-chunk planning.

The serve engine amortizes per-dispatch overhead (Python loop, runtime
launch) over in-graph decode chunks. How many tokens a chunk should hold
depends on how long one decode step *takes* — which is exactly what the
analytical stack models: the decode step's compiled HLO is analyzed by
the port model (``portmodel.compare``) and the chunk size is chosen so
the modeled dispatch overhead stays below ``overhead_frac`` of the
tier-resolved per-step cost (``Report.tier_bound_seconds``).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import portmodel
from repro.core.machine import get_machine, registered_names
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Planned decode chunk: size, the machine it was planned for, the
    tier-resolved per-step model cost there, and the per-machine costs of
    every machine the module was compared on."""

    chunk: int
    machine: str
    t_step_seconds: float
    per_machine: dict            # machine name -> tier-resolved step seconds


def decode_step_hlo(cfg: ModelConfig, batch: int, max_len: int,
                    n_tokens: int = 1, temperature: float = 0.0) -> str:
    """Compiled HLO text of one n-token decode chunk at serve shapes.

    Lowered against abstract shapes only — no parameters or cache are
    materialized.
    """
    from repro.serve.decode import make_chunked_decode_step

    step = make_chunked_decode_step(cfg, n_tokens, temperature)
    pshapes = M.param_shapes(cfg)
    cshapes = M.cache_shapes(cfg, batch, max_len)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return jax.jit(step, donate_argnums=(1,)).lower(
        pshapes, cshapes, tok, pos, key).compile().as_text()


def plan_chunk_size(cfg: ModelConfig, batch: int, max_len: int, *,
                    machine: str | None = None,
                    dispatch_overhead_s: float = 2e-4,
                    overhead_frac: float = 0.1,
                    max_chunk: int = 32,
                    hlo_text: str | None = None) -> ChunkPlan:
    """Pick the decode chunk size from the port model's per-step cost.

    chunk = ceil(dispatch_overhead / (overhead_frac * t_step)) clamped to
    [1, max_chunk]: enough in-graph tokens that the per-dispatch overhead
    is at most ``overhead_frac`` of the modeled chunk time. ``machine``
    defaults to ``host_cpu`` when calibrated, else the first registered
    machine; the compare fan-out prices every registered machine and the
    full table is kept on the plan for reporting (benchmarks/fig6).
    """
    if machine is None:
        names = registered_names()
        machine = "host_cpu" if "host_cpu" in names else names[0]
    if hlo_text is None:
        hlo_text = decode_step_hlo(cfg, batch, max_len, n_tokens=1)
    reports = portmodel.compare(hlo_text)
    per_machine = {name: rep.tier_bound_seconds(get_machine(name))
                   for name, rep in reports.items()}
    t_step = per_machine.get(machine)
    if t_step is None:
        t_step = portmodel.analyze(hlo_text, machine).tier_bound_seconds(
            get_machine(machine))
        per_machine[get_machine(machine).name] = t_step
    chunk = 1 if t_step <= 0 else math.ceil(
        dispatch_overhead_s / (overhead_frac * t_step))
    chunk = max(1, min(max_chunk, chunk))
    return ChunkPlan(chunk=chunk, machine=get_machine(machine).name,
                     t_step_seconds=t_step, per_machine=per_machine)
