"""Analytical decode-chunk planning.

The serve engine amortizes per-dispatch overhead (Python loop, runtime
launch) over in-graph decode chunks. How many tokens a chunk should hold
depends on how long one decode step *takes* — which is exactly what the
analytical stack models: the decode step's compiled HLO is analyzed by
the port model (``portmodel.compare``) and the chunk size is chosen so
the modeled dispatch overhead stays below ``overhead_frac`` of the
tier-resolved per-step cost (``Report.tier_bound_seconds``).

Two things make planning cheap and occupancy-aware:

* **Memoized planning** — lowering the decode step and fanning
  ``portmodel.compare`` across the registry is orders of magnitude more
  expensive than the arithmetic around it, and every engine
  construction (and benchmark cell) replans. Both the HLO text and the
  finished plans are cached on ``(cfg, batch, max_len, ..., registered
  machine set)`` so repeat plans are O(1) dict hits.
* **Kernel-path pricing** — the compiled HLO prices the *dense* decode
  step: every slot reads the full ``max_len`` horizon. When the engine
  routes attention through the split-KV kernel, the only term that
  changes is the KV read traffic — bounded by occupancy rounded to the
  machine's autotuned KV block, not by the horizon. ``plan_chunk_size``
  re-prices that term through the memory ladder per machine
  (:func:`kv_read_seconds`), so the chunk size tracks how full the
  cache actually is.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import memtier, portmodel
from repro.core.machine import (get_machine, registered_names,
                                registry_fingerprint)
from repro.models import model as M

#: (cfg, batch, max_len, n_tokens, temperature) -> compiled HLO text
_HLO_CACHE: dict = {}
#: full plan key (incl. registry content fingerprint) -> ChunkPlan
_PLAN_CACHE: dict = {}
#: planner invocation counters — how each plan request was satisfied.
#: The plan-DB regression tests pin ``online_plans == 0`` on a DB hit.
_PLAN_STATS = {"online_plans": 0, "memo_hits": 0, "db_hits": 0}


def plan_stats() -> dict:
    """Counters of how plan requests were served since the last reset.

    ``online_plans`` counts full plans (HLO lowering + port-model
    compare fan-out), ``memo_hits`` in-process memo returns, and
    ``db_hits`` plans loaded from an installed plan database
    (repro.serve.plandb). The plan-DB acceptance test pins that a DB
    hit performs *zero* online planning.
    """
    return dict(_PLAN_STATS)


def reset_plan_stats() -> None:
    """Zero the planner invocation counters (tests and benchmarks)."""
    for k in _PLAN_STATS:
        _PLAN_STATS[k] = 0


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Planned decode chunk: size, the machine it was planned for, the
    tier-resolved per-step model cost there, and the per-machine costs of
    every machine the module was compared on. When the plan priced the
    split-KV kernel path, ``occupancy`` records the bound it assumed and
    ``per_machine_dense`` keeps the unadjusted full-horizon costs."""

    chunk: int
    machine: str
    t_step_seconds: float
    per_machine: dict            # machine name -> tier-resolved step seconds
    occupancy: int | None = None
    per_machine_dense: dict | None = None
    # which scheduling backend priced the step (core/backends)
    backend: str = "tp_bound"
    # KV-writer store flavor resolved for the plan's machine
    # (repro.kernels.stores) and the per-machine selections
    store_flavor: str = "standard"
    per_machine_flavor: dict | None = None
    # paged-KV geometry the plan was priced for (None = dense slots):
    # the occupancy bound rounds to the page grid, not the autotuned
    # KV block, because a page is the paged kernel's DMA unit
    page_size: int | None = None
    # tensor-parallel degree the plan priced (1 = unsharded): the KV
    # stream is divided per shard and the per-step activation
    # all-reduce (kv_traffic.collective_traffic) is added per machine
    tp: int = 1
    # machine name -> seconds of the per-step collective (tp > 1 only)
    per_machine_collective: dict | None = None


def clear_plan_cache() -> None:
    """Drop every memoized planning artifact, together.

    Clears the lowered-HLO memo, the finished-plan memo, AND the tile
    autotuner's memo (repro.kernels.tuning) in one call — the three
    caches answer the same "what should this machine run" question, so
    tests that re-register machines (or swap a plan DB) must never see
    one cache invalidated and another serving stale answers. Note the
    memo keys also fold content fingerprints of the registered
    machines, so a ``register(replace=True)`` with *different* machine
    parameters misses the memo even without this call — clearing is
    for reclaiming memory and forcing DB re-consultation, not the only
    staleness defense.
    """
    _HLO_CACHE.clear()
    _PLAN_CACHE.clear()
    from repro.kernels import tuning
    tuning.clear_cache()


def decode_step_hlo(cfg: ModelConfig, batch: int, max_len: int,
                    n_tokens: int = 1, temperature: float = 0.0,
                    attn_impl: str | None = None,
                    kv_len: int | None = None) -> str:
    """Compiled HLO text of one n-token decode chunk at serve shapes.

    Lowered against abstract shapes only — no parameters or cache are
    materialized. Results are memoized on the full argument key (cfg is
    a frozen dataclass, so identical configs share an entry).
    """
    key = (cfg, batch, max_len, n_tokens, temperature, attn_impl, kv_len)
    hit = _HLO_CACHE.get(key)
    if hit is not None:
        return hit
    from repro.serve.decode import make_chunked_decode_step

    step = make_chunked_decode_step(cfg, n_tokens, temperature,
                                    attn_impl=attn_impl, kv_len=kv_len)
    pshapes = M.param_shapes(cfg)
    cshapes = M.cache_shapes(cfg, batch, max_len)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    key_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    text = jax.jit(step, donate_argnums=(1,)).lower(
        pshapes, cshapes, tok, pos, key_shape).compile().as_text()
    _HLO_CACHE[key] = text
    return text


def kv_read_seconds(cfg: ModelConfig, batch: int, kv_tokens: int,
                    machine, *, max_len: int | None = None,
                    tp: int = 1) -> float:
    """Tier-resolved seconds one decode step spends streaming KV.

    ``kv_tokens`` cache rows per slot, K and V, every attention layer —
    the traffic term that distinguishes the dense path (``kv_tokens =
    max_len``) from the split-KV kernel (``kv_tokens`` = occupancy
    rounded to the machine's block). The working set is the allocated
    cache (``max_len`` horizon), so the read resolves to the tier the
    slot cache actually lives in on that machine. ``tp`` divides both
    the stream and the working set per tensor-parallel shard (the
    kvheads -> TP cache layout): a shard streams ``1/tp`` of the rows'
    bytes, and its cache slice may even home a tier *inward* of the
    unsharded one.
    """
    from repro.serve.kv_traffic import kv_row_bytes
    tp = max(1, int(tp))
    row = kv_row_bytes(cfg, batch) / tp
    ws = row * (max_len if max_len is not None else kv_tokens)
    m = get_machine(machine)
    return memtier.memory_seconds(m, row * kv_tokens, ws_bytes=ws,
                                  store_frac=0.0,
                                  cores_active=getattr(m, "cores", 1)
                                  ).seconds


def _kernel_adjusted(cfg: ModelConfig, batch: int, max_len: int,
                     occupancy: int | None, per_machine: dict,
                     page_size: int | None = None, tp: int = 1,
                     collective: dict | None = None) -> dict:
    """Re-price per-machine dense step costs for the executed KV path.

    Swaps the full-horizon unsharded KV read the compiled HLO priced
    for the one the engine actually streams: bounded by ``occupancy``
    when the split-KV kernel is routed — tiled and rounded exactly as
    the executed kernel path would be
    (``kv_traffic.bounded_decode_plan``; with ``page_size`` set the
    bound rounds to the page grid instead, since the paged kernel's KV
    block is pinned to the page) — and divided per shard when the
    cache is TP-sharded (``tp`` > 1, the kvheads layout). ``collective``
    adds each machine's per-step activation all-reduce seconds
    (``kv_traffic.collective_traffic``) on top. The floor keeps the
    adjusted cost from going below the priced KV stream itself when
    the port model and the ladder disagree about the dense share.
    """
    from repro.serve.kv_traffic import bounded_decode_plan
    out = {}
    for name, t_dense in per_machine.items():
        if occupancy is None:
            bound = max_len
        elif page_size is not None:
            bound = min(math.ceil(occupancy / page_size) * page_size,
                        max_len)
        else:
            _, bound = bounded_decode_plan(cfg, batch, max_len, occupancy,
                                           name)
        dense_kv = kv_read_seconds(cfg, batch, max_len, name,
                                   max_len=max_len)
        split_kv = kv_read_seconds(cfg, batch, bound, name,
                                   max_len=max_len, tp=tp)
        coll = (collective or {}).get(name, 0.0)
        out[name] = max(t_dense - dense_kv + split_kv + coll,
                        split_kv + coll, 1e-12)
    return out


def plan_chunk_size(cfg: ModelConfig, batch: int, max_len: int, *,
                    machine: str | None = None,
                    dispatch_overhead_s: float = 2e-4,
                    overhead_frac: float = 0.1,
                    max_chunk: int = 32,
                    hlo_text: str | None = None,
                    occupancy: int | None = None,
                    backend: str = "tp_bound",
                    store_flavor: str = "auto",
                    page_size: int | None = None,
                    mesh=None, rules: dict | None = None,
                    tp: int | None = None) -> ChunkPlan:
    """Pick the decode chunk size from the port model's per-step cost.

    chunk = ceil(dispatch_overhead / (overhead_frac * t_step)) clamped to
    [1, max_chunk]: enough in-graph tokens that the per-dispatch overhead
    is at most ``overhead_frac`` of the modeled chunk time. ``machine``
    defaults to ``host_cpu`` when calibrated, else the first registered
    machine; the compare fan-out prices every registered machine and the
    full table is kept on the plan for reporting (benchmarks/fig6).

    ``occupancy`` switches the plan to the split-KV kernel path: the
    per-machine costs are re-priced with the KV read bounded by that
    many rows (rounded to each machine's autotuned block), so a nearly
    empty cache plans *larger* chunks than a full one. ``backend``
    picks the scheduling backend that prices the step (core/backends):
    the default analytical ``tp_bound`` keeps plans identical to the
    pre-backend-split planner; ``mca_sched`` plans against the
    simulator's pessimistic-or-equal step cost (never a larger chunk
    than the default). Plans (and the lowered HLO) are memoized;
    passing an explicit ``hlo_text`` bypasses the plan cache.

    ``store_flavor`` ("standard" | "nt" | "auto") is resolved per
    machine against the slot cache working set
    (repro.kernels.stores) and recorded on the plan — ``auto`` picks
    each machine's cheaper modeled store path, so every plan knows
    which KV-writer flavor it was priced for.

    ``page_size`` records paged-KV geometry (repro.serve.pages): the
    occupancy bound then rounds to the page grid (the paged kernel's
    KV block is pinned to the page) instead of the machine's autotuned
    dense block.

    ``mesh``/``rules`` switch the plan to sharded pricing: the TP
    degree is read off the mesh through the rules' ``kvheads`` axes
    (``sharding.tp_degree``), the KV stream is divided per shard, and
    the per-step activation all-reduce
    (``kv_traffic.collective_traffic``) is priced per machine and
    added to every per-machine cost. The memo key folds the mesh axis
    sizes, a rules fingerprint, and the TP degree, so a sharded plan
    never serves an unsharded admission (and vice versa). Passing
    ``tp`` *without* a mesh synthesizes the serve layout a real
    ``(data=1, model=tp)`` mesh would present — the offline plan-DB
    sweep (repro.serve.plandb) prices sharded plans on machines with
    no such mesh available, under exactly the memo/DB key a real
    sharded engine computes at admission.

    Resolution order: in-process memo, then an installed plan database
    (``repro.serve.plandb.install``), then a full online plan. The DB
    key folds content fingerprints of the config and every registered
    machine, so a stale DB entry can never outlive a model-config or
    machine-parameter change — it simply misses and the planner falls
    back online, bit-identically.
    """
    from repro.core.backends import get_backend
    from repro.utils.sharding import (SERVE_ENGINE_RULES, mesh_axis_sizes,
                                      rules_fingerprint, tp_degree)
    backend = get_backend(backend).name     # canonical (aliases fold)
    if machine is None:
        names = registered_names()
        machine = "host_cpu" if "host_cpu" in names else names[0]
    if mesh is not None and rules is None:
        rules = SERVE_ENGINE_RULES
    if mesh is not None:
        mesh_sizes = mesh_axis_sizes(mesh)
        tp = tp_degree(mesh_sizes, rules)
    elif tp is not None and int(tp) > 1:
        # meshless sharded pricing: stand in for a (1, tp) serve mesh
        mesh_sizes = {"data": 1, "model": int(tp)}
        rules = SERVE_ENGINE_RULES if rules is None else rules
        tp = tp_degree(mesh_sizes, rules)
    else:
        mesh_sizes, tp = {}, 1
    cache_key = None
    if hlo_text is None:
        cache_key = (cfg, batch, max_len, machine, dispatch_overhead_s,
                     overhead_frac, max_chunk, occupancy, backend,
                     store_flavor, page_size,
                     tuple(sorted(mesh_sizes.items())),
                     rules_fingerprint(rules), tp, registry_fingerprint())
        hit = _PLAN_CACHE.get(cache_key)
        if hit is not None:
            _PLAN_STATS["memo_hits"] += 1
            return hit
        from repro.serve import plandb
        db = plandb.installed()
        if db is not None:
            dbhit = db.lookup_chunk(
                cfg, batch, max_len, machine=machine,
                dispatch_overhead_s=dispatch_overhead_s,
                overhead_frac=overhead_frac, max_chunk=max_chunk,
                occupancy=occupancy, backend=backend,
                store_flavor=store_flavor, page_size=page_size,
                mesh_sizes=mesh_sizes,
                rules_fp=rules_fingerprint(rules), tp=tp)
            if dbhit is not None:
                _PLAN_STATS["db_hits"] += 1
                _PLAN_CACHE[cache_key] = dbhit
                return dbhit
        hlo_text = decode_step_hlo(cfg, batch, max_len, n_tokens=1)
    _PLAN_STATS["online_plans"] += 1
    reports = portmodel.compare(hlo_text, backends=backend)
    per_machine = {name: rep.tier_bound_seconds(get_machine(name))
                   for name, rep in reports.items()}
    if per_machine.get(machine) is None:
        per_machine[get_machine(machine).name] = portmodel.analyze(
            hlo_text, machine,
            backend=backend).tier_bound_seconds(get_machine(machine))
    from repro.kernels.stores import resolve_flavor
    from repro.serve.kv_traffic import collective_traffic, kv_row_bytes
    cache_ws = kv_row_bytes(cfg, batch) * max_len
    per_machine_collective = None
    if tp > 1:
        per_machine_collective = {
            r["machine"]: r["coll_seconds"]
            for r in collective_traffic(cfg, batch, tp,
                                        machines=tuple(per_machine),
                                        ws_bytes=cache_ws)}
    per_machine_dense = None
    if occupancy is not None or tp > 1:
        per_machine_dense = dict(per_machine)
        per_machine = _kernel_adjusted(cfg, batch, max_len, occupancy,
                                       per_machine, page_size=page_size,
                                       tp=tp,
                                       collective=per_machine_collective)
    t_step = per_machine[get_machine(machine).name]
    chunk = 1 if t_step <= 0 else math.ceil(
        dispatch_overhead_s / (overhead_frac * t_step))
    chunk = max(1, min(max_chunk, chunk))
    per_machine_flavor = {
        name: resolve_flavor(store_flavor, name, ws_bytes=cache_ws,
                             cores_active=get_machine(name).cores)
        for name in per_machine}
    plan = ChunkPlan(chunk=chunk, machine=get_machine(machine).name,
                     t_step_seconds=t_step, per_machine=per_machine,
                     occupancy=occupancy,
                     per_machine_dense=per_machine_dense,
                     backend=backend,
                     store_flavor=per_machine_flavor[
                         get_machine(machine).name],
                     per_machine_flavor=per_machine_flavor,
                     page_size=page_size, tp=tp,
                     per_machine_collective=per_machine_collective)
    if cache_key is not None:
        _PLAN_CACHE[cache_key] = plan
    return plan


def planned_round_seconds(plan: ChunkPlan, chunk: int | None = None,
                          dispatch_overhead_s: float = 2e-4,
                          machine: str | None = None) -> float:
    """Modeled wall seconds of one decode round at ``chunk`` tokens.

    ``chunk`` in-graph steps at the plan's tier-resolved per-step cost
    plus one dispatch overhead — the health tracker's latency budget
    (repro.serve.health) and the fault injector's virtual-clock unit
    (repro.serve.faults) both come from here, so "slow" is always
    *slow relative to what the port model predicts for this machine*,
    not an absolute wall-clock constant. ``machine`` prices the round
    on another registered machine's column of the plan (default: the
    plan's own machine).
    """
    c = plan.chunk if chunk is None else max(1, int(chunk))
    t = plan.t_step_seconds if machine is None \
        else plan.per_machine[machine]
    return c * t + dispatch_overhead_s
