"""Replica router: admission control over N serve-engine replicas.

One :class:`ServeEngine` (or :class:`PagedServeEngine`) is a single
continuous-batching domain: every active request shares its slot cache,
its chunk clock, and — when mesh-sharded — its device mesh. Scaling
*traffic* rather than model size means running N such engines
side-by-side and deciding, per request, which replica admits it. That
admission decision is this module.

The router is deliberately engine-shaped rather than wall-clock-shaped:
it owns per-replica *pending queues* and a ``step()`` that advances
every replica one decode round, so the closed-loop load harness
(benchmarks/fig9_load) can drive it on a virtual clock and the launch
driver can drive it in real time with the same code.

Admission policies:

- ``round_robin`` — strict rotation over replicas; queue depth is
  ignored. Predictable, and optimal when requests are i.i.d.
- ``least_loaded`` — each submit goes to the replica with the fewest
  committed tokens (active decode work + queued requests); ties break
  toward the lowest index. This is the policy that absorbs bursty
  arrival traces without head-of-line blocking one replica.

Backpressure: each replica queue holds at most ``max_queue`` waiting
requests. A submit that finds its chosen replica full raises
:class:`QueueFull` — the caller (generator, launch loop) decides
whether to retry after a ``step()`` or to shed the request. Nothing is
silently dropped.

Cancel/fork forwarding: the router remembers which replica owns each
request id, so ``cancel`` reaches into the owning replica (or silently
removes a still-queued request) and ``fork`` lands the clone on the
parent's replica — pages can only be shared inside one engine's pool.
"""

from __future__ import annotations

from collections import deque

import numpy as np


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the chosen replica's queue is full."""


class ReplicaRouter:
    """Route requests across serve-engine replicas; drive them in rounds.

    ``replicas`` is a non-empty list of already-constructed engines
    (mixing dense and paged replicas is allowed — ``fork`` simply only
    works on requests owned by a paged replica). All replicas are
    assumed to serve the same model; the router never inspects params.
    """

    POLICIES = ("round_robin", "least_loaded")

    def __init__(self, replicas: list, *, policy: str = "round_robin",
                 max_queue: int = 8):
        if not replicas:
            raise ValueError("need at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"known: {self.POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.max_queue = int(max_queue)
        self.queues = [deque() for _ in self.replicas]
        self._rr = 0                     # next round-robin replica
        self._owner: dict = {}           # rid -> replica index
        self.submitted = [0] * len(self.replicas)
        self.completed = [0] * len(self.replicas)
        # robustness counters (surfaced by stats()): per-replica decode
        # failures, ``run()`` retry attempts, and requests shed after
        # the retry budget — attributed to the replica that refused the
        # final attempt. ``shed_rids`` names every shed request so a
        # drop is never silent; ``quarantined`` collects streams the
        # engines' non-finite guard pulled out of their batches.
        self.failed = [0] * len(self.replicas)
        self.retries = [0] * len(self.replicas)
        self.shed = [0] * len(self.replicas)
        self.shed_rids: list = []
        self.quarantined: list = []      # (rid, tokens-so-far) pairs

    # -- admission ----------------------------------------------------------
    def _active_tokens(self, i: int) -> int:
        """Committed decode work on replica ``i``: tokens still owed by
        its active slots plus everything waiting in its queue."""
        eng = self.replicas[i]
        owed = sum(s.remaining for s in eng.slots if s is not None)
        queued = sum(r.max_new_tokens for r in self.queues[i])
        return owed + queued

    def _pick(self) -> int:
        if self.policy == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % len(self.replicas)
            return i
        return min(range(len(self.replicas)), key=self._active_tokens)

    def submit(self, req) -> int:
        """Enqueue one request; returns the replica index it landed on.

        Raises :class:`QueueFull` when the chosen replica's queue is at
        ``max_queue`` (round-robin does *not* hunt for a free queue —
        backpressure is the signal the load generator keys off).
        """
        if req.rid in self._owner:
            raise ValueError(f"duplicate request id {req.rid!r}")
        i = self._pick()
        if len(self.queues[i]) >= self.max_queue:
            err = QueueFull(
                f"replica {i} queue full ({self.max_queue} waiting)")
            err.replica = i              # lets run() attribute the shed
            raise err
        self.queues[i].append(req)
        self._owner[req.rid] = i
        self.submitted[i] += 1
        # prefetch the prompt to the chosen replica's device while the
        # request waits in queue (repro.serve.staging): admission then
        # skips the H2D copy. Rescue replays resubmit through here, so
        # rescued prompt+prefix streams are staged for free.
        stage = getattr(self.replicas[i], "stage", None)
        if stage is not None:
            stage(req)
        return i

    def cancel(self, rid: str):
        """Abort a request wherever it lives; tokens so far or None.

        A still-queued request is removed before it ever touches a
        slot (returns an empty token array); an active one forwards to
        its replica's ``cancel`` (paged replicas recycle its pages).
        """
        i = self._owner.pop(rid, None)
        if i is None:
            return None
        for r in list(self.queues[i]):
            if r.rid == rid:
                self.queues[i].remove(r)
                self.completed[i] += 1
                return np.zeros((0,), np.int32)
        out = self.replicas[i].cancel(rid)
        if out is not None:
            self.completed[i] += 1
        return out

    def fork(self, rid: str, new_rid: str,
             max_new_tokens: int | None = None) -> int:
        """Fork an *active* request on its owning (paged) replica.

        Returns the replica index the clone runs on (always the
        parent's — CoW pages cannot cross page pools). Raises
        ``KeyError`` for unknown/queued rids and ``AttributeError``
        when the owning replica is dense.
        """
        i = self._owner.get(rid)
        if i is None:
            raise KeyError(f"no such request {rid!r}")
        self.replicas[i].fork(rid, new_rid, max_new_tokens)
        self._owner[new_rid] = i
        self.submitted[i] += 1
        return i

    # -- rounds -------------------------------------------------------------
    def step(self) -> list:
        """One router round: admit what fits, decode every busy replica.

        Per replica: pop queued requests into free slots (prefill +
        insert), then run one chunked decode round. Returns all
        requests retired this round as (rid, tokens) pairs, across
        replicas.
        """
        retired = []
        for i, eng in enumerate(self.replicas):
            q = self.queues[i]
            while q and eng.free_slots():
                eng.admit(q.popleft())
            if any(s is not None for s in eng.slots):
                done = eng.step()
            else:
                done = []
            for rid, toks in done:
                self._owner.pop(rid, None)
                self.completed[i] += 1
            retired.extend(done)
            for rid, toks in self._drain_quarantined(i, eng):
                self._owner.pop(rid, None)
                self._on_quarantined(i, rid, toks)
        return retired

    @staticmethod
    def _drain_quarantined(i: int, eng) -> list:
        """Pull the engine's non-finite-guard quarantine list, if any."""
        drain = getattr(eng, "drain_quarantined", None)
        return drain() if drain is not None else []

    def _on_quarantined(self, i: int, rid: str, toks) -> None:
        """A stream the guard pulled from replica ``i``'s batch.

        The base router records it as failed (tokens-so-far kept on
        ``self.quarantined`` — never silently lost); the
        fault-tolerant router overrides this to rescue the stream on a
        healthy replica instead.
        """
        self.failed[i] += 1
        self.quarantined.append((rid, toks))

    def busy(self) -> bool:
        """True while any replica has queued or active work."""
        return any(self.queues) or any(
            s is not None for eng in self.replicas for s in eng.slots)

    def _shed(self, req, replica: int, reason: str) -> None:
        """Drop one request after its retry budget is spent.

        Recorded, never silent: the rid lands on ``shed_rids`` and the
        per-replica ``shed`` counter (attributed to the replica that
        refused the final attempt) feeds ``stats()``.
        """
        self.shed[replica] += 1
        self.shed_rids.append(req.rid)

    def run(self, requests: list, *, max_retries: int = 8,
            backoff_base: int = 1, seed: int = 0,
            stall_rounds: int = 256) -> dict:
        """Serve a request list to completion: {rid: (n_tokens,) int32}.

        Submits as backpressure allows, then drains. ``QueueFull`` is
        retried at most ``max_retries`` times per request with
        exponential backoff in *rounds* (``backoff_base * 2**attempt``
        plus seeded jitter — rounds, not wall seconds, so the policy is
        identical on the virtual clock); a request that exhausts its
        budget is shed via :meth:`_shed` and reported in ``stats()``
        rather than retried forever. If ``stall_rounds`` consecutive
        rounds pass with no completion, no queue movement, no slot
        progress, and no retry pending, the router raises
        ``RuntimeError`` instead of spinning — the every-replica-wedged
        case is loud, not an infinite loop. This is the offline-batch
        path; the load harness drives ``submit``/``step`` itself to
        model arrival processes.
        """
        rng = np.random.default_rng(seed)
        pending = deque(requests)
        results: dict = {}
        attempts: dict = {}              # rid -> failed submit attempts
        not_before: dict = {}            # rid -> earliest retry round
        round_idx = 0
        stalled = 0
        last_sig = None
        while pending or self.busy():
            waiting = deque()
            while pending:
                req = pending.popleft()
                if not_before.get(req.rid, 0) > round_idx:
                    waiting.append(req)
                    continue
                try:
                    self.submit(req)
                except QueueFull as e:
                    n = attempts.get(req.rid, 0) + 1
                    attempts[req.rid] = n
                    replica = getattr(e, "replica",
                                      len(self.replicas) - 1)
                    if n > max_retries:
                        self._shed(req, replica, str(e))
                        continue
                    self.retries[replica] += 1
                    delay = backoff_base * (2 ** (n - 1))
                    delay += int(rng.integers(0, delay + 1))  # jitter
                    not_before[req.rid] = round_idx + delay
                    waiting.append(req)
            pending = waiting
            for rid, toks in self.step():
                results[rid] = toks
            round_idx += 1
            sig = (len(results), sum(self.completed), sum(self.shed),
                   tuple(len(q) for q in self.queues),
                   sum(s.remaining for eng in self.replicas
                       for s in eng.slots if s is not None))
            backing_off = any(r > round_idx for r in not_before.values())
            if sig == last_sig and not backing_off:
                stalled += 1
                if stalled >= stall_rounds:
                    raise RuntimeError(
                        f"router made no progress for {stalled} rounds "
                        f"({len(pending)} pending, "
                        f"{sum(len(q) for q in self.queues)} queued)")
            else:
                stalled = 0
            last_sig = sig
        return results

    def stats(self) -> list:
        """Per-replica counters: queue/progress plus robustness tallies.

        ``failed`` counts decode-round faults, ``retries`` the
        backoff-retried submits this replica refused, ``shed`` the
        requests dropped after the retry budget — all per replica, so
        a sick replica is visible in one row. ``pipeline`` and
        ``mean_dispatch_gap_s`` surface each replica's overlapped-
        runtime state: the in-flight round bound (0 = serial) and the
        measured mean host gap between decode-dispatch enqueues — the
        number fig11 gates on, readable live mid-serve.
        """
        return [{"replica": i,
                 "queued": len(self.queues[i]),
                 "active": sum(s is not None for s in eng.slots),
                 "submitted": self.submitted[i],
                 "completed": self.completed[i],
                 "failed": self.failed[i],
                 "retries": self.retries[i],
                 "shed": self.shed[i],
                 "pipeline": getattr(eng, "pipeline", 0),
                 "mean_dispatch_gap_s": (
                     eng.stats().get("mean_dispatch_gap_s", 0.0)
                     if hasattr(eng, "stats") else 0.0)}
                for i, eng in enumerate(self.replicas)]
