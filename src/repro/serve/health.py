"""Replica health, request rescue, and priced graceful degradation.

The plain :class:`~repro.serve.router.ReplicaRouter` treats replicas
as always-correct and always-on-time; the only failure signal is
``QueueFull``. This module adds the model-driven fault-tolerance
layer on top of it:

- :class:`ReplicaHealth` — a per-replica state machine scored on
  *consecutive failures* and *step latency vs. the planned budget*,
  where the budget is the port model's tier-resolved per-round
  seconds (:func:`repro.serve.planner.planned_round_seconds`). "Slow"
  therefore always means slow *for this machine* — a Grace replica
  and a Genoa replica each get their own baseline, which is what the
  per-machine variability across the paper's three cores demands.

  ::

      healthy --strike x fail_threshold--> quarantined (drain)
      quarantined --success--> healthy          (re-admit)
      quarantined --strike x eject_threshold--> ejected (rescue)
      ejected --cooldown_rounds--> probing
      probing --probe_successes--> healthy
      probing --strike--> ejected               (re-eject)

- **Request rescue** — when a replica is ejected (or a stream is
  quarantined by the engines' non-finite guard), its in-flight
  requests are *not* lost: each is resubmitted to a healthy replica
  as a replay of ``prompt + tokens-so-far`` with the remaining token
  budget, and the completed stream is the emitted prefix plus the
  replayed continuation — byte-identical to the fault-free stream
  under greedy decoding. Every rescue is priced through
  :func:`repro.serve.kv_traffic.rescue_traffic` (prefix sharing makes
  a paged rescue pay only the replayed rows' unshared pages).

- **Priced degradation** — under page-pool exhaustion or deadline
  pressure the router chooses between keeping the plan, re-planning a
  smaller chunk (``set_chunk``: lower per-round latency, more
  dispatch overhead), and shedding, via
  :func:`priced_degradation` — the same modeled-seconds comparison
  that picks chunk sizes and store flavors everywhere else in the
  repo. Every decision is logged with all its priced options so the
  fig10 chaos artifact can justify each one.

Everything runs on the router's virtual clock (``now_s`` advances by
the slowest stepped replica's reported seconds each round), so the
whole layer is deterministic under the fault injector
(repro.serve.faults) and testable without wall-clock sleeps.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque

import numpy as np

from repro.serve.engine import Request
from repro.serve.faults import TransientFault
from repro.serve.kv_traffic import rescue_traffic
from repro.serve.pages import PoolExhausted
from repro.serve.planner import planned_round_seconds
from repro.serve.router import QueueFull, ReplicaRouter

STATES = ("healthy", "quarantined", "ejected", "probing")


class NoHealthyReplica(QueueFull):
    """Raised by ``submit`` when no replica is admissible right now.

    Subclasses :class:`~repro.serve.router.QueueFull` so the bounded
    retry/backoff policy in ``run()`` applies unchanged: back off and
    retry while cooldowns elapse, shed only after the budget.
    """


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds for the per-replica health state machine.

    ``fail_threshold`` consecutive strikes quarantine a replica
    (drain: no new admissions, existing work continues);
    ``eject_threshold`` strikes eject it (every in-flight request is
    rescued elsewhere). A strike is a failed round, a failed
    admission, or a round slower than ``latency_factor`` × the
    planned per-round budget. Ejected replicas re-enter as probing
    after ``cooldown_rounds`` and must put up ``probe_successes``
    clean rounds before counting as healthy again.
    """

    fail_threshold: int = 3
    eject_threshold: int = 5
    latency_factor: float = 20.0
    cooldown_rounds: int = 4
    probe_successes: int = 2


class ReplicaHealth:
    """One replica's health state machine (see module diagram).

    ``strike()`` and ``success()`` drive transitions; ``tick()``
    advances the ejection cooldown once per router round.
    ``transitions`` keeps every (round, from, to) edge for the chaos
    artifact.
    """

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self.state = "healthy"
        self.strikes = 0
        self.successes = 0
        self.cooldown = 0
        self.transitions: list = []

    def admissible(self) -> bool:
        """May new work land here? (healthy or probing)"""
        return self.state in ("healthy", "probing")

    def steppable(self) -> bool:
        """Should the router still step this replica? (not ejected)"""
        return self.state != "ejected"

    def _to(self, state: str, round_idx: int) -> None:
        self.transitions.append((round_idx, self.state, state))
        self.state = state

    def strike(self, round_idx: int) -> bool:
        """Record one failure; returns True when this strike ejects.

        The caller must rescue the replica's in-flight work when True
        is returned (the state machine only tracks, never touches
        requests).
        """
        self.successes = 0
        self.strikes += 1
        if self.state == "probing":
            self._to("ejected", round_idx)
            self.cooldown = self.cfg.cooldown_rounds
            return True
        if (self.state == "healthy"
                and self.strikes >= self.cfg.fail_threshold):
            self._to("quarantined", round_idx)
        if (self.state == "quarantined"
                and self.strikes >= self.cfg.eject_threshold):
            self._to("ejected", round_idx)
            self.cooldown = self.cfg.cooldown_rounds
            return True
        return False

    def success(self, round_idx: int) -> None:
        """Record one clean round; may re-admit a draining replica."""
        if self.state == "quarantined":
            self._to("healthy", round_idx)
            self.strikes = 0
        elif self.state == "probing":
            self.successes += 1
            if self.successes >= self.cfg.probe_successes:
                self._to("healthy", round_idx)
                self.strikes = 0
        else:
            self.strikes = 0             # consecutive-failure scoring

    def tick(self, round_idx: int) -> None:
        """Advance the ejection cooldown; ejected -> probing at zero."""
        if self.state == "ejected":
            self.cooldown -= 1
            if self.cooldown <= 0:
                self._to("probing", round_idx)
                self.strikes = 0
                self.successes = 0


def deadline_for(plan, max_new_tokens: int, *, chunk: int | None = None,
                 slack: float = 3.0, queue_rounds: int = 0,
                 dispatch_overhead_s: float = 2e-4) -> float:
    """Planner-derived completion deadline for one request, in seconds.

    ``ceil(max_new_tokens / chunk)`` decode rounds at the plan's
    modeled per-round seconds, plus ``queue_rounds`` of expected
    queueing, stretched by ``slack``. Attach the result to
    ``Request.deadline_s`` so "late" is defined relative to what the
    port model promises on this machine, not an absolute constant.
    """
    c = plan.chunk if chunk is None else max(1, int(chunk))
    rounds = math.ceil(max(1, int(max_new_tokens)) / c) + int(queue_rounds)
    return slack * rounds * planned_round_seconds(
        plan, chunk=c, dispatch_overhead_s=dispatch_overhead_s)


def priced_degradation(plan, chunk: int, slots: int, replicas_up: int,
                       backlog_tokens: int, *,
                       deadline_s: float | None = None,
                       dispatch_overhead_s: float = 2e-4,
                       trigger: str = "overload") -> dict:
    """Price keep vs. re-planned smaller chunk vs. shed; pick one.

    Every option is costed in the plan's modeled seconds: one round
    takes ``chunk * t_step + overhead`` and draining the backlog takes
    ``rounds = ceil(backlog / (slots * replicas_up * chunk))`` of
    them. Halving the chunk halves the per-round latency (what a
    deadline cares about) but pays the dispatch overhead twice as
    often (what throughput cares about) — the same trade
    ``plan_chunk_size`` resolves at planning time, re-resolved here
    under degraded capacity. The choice is the cheapest-drain option
    whose *per-round* latency fits the deadline; when not even the
    smallest chunk fits, the verdict is ``"shed"``. Returns the
    decision with every priced option attached, so the fig10 artifact
    records the justification, not just the verdict.
    """
    t = plan.t_step_seconds
    up = max(1, int(replicas_up))
    backlog = max(0, int(backlog_tokens))
    candidates = {"keep": max(1, int(chunk))}
    half = max(1, int(chunk) // 2)
    if half != candidates["keep"]:
        candidates["replan"] = half
    options = {}
    for name, c in candidates.items():
        round_s = c * t + dispatch_overhead_s
        rounds = math.ceil(backlog / max(1, slots * up * c)) if backlog \
            else 0
        options[name] = {"chunk": c, "round_s": round_s,
                         "drain_s": round_s * rounds}
    feasible = {
        name: o for name, o in options.items()
        if deadline_s is None or o["round_s"] <= deadline_s}
    if feasible:
        choice = min(feasible, key=lambda n: (feasible[n]["drain_s"],
                                              n != "keep"))
    else:
        choice = "shed"
    return {"trigger": trigger, "choice": choice,
            "chunk": options.get(choice, {}).get("chunk"),
            "deadline_s": deadline_s, "backlog_tokens": backlog,
            "replicas_up": up, "options": options}


class FaultTolerantRouter(ReplicaRouter):
    """ReplicaRouter with health tracking, rescue, and degradation.

    Drop-in superset of the base router: same ``submit`` / ``step`` /
    ``run`` / ``stats`` surface, driven on a virtual clock. Per
    round, each non-ejected replica is deadline-checked, admitted
    into, and stepped; failures and latency breaches strike its
    :class:`ReplicaHealth`, ejection rescues its in-flight requests
    onto healthy replicas, and page-pool exhaustion triggers a priced
    keep/replan/shed decision (``degrade_log``). ``drain_events()``
    yields the event stream the chaos harness reconciles — nothing is
    ever silently dropped.
    """

    def __init__(self, replicas: list, *, policy: str = "round_robin",
                 max_queue: int = 8,
                 health: HealthConfig | None = None,
                 budget_s: float | None = None):
        super().__init__(replicas, policy=policy, max_queue=max_queue)
        self.health_cfg = health if health is not None else HealthConfig()
        self.health = [ReplicaHealth(self.health_cfg)
                       for _ in self.replicas]
        self._budget_override = budget_s
        self.now_s = 0.0
        self.round_idx = 0
        self._requests: dict = {}        # rid -> original Request
        self._prefix: dict = {}          # rid -> rescued tokens so far
        self._deadline_at: dict = {}     # rid -> absolute virtual deadline
        self._resubmit: deque = deque()  # rescued, awaiting resubmission
        self._pending_retire: list = []  # rescues already at full budget
        self.events: list = []
        self.degrade_log: list = []
        self.rescue_log: list = []
        self.rescued = 0
        self.deadline_shed = 0
        self.deadline_cancelled = 0

    # -- budgets ------------------------------------------------------------
    def budget(self, i: int) -> float:
        """Planned healthy per-round seconds for replica ``i``."""
        if self._budget_override is not None:
            return float(self._budget_override)
        eng = self.replicas[i]
        b = getattr(eng, "budget_s", None)
        if b is not None:
            return float(b)
        plan = getattr(eng, "plan", None)
        if plan is not None:
            return planned_round_seconds(plan, chunk=eng.chunk)
        return 1e-3

    # -- admission ----------------------------------------------------------
    def _pick(self) -> int:
        ok = [i for i, h in enumerate(self.health) if h.admissible()]
        if not ok:
            err = NoHealthyReplica(
                "no admissible replica (all quarantined/ejected)")
            err.replica = 0
            raise err
        if self.policy == "round_robin":
            for k in range(len(self.replicas)):
                i = (self._rr + k) % len(self.replicas)
                if i in ok:
                    self._rr = (i + 1) % len(self.replicas)
                    return i
        return min(ok, key=self._active_tokens)

    def submit(self, req) -> int:
        """Submit with deadline registration (relative -> absolute)."""
        i = super().submit(req)
        self._requests.setdefault(req.rid, req)
        if req.deadline_s is not None and req.rid not in self._deadline_at:
            self._deadline_at[req.rid] = self.now_s + float(req.deadline_s)
        return i

    # -- rescue -------------------------------------------------------------
    def _rescue(self, i: int, rid: str, toks, reason: str) -> None:
        """Resubmit one interrupted stream as a prompt+prefix replay."""
        orig = self._requests.get(rid)
        prefix = list(self._prefix.get(rid, []))
        prefix += [int(t) for t in np.asarray(toks).tolist()]
        if orig is None:                 # unknown rid: keep, don't lose
            self.quarantined.append((rid, np.asarray(prefix, np.int32)))
            return
        remaining = orig.max_new_tokens - len(prefix)
        self._prefix[rid] = prefix
        if remaining <= 0:               # already owed nothing: retire
            self._pending_retire.append(rid)
            return
        eng = self.replicas[i]
        self.rescue_log.append({
            "rid": rid, "replica": i, "reason": reason,
            "prefix": len(prefix),
            "rows": rescue_traffic(
                eng.cfg, len(orig.prompt), len(prefix), eng.max_len,
                page_size=getattr(eng, "page_size", None)
                if getattr(eng, "paged", False) else None)})
        self._resubmit.append(Request(
            rid, prompt=tuple(orig.prompt) + tuple(prefix),
            max_new_tokens=remaining, deadline_s=orig.deadline_s))
        self.rescued += 1
        self.events.append({"kind": "rescue", "rid": rid, "replica": i,
                            "reason": reason, "round": self.round_idx,
                            "prefix": len(prefix)})

    def _eject(self, i: int) -> None:
        """Evacuate replica ``i``: requeue its queue, rescue its slots."""
        eng = self.replicas[i]
        q = self.queues[i]
        while q:
            r = q.popleft()
            self._owner.pop(r.rid, None)
            self._resubmit.append(r)
            self.events.append({"kind": "requeue", "rid": r.rid,
                                "replica": i, "round": self.round_idx})
        for st in [s for s in eng.slots if s is not None]:
            out = eng.cancel(st.rid)
            self._owner.pop(st.rid, None)
            self._rescue(i, st.rid, out, reason="eject")

    def _on_quarantined(self, i: int, rid: str, toks) -> None:
        """Non-finite stream: strike the replica, rescue the stream."""
        self.failed[i] += 1
        if self.health[i].strike(self.round_idx):
            self._eject(i)
        self._rescue(i, rid, toks, reason="nonfinite")

    def _merge_prefix(self, rid: str, toks):
        """Prepend any rescued prefix to a retiring stream's tokens."""
        prefix = self._prefix.pop(rid, None)
        if not prefix:
            return toks
        self.events.append({"kind": "rescued_complete", "rid": rid,
                            "round": self.round_idx,
                            "prefix": len(prefix)})
        return np.concatenate(
            [np.asarray(prefix, np.int32), np.asarray(toks, np.int32)])

    # -- degradation --------------------------------------------------------
    def _degrade(self, i: int, eng, req) -> None:
        """Pool exhausted on admit: priced keep/replan/shed decision."""
        plan = getattr(eng, "plan", None)
        q = self.queues[i]
        if plan is None:                 # explicit-chunk engine: keep
            return                       # queued, retry next round
        up = sum(1 for h in self.health if h.admissible())
        backlog = self._active_tokens(i)
        dl = self._deadline_at.get(req.rid)
        decision = priced_degradation(
            plan, eng.chunk, eng.max_slots, up, backlog,
            deadline_s=None if dl is None else dl - self.now_s,
            trigger="pool_exhausted")
        decision["replica"] = i
        decision["round"] = self.round_idx
        decision["rid"] = req.rid
        self.degrade_log.append(decision)
        if decision["choice"] == "shed":
            q.remove(req)
            self._owner.pop(req.rid, None)
            self.shed[i] += 1
            self.shed_rids.append(req.rid)
            self.events.append({"kind": "shed", "rid": req.rid,
                                "replica": i, "round": self.round_idx,
                                "reason": "pool_exhausted"})
        elif decision["choice"] == "replan" and hasattr(eng, "set_chunk"):
            eng.set_chunk(decision["chunk"])

    def _shed(self, req, replica: int, reason: str) -> None:
        """Retry budget spent: justify the shed with a priced comparison."""
        super()._shed(req, replica, reason)
        eng = self.replicas[replica]
        plan = getattr(eng, "plan", None)
        if plan is not None:
            up = sum(1 for h in self.health if h.admissible())
            decision = priced_degradation(
                plan, eng.chunk, eng.max_slots, up,
                self._active_tokens(replica), trigger="retry_exhausted")
            decision["choice"] = "shed"  # the retry budget already chose
            decision["replica"] = replica
            decision["rid"] = req.rid
            self.degrade_log.append(decision)
        self.events.append({"kind": "shed", "rid": req.rid,
                            "replica": replica, "round": self.round_idx,
                            "reason": reason})

    # -- rounds -------------------------------------------------------------
    def _deadline_sweep(self, i: int, eng) -> None:
        """Shed queued / cancel active requests past their deadline."""
        q = self.queues[i]
        for r in list(q):
            dl = self._deadline_at.get(r.rid)
            if dl is not None and self.now_s > dl:
                q.remove(r)
                self._owner.pop(r.rid, None)
                self.deadline_shed += 1
                self.events.append({"kind": "deadline_shed", "rid": r.rid,
                                    "replica": i,
                                    "round": self.round_idx})
        for st in [s for s in eng.slots if s is not None]:
            dl = self._deadline_at.get(st.rid)
            if dl is not None and self.now_s > dl:
                out = eng.cancel(st.rid)
                self._owner.pop(st.rid, None)
                self.deadline_cancelled += 1
                merged = self._merge_prefix(st.rid, out)
                self.events.append({"kind": "deadline_cancel",
                                    "rid": st.rid, "replica": i,
                                    "round": self.round_idx,
                                    "tokens": int(len(merged))})

    def step(self) -> list:
        """One fault-aware round; advances the virtual clock.

        Order per replica: health tick, deadline sweep, admissions
        (admissible states only — quarantined replicas drain), one
        decode round with failure/latency scoring, quarantine drain.
        Rescued requests are resubmitted before admissions so they
        re-enter service with minimum added latency. The clock
        advances by the slowest stepped replica's reported seconds
        (replicas step concurrently in a real deployment).
        """
        self.round_idx += 1
        retired = []
        for rid in self._pending_retire:
            toks = np.asarray(self._prefix.pop(rid, []), np.int32)
            retired.append((rid, toks))
        self._pending_retire = []
        keep = deque()
        while self._resubmit:
            req = self._resubmit.popleft()
            try:
                self.submit(req)
            except QueueFull:
                keep.append(req)
        self._resubmit = keep
        step_secs = []
        for i, eng in enumerate(self.replicas):
            h = self.health[i]
            h.tick(self.round_idx)
            if not h.steppable():
                continue
            self._deadline_sweep(i, eng)
            q = self.queues[i]
            if h.admissible():
                while q and eng.free_slots():
                    req = q[0]
                    try:
                        eng.admit(req)
                    except TransientFault:
                        self.failed[i] += 1
                        if h.strike(self.round_idx):
                            self._eject(i)
                        break
                    except PoolExhausted:
                        self.failed[i] += 1
                        self._degrade(i, eng, req)
                        break
                    q.popleft()
            if h.state == "ejected":     # struck out during admission
                continue
            done = []
            if any(s is not None for s in eng.slots):
                try:
                    done = eng.step()
                except TransientFault:
                    self.failed[i] += 1
                    if h.strike(self.round_idx):
                        self._eject(i)
                else:
                    dt = float(getattr(eng, "last_step_seconds",
                                       self.budget(i)))
                    step_secs.append(min(
                        dt, self.health_cfg.latency_factor
                        * self.budget(i)))
                    if dt > self.health_cfg.latency_factor \
                            * self.budget(i):
                        if h.strike(self.round_idx):
                            self._eject(i)
                    else:
                        h.success(self.round_idx)
            elif h.state in ("probing", "quarantined"):
                # idle probe: with no slots to step there is nothing
                # left to drain and nothing to strike on — without
                # this, a replica quarantined by admission faults
                # would stay quarantined forever and starve its queue
                h.success(self.round_idx)
            for rid, toks in done:
                self._owner.pop(rid, None)
                self.completed[i] += 1
                retired.append((rid, self._merge_prefix(rid, toks)))
            for rid, toks in self._drain_quarantined(i, eng):
                self._owner.pop(rid, None)
                self._on_quarantined(i, rid, toks)
        self.now_s += max(step_secs) if step_secs else max(
            self.budget(i) for i in range(len(self.replicas)))
        return retired

    def busy(self) -> bool:
        """True while anything is queued, active, or awaiting rescue."""
        return (bool(self._resubmit) or bool(self._pending_retire)
                or super().busy())

    def drain_events(self) -> list:
        """Return and clear the event log (shed/rescue/deadline/...)."""
        out, self.events = self.events, []
        return out

    def stats(self) -> list:
        """Base counters plus each replica's health state and strikes."""
        rows = super().stats()
        for i, row in enumerate(rows):
            row["health"] = self.health[i].state
            row["strikes"] = self.health[i].strikes
        return rows
