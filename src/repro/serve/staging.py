"""Async host→device prompt staging for the serve path.

Admission used to pay the host→device copy of every prompt inside the
admission call itself: ``admit()`` built the ``(1, S)`` token array and
handed it straight to the jitted prefill, so the H2D transfer sat on
the admission critical path. At traffic scale that copy is pure,
avoidable latency — the prompt is known the moment the request is
queued, usually several decode rounds before a slot frees.

:class:`PromptStager` is the small prefetch queue that closes that
gap: ``stage()`` issues an *asynchronous* ``jax.device_put`` of the
prompt tokens as soon as the request is enqueued (router submit, the
engine's ``run()`` look-ahead, or a rescue replay), and ``take()``
hands the already-resident device array to the prefill at admission
time. jax's async dispatch means ``device_put`` returns immediately
while the copy proceeds in the background, so by the time a slot
frees the tokens are (typically) already on device and admission
never blocks on the transfer.

Correctness is unconditional: the staged array is built from exactly
the same ``np.int32`` prompt tokens the unstaged path would have
used, so prefill results are bit-identical whether or not a prompt
was prefetched. ``take()`` verifies the staged entry against the
prompt it is asked for and silently falls back to staging on the spot
on any mismatch (a rid reused with a different prompt can never serve
stale tokens). The queue is bounded (``depth``) so a long pending
backlog cannot pin unbounded device memory; eviction is
least-recently-staged.

Rescued streams ride the same path for free: the fault-tolerant
router replays an interrupted request as prompt+prefix through
``submit()``, which stages the replay like any fresh arrival.
"""

from __future__ import annotations

from collections import OrderedDict

import jax
import numpy as np


class PromptStager:
    """Bounded prefetch queue of device-resident prompt token arrays.

    ``depth`` bounds how many prompts may be staged at once; staging
    past the bound evicts the least-recently-staged entry (its device
    buffer is dropped and the prompt simply re-stages at admission,
    i.e. the historical synchronous path). ``device`` optionally pins
    the ``device_put`` target; ``None`` uses jax's default placement —
    the same placement a jitted prefill would commit the tokens to.
    """

    def __init__(self, depth: int = 8, device=None):
        self.depth = max(1, int(depth))
        self.device = device
        self._staged: OrderedDict = OrderedDict()   # rid -> (prompt, dev)
        self.staged = 0          # device_put prefetches issued
        self.hits = 0            # admissions served from the queue
        self.misses = 0          # admissions that had to stage inline

    def _put(self, prompt: tuple):
        arr = np.asarray(prompt, np.int32)[None, :]
        if self.device is not None:
            return jax.device_put(arr, self.device)
        return jax.device_put(arr)

    def stage(self, rid: str, prompt: tuple) -> bool:
        """Prefetch one prompt; returns True if a new copy was issued.

        A rid already staged with the same prompt is refreshed in
        recency order but not re-copied. ``device_put`` is async — the
        call returns as soon as the transfer is enqueued.
        """
        hit = self._staged.get(rid)
        if hit is not None and hit[0] == tuple(prompt):
            self._staged.move_to_end(rid)
            return False
        while len(self._staged) >= self.depth:
            self._staged.popitem(last=False)
        self._staged[rid] = (tuple(prompt), self._put(prompt))
        self.staged += 1
        return True

    def take(self, rid: str, prompt: tuple):
        """The staged ``(1, S)`` device array for one admission.

        Pops the entry (a prompt is prefilled exactly once). A missing
        or mismatched entry stages inline — bit-identical tokens, just
        without the head start.
        """
        hit = self._staged.pop(rid, None)
        if hit is not None and hit[0] == tuple(prompt):
            self.hits += 1
            return hit[1]
        self.misses += 1
        return self._put(prompt)

    def discard(self, rid: str) -> None:
        """Drop one staged prompt (cancelled before admission)."""
        self._staged.pop(rid, None)

    def stats(self) -> dict:
        """Prefetch counters: staged/hit/miss plus current queue depth."""
        return {"staged": self.staged, "hits": self.hits,
                "misses": self.misses, "queued": len(self._staged)}
