"""Continuous-batching serve engine: fixed KV slots, admit/evict per
decode round, chunked in-graph decode.

Life of a request: it waits in the pending queue until a slot frees,
is prefilled (batch=1, cache built directly at the full horizon) and
inserted into its slot in place, then decodes along with every other
active slot — each at its own position — in multi-token chunks. When its
budget is spent it retires and the slot is free for the next admission;
the big slot cache is never reallocated, regrown, or recompiled as the
batch composition changes.

Two cache layouts share the engine skeleton:

- :class:`ServeEngine` — dense per-slot KV: every slot owns a
  ``max_len`` stripe of the cache, zero-filled to the horizon at
  admission regardless of how much of it the request will use.
- :class:`PagedServeEngine` — paged KV (repro.serve.pages): attention
  KV lives in fixed-size physical pages mapped through per-slot block
  tables. Pages are allocated lazily as positions advance, identical
  prompt prefixes share pages by refcount (copy-on-write on first
  divergent write), and retiring a request returns its pages without
  any zero-fill — recycled pages keep stale rows, masked by position,
  which is the serve-scale write-allocate-evasion story (DESIGN.md).

Numerical caveat: slots are independent streams for every per-row mixer
(attention, mamba, xLSTM). MoE blocks with finite capacity couple rows
through expert capacity — serve MoE configs with a generous
``capacity_factor`` if bit-exact per-request streams matter (and note
prefix sharing reuses KV computed in a *different* prefill batch, so
shared-prefix determinism also assumes dense FFNs).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.attention.ops import validate_tp_heads
from repro.models import model as M
from repro.serve import pages as pages_lib
from repro.serve.decode import make_chunked_decode_step
from repro.serve.planner import plan_chunk_size
from repro.serve.slots import make_insert_step
from repro.serve.staging import PromptStager
from repro.train import serve as serve_lib
from repro.utils.sharding import (SERVE_ENGINE_RULES, mesh_axis_sizes,
                                  named_sharding, tp_degree, use_mesh_rules)


def _named(mesh, pspecs):
    """PartitionSpec tree -> NamedSharding tree (P is a tuple: mark leaves)."""
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(lambda s: named_sharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt token ids and a token budget.

    ``deadline_s`` is an optional completion budget in seconds
    *relative to submission* (virtual-clock seconds under the load
    harness). Engines ignore it; the fault-tolerant router
    (repro.serve.health) sheds queued requests and cancels active ones
    once their budget is spent. ``None`` means no deadline.
    """

    rid: str
    prompt: tuple                 # prompt token ids
    max_new_tokens: int
    deadline_s: float | None = None


@dataclasses.dataclass
class _Slot:
    rid: str
    remaining: int                # tokens still owed to this request
    out: list                     # tokens emitted so far


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unconsumed decode round (pipelined mode).

    ``toks``/``ok`` are *device* arrays — touching them with
    ``np.asarray`` is the readback the pipeline defers. ``entries``
    snapshots which slot objects the round decoded and how many of its
    tokens each one keeps (``take``); the identity of the ``_Slot``
    reference is what lets a later consume skip rounds belonging to a
    stream that was quarantined in an earlier buffered round.
    """

    toks: object                  # (B, chunk) device int32
    ok: object | None             # (B,) device bool, or None (no guard)
    entries: list                 # [(slot index, _Slot, take)]
    chunk: int                    # chunk size this round was decoded at


class ServeEngine:
    """Continuous-batching engine over ``max_slots`` preallocated KV slots.

    ``chunk`` tokens are decoded per dispatch; when omitted the chunk size
    is planned analytically from the port model's tier-resolved per-step
    cost (repro.serve.planner). Prefill compiles once per distinct prompt
    length (jit's own shape-keyed cache); decode and slot-insert compile
    exactly once. ``run(requests)`` drives admit -> decode-chunk -> retire
    rounds until every request has its tokens.

    Subclass hooks (`PagedServeEngine` overrides all five): `_make_plan`
    prices the chunk, `_build_state` allocates the cache and jits the
    dispatch steps, `_insert_prefilled` lands one prefilled request in a
    slot, `_pre_dispatch` runs host-side bookkeeping before each chunk,
    `_dispatch` issues it, `_release_slot` retires a slot.
    """

    paged = False

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, chunk: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 machine: str | None = None,
                 attn_impl: str | None = None,
                 kv_len: int | None = None,
                 store_flavor: str = "auto",
                 mesh=None, rules: dict | None = None,
                 nonfinite_guard: bool = True,
                 pipeline: bool | int = 0,
                 stage_depth: int = 8):
        assert cfg.embed_inputs, "serve engine needs a token-id model"
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.temperature = float(temperature)
        # pipelined (double-buffered) dispatch: True -> depth 2, an int
        # sets the in-flight round bound explicitly, 0/False keeps the
        # historical serial step. See step()/sync() for the contract.
        self.pipeline = 2 if pipeline is True else max(0, int(pipeline))
        self._inflight: deque = deque()   # _InFlight records, oldest first
        self._tok_dev = None              # device (B,1) next-token feed
        # measured dispatch gap: host seconds between consecutive decode
        # dispatch *enqueues* (readback + bookkeeping between rounds).
        # Serial rounds block on token readback inside that window;
        # pipelined rounds only do host bookkeeping there — the delta is
        # exactly what fig11 measures.
        self.dispatch_gap_s = 0.0
        self.gap_rounds = 0
        self._t_enqueued: float | None = None
        # async H2D prompt staging (repro.serve.staging): stage() ahead
        # of admission, admit() takes the already-resident array
        self.stager = PromptStager(depth=stage_depth)
        # the non-finite guard makes every decode chunk also return a
        # per-slot isfinite flag (serve.decode guard=): a slot whose
        # logits went NaN/inf is quarantined — removed from its slot
        # with its pre-chunk tokens parked on ``self.quarantined`` —
        # instead of silently self-feeding garbage or poisoning the
        # batch. One cheap jit-fused reduce per in-graph step.
        self.nonfinite_guard = bool(nonfinite_guard)
        self.quarantined: list = []   # (rid, tokens-so-far) pairs
        # attn_impl routes decode attention through the split-KV kernel
        # suite; kv_len is a static occupancy bound for the engine's
        # lifetime (no request may decode past it) — when set, the
        # planner prices the occupancy-bounded kernel step instead of
        # the dense full-horizon one.
        self.attn_impl, self.kv_len = attn_impl, kv_len
        # store_flavor picks the KV-writer store path
        # (repro.kernels.stores): "auto" records the per-machine
        # selection on the plan but executes NT kernels only on a real
        # TPU, so off-TPU serving keeps the standard XLA path.
        self.store_flavor = store_flavor
        # mesh=None keeps the single-device path bit-for-bit: every
        # sharding hook below is behind the mesh guard. With a mesh,
        # params/cache are device_put against param_pspecs/cache_pspecs
        # under ``rules`` (SERVE_ENGINE_RULES by default: kvheads -> TP,
        # kv_seq resident), the step functions trace with the ambient
        # mesh+rules installed (sc() constraints go live), and the
        # planner prices the per-shard KV stream + per-step collective.
        self.mesh = mesh
        self.rules = (rules if rules is not None else SERVE_ENGINE_RULES) \
            if mesh is not None else None
        self._mesh_sizes = mesh_axis_sizes(mesh) if mesh is not None else {}
        self.tp = tp_degree(self._mesh_sizes, self.rules)
        if mesh is not None:
            validate_tp_heads(cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim_eff, self.tp,
                              page_size=getattr(self, "page_size", None))
            self.params = jax.device_put(
                params, _named(mesh, M.param_pspecs(cfg, self.rules,
                                                    self._mesh_sizes)))
        if chunk is None:
            self.plan = self._make_plan(machine)
            chunk = self.plan.chunk
        else:
            self.plan = None     # explicit chunk: no analytic plan made
        self.chunk = max(1, int(chunk))
        self._build_state()
        self._key = jax.random.PRNGKey(seed)
        self.slots: list = [None] * max_slots
        self._tok = np.zeros((max_slots, 1), np.int32)
        self._pos = np.zeros((max_slots,), np.int32)
        self._last_ok = np.ones((max_slots,), bool)
        self.decode_dispatches = 0
        self.prefill_dispatches = 0

    # -- layout hooks -------------------------------------------------------
    def _make_plan(self, machine):
        """Analytic chunk plan for this cache layout."""
        return plan_chunk_size(self.cfg, self.max_slots, self.max_len,
                               machine=machine, occupancy=self.kv_len,
                               store_flavor=self.store_flavor,
                               mesh=self.mesh, rules=self.rules)

    def _traced(self, fn):
        """Install the engine's mesh+rules around ``fn`` for jit tracing.

        jit calls the wrapped function once per trace (including the
        per-prompt-length prefill retraces), so the thread-local
        ``use_mesh_rules`` context is live exactly when the model's
        ``sc()`` constraints are staged. ``mesh=None`` returns ``fn``
        untouched — the unsharded engine traces the very same function
        object it always did.
        """
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        def wrapped(*a, **kw):
            with mesh, use_mesh_rules(mesh, rules):
                return fn(*a, **kw)
        return wrapped

    def _shard_cache(self, cache, pspecs):
        """Commit a fresh cache to its mesh layout (no-op unsharded)."""
        if self.mesh is None:
            return cache
        return jax.device_put(cache, _named(self.mesh, pspecs))

    def _donate(self) -> tuple:
        """Cache-donation argnums for the decode jit, mode-dependent.

        Serial mode donates the cache: the KV update happens in place,
        one buffer, minimal traffic. Pipelined mode must NOT donate —
        donating a buffer that is still being produced by the previous
        in-flight round forces the runtime to block the *enqueue* until
        the producer completes (measured on this backend: a donated
        chained dispatch serializes entirely), which would silently
        turn the pipeline back into the serial loop. Double-buffering
        therefore pays the classic price: two cache buffers alive and a
        copy-on-update round, in exchange for enqueues that never wait.
        """
        return () if self.pipeline else (1,)

    def _make_decode(self):
        """Jit the chunked decode step for the current ``self.chunk``."""
        return jax.jit(
            self._traced(make_chunked_decode_step(
                self.cfg, self.chunk, self.temperature,
                attn_impl=self.attn_impl, kv_len=self.kv_len,
                store_flavor=self.store_flavor,
                guard=self.nonfinite_guard)),
            donate_argnums=self._donate())

    def set_chunk(self, chunk: int) -> None:
        """Re-plan the decode chunk size mid-flight (degraded mode).

        Only the chunked decode step is re-jitted — the cache, the
        slots, and every in-flight stream are untouched, so the next
        ``step()`` simply decodes ``chunk`` tokens per dispatch. Used
        by the fault-tolerant router's priced degradation
        (``repro.serve.health``): a smaller chunk shortens each round
        (lower per-round latency under deadline pressure) at the cost
        of amortizing dispatch overhead over fewer tokens. Repeated
        sizes hit jit's compilation cache.
        """
        chunk = max(1, int(chunk))
        if chunk == self.chunk:
            return
        self.chunk = chunk
        self._decode = self._make_decode()

    def _build_state(self):
        """Allocate the cache and jit the per-layout dispatch steps."""
        self.cache = self._shard_cache(
            M.init_cache(self.cfg, self.max_slots, self.max_len),
            M.cache_pspecs(self.cfg, self.rules, self._mesh_sizes,
                           self.max_slots, self.max_len)
            if self.mesh is not None else None)
        self._decode = self._make_decode()
        self._insert = jax.jit(self._traced(make_insert_step(self.cfg)),
                               donate_argnums=(0,))
        # jit retraces per prompt length/batch shape on its own — one
        # wrapper serves every admission path
        self._prefill = jax.jit(self._traced(serve_lib.make_prefill_step(
            self.cfg, cache_len=self.max_len,
            store_flavor=self.store_flavor)))

    def _insert_prefilled(self, slot: int, one, prompt) -> None:
        """Land one prefilled (batch-1) request cache in ``slot``."""
        self.cache = self._insert(self.cache, one, jnp.int32(slot))

    def _release_slot(self, i: int) -> None:
        """Retire slot ``i`` and free whatever it held."""
        self.slots[i] = None

    def _pre_dispatch(self) -> None:
        """Host-side bookkeeping before a chunk (no-op for dense slots)."""

    def _mark_gap(self) -> None:
        """Accumulate the host gap since the previous dispatch enqueue."""
        now = time.perf_counter()
        if self._t_enqueued is not None:
            self.dispatch_gap_s += now - self._t_enqueued
            self.gap_rounds += 1

    def _host_dev(self, arr):
        """Ship one mutable host array to device for a dispatch.

        ``jnp.asarray`` of an aligned numpy buffer may be *zero-copy*
        on CPU, so the enqueued computation reads the live host memory.
        Serial rounds are safe (the readback at the end of the step
        completes the dispatch before any bookkeeping mutates
        ``_pos``/``_tok``), but pipelined rounds mutate both right
        after the enqueue while the round is still in flight — ship a
        snapshot copy instead, or the eager position advance races the
        device reads (observed as timing-dependent stream corruption).
        """
        return jnp.asarray(arr.copy() if self.pipeline else arr)

    def _tok_input(self):
        """Next-token feed for the coming dispatch.

        Serial rounds (and the first pipelined round after a sync)
        ship the host-side ``self._tok``; chained pipelined rounds
        feed the previous round's last-token *device* slice directly,
        so the dispatch never waits for a readback.
        """
        return self._tok_dev if self._tok_dev is not None \
            else self._host_dev(self._tok)

    def _decode_args(self):
        """Positional args of one decode dispatch (before the PRNG key)."""
        return (self.params, self.cache, self._tok_input(),
                self._host_dev(self._pos))

    def _dispatch_raw(self, sub):
        """Enqueue one chunked decode; returns device (toks, ok|None).

        Purely asynchronous: the result arrays are *futures* (jax async
        dispatch) and nothing here blocks on device work. ``self.cache``
        advances to the round's output cache immediately — later
        dispatches, admissions, and page copies chain on it in enqueue
        order. In pipelined mode the last-token slice becomes the next
        round's device-side token feed.
        """
        self._mark_gap()
        out = self._decode(*self._decode_args(), sub)
        self._t_enqueued = time.perf_counter()
        if self.nonfinite_guard:
            toks, self.cache, _, ok = out
        else:
            toks, self.cache, _ = out
            ok = None
        if self.pipeline:
            self._tok_dev = toks[:, self.chunk - 1:self.chunk]
        return toks, ok

    def _dispatch(self, sub):
        """Issue one chunked decode over all slots; returns (B, chunk)."""
        toks, ok = self._dispatch_raw(sub)
        if ok is not None:
            self._last_ok = np.asarray(ok)
        return toks

    # -- admission ----------------------------------------------------------
    def free_slots(self) -> list:
        """Indices of slots with no active request."""
        return [i for i, s in enumerate(self.slots) if s is None]

    def _sample_first(self, logits):
        """First output token from the prefill's last-prompt-token logits."""
        if self.temperature > 0.0:
            self._key, sub = jax.random.split(self._key)
            tok = jax.random.categorical(sub, logits / self.temperature,
                                         axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        return np.asarray(tok, np.int32)

    def _check_request(self, req: Request, prompt_len: int) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 "
                f"(got {req.max_new_tokens})")
        horizon = self.max_len if self.kv_len is None \
            else min(self.max_len, self.kv_len)
        if prompt_len + req.max_new_tokens - 1 > horizon:
            raise ValueError(
                f"request {req.rid}: prompt {prompt_len} + "
                f"{req.max_new_tokens} new tokens exceeds the slot "
                f"horizon {horizon}")
        # out-of-vocab ids don't fail loudly downstream: the jitted
        # embedding gather fills OOB rows with NaN, which poisons the
        # whole stream (and trips the non-finite guard). Reject at
        # admission, where the rid is still attached to the cause.
        if req.prompt and (min(req.prompt) < 0
                           or max(req.prompt) >= self.cfg.vocab_size):
            raise ValueError(
                f"request {req.rid}: prompt ids must be in "
                f"[0, {self.cfg.vocab_size})")

    def stage(self, req: Request) -> bool:
        """Prefetch one pending request's prompt to device (async H2D).

        Called ahead of admission — by ``run()``'s look-ahead, the
        router's ``submit()``, or a rescue replay — so that when a slot
        frees the prompt tokens are already device-resident and
        ``admit()`` skips the host→device copy. Purely an optimization:
        bit-identical whether or not the prompt was staged. Sharded
        engines decline (the jitted prefill shards its own host input);
        returns True iff a new async copy was issued.
        """
        if self.mesh is not None:
            return False
        return self.stager.stage(req.rid, tuple(int(t) for t in req.prompt))

    def admit(self, req: Request, slot: int | None = None) -> int:
        """Prefill one request and insert it into a free slot, in place.

        Concurrent with any in-flight pipelined rounds: the prefill and
        slot-insert enqueue *behind* the dispatched decodes, so the
        in-flight writes to this slot's (now retired) stripe or pages
        happen-before the insert in device order — the insert wins.
        The device-side token feed is patched in place so the chained
        dispatch picks up the admission's first token.
        """
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free slot")
            slot = free[0]
        assert self.slots[slot] is None, f"slot {slot} busy"
        prompt = np.asarray(req.prompt, np.int32)
        s = prompt.shape[0]
        self._check_request(req, s)
        prompt_t = tuple(int(t) for t in prompt)
        tokens = prompt[None, :] if self.mesh is not None \
            else self.stager.take(req.rid, prompt_t)
        logits, one = self._prefill(self.params, {"tokens": tokens})
        self.prefill_dispatches += 1
        tok0 = int(self._sample_first(logits[:, -1])[0])
        self._insert_prefilled(slot, one, prompt_t)
        self.slots[slot] = _Slot(rid=req.rid, remaining=req.max_new_tokens - 1,
                                 out=[tok0])
        self._tok[slot, 0] = tok0
        if self._tok_dev is not None:
            # keep the chained device feed coherent with the host copy
            self._tok_dev = self._tok_dev.at[slot, 0].set(tok0)
        self._pos[slot] = s
        return slot

    def admit_batch(self, reqs: list) -> None:
        """Admit a full batch at once (all slots free, equal prompt lens).

        One batched prefill builds the whole slot cache directly — the
        fast path for the launch driver's fixed-shape batch. Paged
        engines always take the per-request path (admission is where
        prefix matching happens). Falls back to per-request admission
        otherwise.
        """
        lens = {len(r.prompt) for r in reqs}
        if (self.paged or len(reqs) != self.max_slots or len(lens) != 1
                or any(s is not None for s in self.slots)):
            for r in reqs:
                self.admit(r)
            return
        s = lens.pop()
        prompts = np.stack([np.asarray(r.prompt, np.int32) for r in reqs])
        for r in reqs:
            self._check_request(r, s)
        logits, self.cache = self._prefill(self.params, {"tokens": prompts})
        self.prefill_dispatches += 1
        tok0 = self._sample_first(logits[:, -1])
        for i, r in enumerate(reqs):
            self.slots[i] = _Slot(rid=r.rid, remaining=r.max_new_tokens - 1,
                                  out=[int(tok0[i])])
            self._tok[i, 0] = tok0[i]
            self._pos[i] = s

    def drain_quarantined(self) -> list:
        """Return and clear the (rid, tokens-so-far) quarantine list.

        Populated by ``step()`` when the non-finite guard trips; the
        router (``repro.serve.health``) drains it every round to rescue
        the streams on a healthy replica by replaying prompt + prefix.
        """
        out, self.quarantined = self.quarantined, []
        return out

    def cancel(self, rid: str):
        """Abort an active request; returns its tokens so far, or None.

        On the paged engine this is the page-recycling fast path: the
        request's pages go straight back to the pool (no zero-fill, no
        cache traffic at all) and the next admission may recycle them.
        """
        if self._inflight:
            self.sync()          # materialize the stream before returning it
        self.stager.discard(rid)
        for i, st in enumerate(self.slots):
            if st is not None and st.rid == rid:
                out = np.asarray(st.out, np.int32)
                self._release_slot(i)
                return out
        return None

    # -- decode -------------------------------------------------------------
    def step(self) -> list:
        """One decode round: a single chunked dispatch over all slots.

        Returns the requests retired this round as (rid, tokens) pairs.
        With ``pipeline`` enabled the dispatch is double-buffered —
        round N+1 is enqueued while round N's tokens are still in
        flight, and the host only blocks on readback when a stream
        actually retires (or the in-flight bound is hit). Retirement
        and admission timing are identical to the serial step, so token
        streams are byte-for-byte the same in both modes.
        """
        if self.pipeline:
            return self._step_pipelined()
        return self._step_serial()

    def _step_serial(self) -> list:
        retired = []
        for i, st in enumerate(self.slots):
            if st is not None and st.remaining <= 0:   # 1-token budgets:
                # the prefill already yielded their only token
                retired.append((st.rid, np.asarray(st.out, np.int32)))
                self._release_slot(i)
        if all(s is None for s in self.slots):
            return retired
        self._pre_dispatch()
        self._key, sub = jax.random.split(self._key)
        toks = self._dispatch(sub)
        self.decode_dispatches += 1
        toks = np.asarray(toks)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            if not bool(self._last_ok[i]):
                # non-finite logits this chunk: quarantine the request
                # (tokens-so-far, pre-chunk — the chunk's output is
                # garbage) instead of letting it self-feed NaNs. The
                # slot frees immediately; the router decides whether
                # the stream is rescued or reported failed.
                self.quarantined.append(
                    (st.rid, np.asarray(st.out, np.int32)))
                self._release_slot(i)
                continue
            take = min(self.chunk, st.remaining)
            st.out.extend(int(t) for t in toks[i, :take])
            st.remaining -= take
            self._tok[i, 0] = toks[i, self.chunk - 1]
            self._pos[i] += self.chunk
            if st.remaining <= 0:
                retired.append((st.rid, np.asarray(st.out, np.int32)))
                self._release_slot(i)
        return retired

    def _step_pipelined(self) -> list:
        """Double-buffered decode round: enqueue now, read back later.

        The host bookkeeping that *can* run without token values does
        run eagerly — ``remaining`` is decremented and positions advance
        at dispatch time (both are pure arithmetic), so the next round's
        page allocation and retirement *decisions* never wait on the
        device. Only two things force a sync: a stream finishing (its
        tokens must be materialized to be returned) and the in-flight
        bound (consume the oldest round — by then it has been computing
        behind the newer dispatches, so the readback is nearly free).
        Syncing at the retirement round keeps slot-free timing — and
        therefore admission order and the PRNG split sequence —
        identical to the serial step.
        """
        retired = []
        for i, st in enumerate(self.slots):
            if st is not None and st.remaining <= 0:   # 1-token budgets
                self.sync()
                st = self.slots[i]      # sync may have quarantined it
                if st is not None and st.remaining <= 0:
                    retired.append((st.rid, np.asarray(st.out, np.int32)))
                    self._release_slot(i)
        if all(s is None for s in self.slots):
            self.sync()
            return retired
        self._pre_dispatch()
        self._key, sub = jax.random.split(self._key)
        toks, ok = self._dispatch_raw(sub)
        self.decode_dispatches += 1
        entries, will_retire = [], False
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            take = min(self.chunk, st.remaining)
            entries.append((i, st, take))
            st.remaining -= take
            self._pos[i] += self.chunk
            will_retire = will_retire or st.remaining <= 0
        self._inflight.append(_InFlight(toks, ok, entries, self.chunk))
        if will_retire:
            self.sync()
            for i, st in enumerate(self.slots):
                if st is not None and st.remaining <= 0:
                    retired.append((st.rid, np.asarray(st.out, np.int32)))
                    self._release_slot(i)
        else:
            while len(self._inflight) > self.pipeline:
                self._consume_oldest()
        return retired

    def _consume_oldest(self) -> None:
        """Read back the oldest in-flight round and apply its bookkeeping.

        This is the only place pipelined mode touches device results:
        tokens land on each stream's ``out``, the host-side next-token
        feed catches up, and guard trips quarantine exactly as the
        serial step would have — with the one difference that rounds
        dispatched *after* a poisoned one are skipped for that stream
        (their token-0 self-feed output is garbage by construction).
        """
        rec = self._inflight.popleft()
        toks = np.asarray(rec.toks)
        oks = np.asarray(rec.ok) if rec.ok is not None else None
        for i, st, take in rec.entries:
            if self.slots[i] is not st:
                continue            # stream quarantined in an earlier round
            if oks is not None and not bool(oks[i]):
                self._last_ok[i] = False
                self.quarantined.append(
                    (st.rid, np.asarray(st.out, np.int32)))
                self._release_slot(i)
                continue
            st.out.extend(int(t) for t in toks[i, :take])
            self._tok[i, 0] = toks[i, rec.chunk - 1]

    def sync(self) -> None:
        """Drain every in-flight round's deferred host bookkeeping.

        After a sync the engine is exactly where the serial step would
        be: every emitted token is host-resident, the next dispatch
        rebuilds its token feed from ``self._tok``, and quarantine
        lists are complete. Cheap when nothing is in flight.
        """
        while self._inflight:
            self._consume_oldest()
        self._tok_dev = None

    def stats(self) -> dict:
        """Dispatch counters and the measured dispatch gap.

        ``mean_dispatch_gap_s`` is the average host time between
        consecutive decode-dispatch enqueues — the serial step blocks
        on token readback inside that window, the pipelined step does
        not, and the delta is the overlap win fig11 gates on.
        """
        gap = self.dispatch_gap_s / self.gap_rounds if self.gap_rounds \
            else 0.0
        return {"decode_dispatches": self.decode_dispatches,
                "prefill_dispatches": self.prefill_dispatches,
                "pipeline": self.pipeline,
                "in_flight": len(self._inflight),
                "dispatch_gap_s": self.dispatch_gap_s,
                "gap_rounds": self.gap_rounds,
                "mean_dispatch_gap_s": gap,
                "staging": self.stager.stats()}

    def snapshot(self, checkpointer, step: int) -> bool:
        """Snapshot the served params without stalling the stream.

        Hands the param tree to the async checkpointer
        (``repro.checkpoint``) with ``skip_if_busy=True``: if the
        previous background write is still running the snapshot is
        *skipped* (returns False) instead of blocking the decode loop
        on disk. In-flight pipelined rounds are untouched — params are
        never donated, so the device-to-host copy the checkpointer
        takes does not synchronize the decode stream.
        """
        return checkpointer.save(step, {"params": self.params},
                                 skip_if_busy=True)

    def run(self, requests: list) -> dict:
        """Serve a request list to completion: {rid: (n_tokens,) int32}."""
        pending = deque(requests)
        results: dict = {}
        first = True
        while pending or any(s is not None for s in self.slots):
            if pending and self.free_slots():
                if first and len(pending) >= self.max_slots:
                    batch = [pending.popleft()
                             for _ in range(self.max_slots)]
                    self.admit_batch(batch)
                else:
                    for slot in self.free_slots():
                        if not pending:
                            break
                        self.admit(pending.popleft(), slot)
            first = False
            # look-ahead prompt staging: the next few pending prompts
            # start their H2D copies now, overlapped with the decode
            # rounds below (already-staged rids just refresh, no copy)
            for r in list(pending)[:self.stager.depth]:
                self.stage(r)
            for rid, toks in self.step():
                results[rid] = toks
        return results


class PagedServeEngine(ServeEngine):
    """Paged-KV serve engine: block tables, prefix sharing, CoW forks.

    Attention KV leaves are physical page pools of ``n_pages + 1`` pages
    of ``page_size`` rows (the extra page is a write-off scratch page:
    unmapped table entries point at it, so stale rows of free slots and
    the overshoot writes of retiring slots land somewhere harmless and
    position-masked). Per-slot block tables live on the host
    (``block_tables``, -1 = unmapped) and are re-shipped each dispatch —
    a few KiB against the MiB-scale KV traffic they steer.

    What the dense engine zero-fills eagerly, this engine allocates
    lazily: pages appear only when a slot's position advances into them
    (`_pre_dispatch`), admissions map shared prompt prefixes instead of
    copying them (``share_prefixes``), `fork` clones a stream for the
    cost of its recurrent state plus refcounts, and retirement returns
    pages with their stale contents intact — recycling skips the
    zero-fill a dense admission would pay, which is exactly the
    write-allocate traffic the MemTier pricing in
    ``serve.kv_traffic`` charges for.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, params, *, page_size: int = 8,
                 n_pages: int | None = None, share_prefixes: bool = True,
                 **kw):
        self.page_size = int(page_size)
        self.pages_per_slot = pages_lib.pages_per_slot(
            kw["max_len"], self.page_size)
        # dense-equivalent capacity by default: sharing and laziness can
        # only ever need fewer pages than one-stripe-per-slot
        self.n_pages = int(n_pages) if n_pages is not None \
            else kw["max_slots"] * self.pages_per_slot
        self.share_prefixes = bool(share_prefixes)
        super().__init__(cfg, params, **kw)

    # -- layout hooks -------------------------------------------------------
    def _make_plan(self, machine):
        return plan_chunk_size(self.cfg, self.max_slots, self.max_len,
                               machine=machine, occupancy=self.kv_len,
                               store_flavor=self.store_flavor,
                               page_size=self.page_size,
                               mesh=self.mesh, rules=self.rules)

    def _make_decode(self):
        return jax.jit(
            self._traced(make_chunked_decode_step(
                self.cfg, self.chunk, self.temperature,
                attn_impl=self.attn_impl, kv_len=self.kv_len,
                store_flavor=self.store_flavor, paged=True,
                guard=self.nonfinite_guard)),
            donate_argnums=self._donate())

    def _build_state(self):
        cfg, ps = self.cfg, self.page_size
        self.pool = pages_lib.PagePool(self.n_pages, ps)
        self._scratch = self.n_pages          # physical index of scratch
        self.cache = self._shard_cache(
            pages_lib.init_paged_cache(cfg, self.max_slots,
                                       self.n_pages + 1, ps),
            pages_lib.paged_cache_pspecs(cfg, self.rules, self._mesh_sizes,
                                         self.max_slots, self.n_pages + 1,
                                         ps)
            if self.mesh is not None else None)
        self.block_tables = np.full(
            (self.max_slots, self.pages_per_slot), -1, np.int32)
        self._decode = self._make_decode()
        self._page_insert = jax.jit(
            self._traced(pages_lib.make_paged_insert_step(cfg, ps)),
            donate_argnums=(0,))
        self._page_copy = jax.jit(
            self._traced(pages_lib.make_page_copy_step(cfg)),
            donate_argnums=(0,))
        self._slot_copy = jax.jit(
            self._traced(pages_lib.make_slot_copy_step(cfg)),
            donate_argnums=(0,))
        # prefill at *exactly* the prompt length: no horizon zero-fill —
        # fresh pages get real rows, recycled pages keep stale ones
        self._prefill = jax.jit(self._traced(serve_lib.make_prefill_step(
            cfg, cache_len=None, store_flavor=self.store_flavor)))
        self.gather_pages = 0                 # live pages read, summed
                                              # over dispatches (fig8)

    def _insert_prefilled(self, slot: int, one, prompt) -> None:
        ps = self.page_size
        s = len(prompt)
        npg = -(-s // ps)
        shared = self.pool.match_prefix(prompt) if self.share_prefixes \
            else []
        fresh = self.pool.allocate(npg - len(shared))
        held = list(shared) + list(fresh)
        if self.share_prefixes:
            # full prompt pages become matchable by later admissions
            self.pool.register_prefix(prompt, held[:s // ps])
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :npg] = held
        # always dispatched: recurrent leaves need their slot row even
        # when every KV page of the prompt is shared (zero page copies)
        self.cache = self._page_insert(
            self.cache, one, jnp.int32(slot),
            jnp.asarray(np.asarray(fresh, np.int32)),
            jnp.arange(len(shared), npg, dtype=jnp.int32))

    def _release_slot(self, i: int) -> None:
        held = [int(p) for p in self.block_tables[i] if p >= 0]
        self.pool.release(held)
        self.block_tables[i, :] = -1
        self.slots[i] = None

    def _pre_dispatch(self) -> None:
        """Make every page the coming chunk will write exist and be ours.

        For each active slot: allocate the pages its next
        ``min(chunk, remaining)`` positions will touch, and
        copy-on-write any that are shared (prefix index, forks). After
        this, the in-graph scatter can never land on a page another
        holder can see. Overshoot writes past ``remaining`` hit either
        an exclusively-held page (rows masked after retirement) or the
        scratch page — never an allocated shared one.
        """
        ps, pps = self.page_size, self.pages_per_slot
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            p0 = int(self._pos[i])
            take = min(self.chunk, st.remaining)
            l_lo = min(p0 // ps, pps - 1)
            l_hi = min((p0 + take - 1) // ps, pps - 1)
            for lg in range(l_lo, l_hi + 1):
                phys = int(self.block_tables[i, lg])
                if phys < 0:
                    self.block_tables[i, lg] = self.pool.allocate(1)[0]
                    continue
                page, copied = self.pool.prepare_write(phys)
                if copied:
                    self.cache = self._page_copy(
                        self.cache, jnp.int32(phys), jnp.int32(page))
                self.block_tables[i, lg] = page
        live = self.block_tables[[i for i, st in enumerate(self.slots)
                                  if st is not None]]
        self.gather_pages += int((live >= 0).sum())

    def _decode_args(self):
        # ``bt`` is a fresh temporary (np.where allocates), so it may
        # zero-copy alias safely; ``_pos`` is live host state and needs
        # the pipelined snapshot copy (see ``_host_dev``)
        bt = np.where(self.block_tables < 0, self._scratch,
                      self.block_tables).astype(np.int32)
        return (self.params, self.cache, jnp.asarray(bt),
                self._tok_input(), self._host_dev(self._pos))

    # -- paged-only surface -------------------------------------------------
    def fork(self, rid: str, new_rid: str,
             max_new_tokens: int | None = None) -> int:
        """Clone an active stream into a free slot, copy-on-write.

        The clone maps the same physical pages (refcounted); only the
        slot-batched recurrent state (mamba/xLSTM) is copied on device.
        Divergent writes trigger per-page CoW at the next
        `_pre_dispatch`. Returns the clone's slot index.
        """
        if self._inflight:
            self.sync()      # clone from materialized host-side state
        src = next((i for i, st in enumerate(self.slots)
                    if st is not None and st.rid == rid), None)
        if src is None:
            raise KeyError(f"no active request {rid!r}")
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slot")
        dst = free[0]
        self.pool.fork([int(p) for p in self.block_tables[src] if p >= 0])
        self.block_tables[dst] = self.block_tables[src]
        self.cache = self._slot_copy(self.cache, jnp.int32(src),
                                     jnp.int32(dst))
        st = self.slots[src]
        self.slots[dst] = _Slot(
            rid=new_rid,
            remaining=st.remaining if max_new_tokens is None
            else max_new_tokens,
            out=list(st.out))
        self._tok[dst] = self._tok[src]
        self._pos[dst] = self._pos[src]
        return dst

    def check_pool(self) -> None:
        """Assert page-conservation invariants over the live block tables."""
        self.pool.check_conservation(
            [[int(p) for p in self.block_tables[i] if p >= 0]
             for i, st in enumerate(self.slots) if st is not None])
