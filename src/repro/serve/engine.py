"""Continuous-batching serve engine: fixed KV slots, admit/evict per
decode round, chunked in-graph decode.

Life of a request: it waits in the pending queue until a slot frees,
is prefilled (batch=1, cache built directly at the full horizon) and
inserted into its slot in place, then decodes along with every other
active slot — each at its own position — in multi-token chunks. When its
budget is spent it retires and the slot is free for the next admission;
the big slot cache is never reallocated, regrown, or recompiled as the
batch composition changes.

Numerical caveat: slots are independent streams for every per-row mixer
(attention, mamba, xLSTM). MoE blocks with finite capacity couple rows
through expert capacity — serve MoE configs with a generous
``capacity_factor`` if bit-exact per-request streams matter.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serve.decode import make_chunked_decode_step
from repro.serve.planner import plan_chunk_size
from repro.serve.slots import make_insert_step
from repro.train import serve as serve_lib


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt token ids and a token budget."""

    rid: str
    prompt: tuple                 # prompt token ids
    max_new_tokens: int


@dataclasses.dataclass
class _Slot:
    rid: str
    remaining: int                # tokens still owed to this request
    out: list                     # tokens emitted so far


class ServeEngine:
    """Continuous-batching engine over ``max_slots`` preallocated KV slots.

    ``chunk`` tokens are decoded per dispatch; when omitted the chunk size
    is planned analytically from the port model's tier-resolved per-step
    cost (repro.serve.planner). Prefill compiles once per distinct prompt
    length (jit's own shape-keyed cache); decode and slot-insert compile
    exactly once. ``run(requests)`` drives admit -> decode-chunk -> retire
    rounds until every request has its tokens.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, chunk: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 machine: str | None = None,
                 attn_impl: str | None = None,
                 kv_len: int | None = None,
                 store_flavor: str = "auto"):
        assert cfg.embed_inputs, "serve engine needs a token-id model"
        self.cfg, self.params = cfg, params
        self.max_slots, self.max_len = max_slots, max_len
        self.temperature = float(temperature)
        # attn_impl routes decode attention through the split-KV kernel
        # suite; kv_len is a static occupancy bound for the engine's
        # lifetime (no request may decode past it) — when set, the
        # planner prices the occupancy-bounded kernel step instead of
        # the dense full-horizon one.
        self.attn_impl, self.kv_len = attn_impl, kv_len
        # store_flavor picks the KV-writer store path
        # (repro.kernels.stores): "auto" records the per-machine
        # selection on the plan but executes NT kernels only on a real
        # TPU, so off-TPU serving keeps the standard XLA path.
        self.store_flavor = store_flavor
        if chunk is None:
            self.plan = plan_chunk_size(cfg, max_slots, max_len,
                                        machine=machine, occupancy=kv_len,
                                        store_flavor=store_flavor)
            chunk = self.plan.chunk
        else:
            self.plan = None     # explicit chunk: no analytic plan made
        self.chunk = max(1, int(chunk))
        self.cache = M.init_cache(cfg, max_slots, max_len)
        self._decode = jax.jit(
            make_chunked_decode_step(cfg, self.chunk, self.temperature,
                                     attn_impl=attn_impl, kv_len=kv_len,
                                     store_flavor=store_flavor),
            donate_argnums=(1,))
        self._insert = jax.jit(make_insert_step(cfg), donate_argnums=(0,))
        # jit retraces per prompt length/batch shape on its own — one
        # wrapper serves every admission path
        self._prefill = jax.jit(serve_lib.make_prefill_step(
            cfg, cache_len=max_len, store_flavor=store_flavor))
        self._key = jax.random.PRNGKey(seed)
        self.slots: list = [None] * max_slots
        self._tok = np.zeros((max_slots, 1), np.int32)
        self._pos = np.zeros((max_slots,), np.int32)
        self.decode_dispatches = 0
        self.prefill_dispatches = 0

    # -- admission ----------------------------------------------------------
    def free_slots(self) -> list:
        """Indices of slots with no active request."""
        return [i for i, s in enumerate(self.slots) if s is None]

    def _sample_first(self, logits):
        """First output token from the prefill's last-prompt-token logits."""
        if self.temperature > 0.0:
            self._key, sub = jax.random.split(self._key)
            tok = jax.random.categorical(sub, logits / self.temperature,
                                         axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        return np.asarray(tok, np.int32)

    def _check_request(self, req: Request, prompt_len: int) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 "
                f"(got {req.max_new_tokens})")
        horizon = self.max_len if self.kv_len is None \
            else min(self.max_len, self.kv_len)
        if prompt_len + req.max_new_tokens - 1 > horizon:
            raise ValueError(
                f"request {req.rid}: prompt {prompt_len} + "
                f"{req.max_new_tokens} new tokens exceeds the slot "
                f"horizon {horizon}")

    def admit(self, req: Request, slot: int | None = None) -> int:
        """Prefill one request and insert it into a free slot, in place."""
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free slot")
            slot = free[0]
        assert self.slots[slot] is None, f"slot {slot} busy"
        prompt = np.asarray(req.prompt, np.int32)
        s = prompt.shape[0]
        self._check_request(req, s)
        logits, one = self._prefill(self.params, {"tokens": prompt[None, :]})
        self.prefill_dispatches += 1
        tok0 = int(self._sample_first(logits[:, -1])[0])
        self.cache = self._insert(self.cache, one, jnp.int32(slot))
        self.slots[slot] = _Slot(rid=req.rid, remaining=req.max_new_tokens - 1,
                                 out=[tok0])
        self._tok[slot, 0] = tok0
        self._pos[slot] = s
        return slot

    def admit_batch(self, reqs: list) -> None:
        """Admit a full batch at once (all slots free, equal prompt lens).

        One batched prefill builds the whole slot cache directly — the
        fast path for the launch driver's fixed-shape batch. Falls back
        to per-request admission otherwise.
        """
        lens = {len(r.prompt) for r in reqs}
        if (len(reqs) != self.max_slots or len(lens) != 1
                or any(s is not None for s in self.slots)):
            for r in reqs:
                self.admit(r)
            return
        s = lens.pop()
        prompts = np.stack([np.asarray(r.prompt, np.int32) for r in reqs])
        for r in reqs:
            self._check_request(r, s)
        logits, self.cache = self._prefill(self.params, {"tokens": prompts})
        self.prefill_dispatches += 1
        tok0 = self._sample_first(logits[:, -1])
        for i, r in enumerate(reqs):
            self.slots[i] = _Slot(rid=r.rid, remaining=r.max_new_tokens - 1,
                                  out=[int(tok0[i])])
            self._tok[i, 0] = tok0[i]
            self._pos[i] = s

    # -- decode -------------------------------------------------------------
    def step(self) -> list:
        """One decode round: a single chunked dispatch over all slots.

        Returns the requests retired this round as (rid, tokens) pairs.
        """
        retired = []
        for i, st in enumerate(self.slots):
            if st is not None and st.remaining <= 0:   # 1-token budgets:
                # the prefill already yielded their only token
                retired.append((st.rid, np.asarray(st.out, np.int32)))
                self.slots[i] = None
        if all(s is None for s in self.slots):
            return retired
        self._key, sub = jax.random.split(self._key)
        toks, self.cache, _ = self._decode(
            self.params, self.cache, jnp.asarray(self._tok),
            jnp.asarray(self._pos), sub)
        self.decode_dispatches += 1
        toks = np.asarray(toks)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            take = min(self.chunk, st.remaining)
            st.out.extend(int(t) for t in toks[i, :take])
            st.remaining -= take
            self._tok[i, 0] = toks[i, self.chunk - 1]
            self._pos[i] += self.chunk
            if st.remaining <= 0:
                retired.append((st.rid, np.asarray(st.out, np.int32)))
                self.slots[i] = None
        return retired

    def run(self, requests: list) -> dict:
        """Serve a request list to completion: {rid: (n_tokens,) int32}."""
        pending = deque(requests)
        results: dict = {}
        first = True
        while pending or any(s is not None for s in self.slots):
            if pending and self.free_slots():
                if first and len(pending) >= self.max_slots:
                    batch = [pending.popleft()
                             for _ in range(self.max_slots)]
                    self.admit_batch(batch)
                else:
                    for slot in self.free_slots():
                        if not pending:
                            break
                        self.admit(pending.popleft(), slot)
            first = False
            for rid, toks in self.step():
                results[rid] = toks
        return results
