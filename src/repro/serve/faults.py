"""Seeded, deterministic fault injection for the serve engines.

:class:`FaultyEngine` wraps any :class:`~repro.serve.engine.ServeEngine`
(dense or paged) behind the exact engine surface the router drives —
``admit`` / ``step`` / ``cancel`` / ``free_slots`` / ``slots`` — and
injects failures from a precomputed, index-keyed schedule:

- ``step_error`` — the decode round raises :class:`TransientFault`
  before touching the wrapped engine (a crashed dispatch).
- ``stuck`` — the round makes no progress at all and reports a step
  latency of ``factor`` × the planned budget (a wedged replica).
- ``slow`` — the round completes but reports ``factor`` × budget (a
  straggling replica, cf. the per-machine variability the health
  baselines normalize away).
- ``nonfinite`` — a slot's cache rows are NaN-poisoned *before* the
  round so the engine's in-graph ``jnp.isfinite`` guard trips and
  quarantines the request; the injector scrubs the NaNs afterwards so
  recycled pages/slots cannot re-poison later admissions.
- ``admit_error`` — the admission raises :class:`TransientFault`.
- ``pool_exhausted`` — the admission raises
  :class:`~repro.serve.pages.PoolExhausted` (injected on either
  layout, modeling a saturated page pool).

Faults are keyed on the wrapper's own monotone step / admission
counters, never on wall-clock, so every recovery path in
``repro.serve.health`` is reproducible on the virtual clock:
``last_step_seconds`` is *always* set (the planned budget when
healthy, ``factor`` × budget under stuck/slow), and the chaos harness
(benchmarks/fig10_chaos.py) advances simulated time from it.
"""

from __future__ import annotations

import dataclasses
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.pages import PoolExhausted

STEP_KINDS = ("step_error", "stuck", "slow", "nonfinite")
ADMIT_KINDS = ("admit_error", "pool_exhausted")
KINDS = STEP_KINDS + ADMIT_KINDS


class TransientFault(RuntimeError):
    """A retryable failure injected into a serve step or admission.

    The router's backoff/retry policy treats it like ``QueueFull``:
    retry with exponential backoff against another (or the same)
    replica, shed only after the retry budget is spent.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` fires at the listed indices.

    ``at`` holds step indices for step kinds and admission indices for
    admission kinds (both 0-based wrapper-local counters). ``slot``
    picks the poisoned slot for ``nonfinite``; ``factor`` scales the
    planned per-round budget into the reported latency for
    ``stuck``/``slow``.
    """

    kind: str
    at: frozenset
    slot: int = 0
    factor: float = 50.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


def chaos_schedule(seed: int, n_steps: int, rates: dict,
                   slots: int = 1) -> tuple:
    """Draw a deterministic fault schedule from per-kind rates.

    ``rates`` maps fault kind -> per-index probability; each of the
    ``n_steps`` indices is sampled independently per kind from a
    ``numpy`` generator seeded with ``seed``, so identical arguments
    always produce the identical schedule (the property-based chaos
    tests rely on this). ``nonfinite`` faults round-robin their target
    slot over ``slots``. Returns a tuple of :class:`FaultSpec`.
    """
    rng = np.random.default_rng(seed)
    specs = []
    for kind in KINDS:
        rate = float(rates.get(kind, 0.0))
        if rate <= 0.0:
            continue
        hits = np.flatnonzero(rng.random(n_steps) < rate)
        if kind == "nonfinite":
            for j, i in enumerate(hits):
                specs.append(FaultSpec(kind, frozenset({int(i)}),
                                       slot=j % max(1, slots)))
        elif hits.size:
            specs.append(FaultSpec(kind, frozenset(int(i) for i in hits)))
    return tuple(specs)


def _poison_leaf(leaf, axis1_size, index):
    """NaN one axis-1 row of a float leaf whose axis 1 is ``axis1_size``."""
    a = np.asarray(leaf)
    if (np.issubdtype(a.dtype, np.floating) and a.ndim >= 2
            and a.shape[1] == axis1_size):
        a = a.copy()
        a[:, index] = np.nan
        return jnp.asarray(a, leaf.dtype)
    return leaf


def poison_slot(engine, slot: int) -> None:
    """NaN-poison one slot's cache rows so its next logits go non-finite.

    Cache leaves are scan-stacked with the layer axis first, so the
    slot-batched axis (dense KV, recurrent state) is axis 1; paged KV
    leaves carry physical pages on axis 1 instead, and there the last
    *exclusively held* page of the slot is poisoned (poisoning a
    shared page would condemn every other holder, and ``prepare_write``
    would dutifully copy the NaNs into the CoW clone). Recurrent
    slot-batched leaves are poisoned on either layout.
    """
    if slot < 0 or slot >= engine.max_slots:
        raise ValueError(f"slot {slot} out of range")
    cache = engine.cache
    cache = jax.tree.map(
        lambda leaf: _poison_leaf(leaf, engine.max_slots, slot), cache)
    if engine.paged:
        pool = engine.pool
        mine = [int(p) for p in engine.block_tables[slot] if p >= 0]
        own = [p for p in mine if pool.refcount[p] == 1]
        if own:
            phys = own[-1]
            cache = jax.tree.map(
                lambda leaf: _poison_leaf(leaf, engine.n_pages + 1, phys),
                cache)
    engine.cache = cache


def scrub_nonfinite(engine) -> None:
    """Replace every non-finite cache value with 0 (post-fault cleanup).

    Finite values pass through bit-exactly (``nan_to_num`` is the
    identity on them), so healthy slots are untouched; only the
    poisoned rows — whose request was quarantined and whose tokens are
    discarded anyway — are neutralized. Without this, a NaN page
    released back to the pool would re-poison whichever request
    recycles it (stale rows are position-masked, but ``0 * NaN`` is
    still ``NaN`` through attention).
    """
    engine.cache = jax.tree.map(
        lambda leaf: jnp.nan_to_num(leaf, nan=0.0, posinf=0.0, neginf=0.0)
        if jnp.issubdtype(leaf.dtype, jnp.floating) else leaf,
        engine.cache)


class FaultyEngine:
    """Engine wrapper that injects scheduled faults, virtual-clock style.

    Everything not intercepted (``cancel``, ``free_slots``, ``slots``,
    ``plan``, ``chunk``, ``set_chunk``, ``drain_quarantined``, ...)
    delegates to the wrapped engine, so the wrapper drops into any
    router slot a real engine occupies. ``budget_s`` is the planned
    healthy per-round latency (defaulting to the wrapped engine's
    analytic plan via :func:`repro.serve.planner.planned_round_seconds`
    when available); ``last_step_seconds`` reports it after every
    round — scaled by the fault's ``factor`` under stuck/slow — which
    is what the health tracker scores against the very same budget.
    """

    def __init__(self, inner, faults=(), budget_s: float | None = None):
        self.inner = inner
        self.faults = tuple(faults)
        if budget_s is None:
            plan = getattr(inner, "plan", None)
            if plan is not None:
                from repro.serve.planner import planned_round_seconds
                budget_s = planned_round_seconds(plan, chunk=inner.chunk)
            else:
                budget_s = 1e-3
        self.budget_s = float(budget_s)
        self.step_idx = 0
        self.admit_idx = 0
        self.injected: Counter = Counter()
        self.last_step_seconds = self.budget_s

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _firing(self, kinds, idx):
        return [f for f in self.faults
                if f.kind in kinds and idx in f.at]

    def admit(self, req, slot=None):
        """Admit through the wrapper, honoring scheduled admission faults."""
        idx = self.admit_idx
        self.admit_idx += 1
        for f in self._firing(ADMIT_KINDS, idx):
            self.injected[f.kind] += 1
            if f.kind == "admit_error":
                raise TransientFault(
                    f"injected admission fault at admit #{idx}")
            raise PoolExhausted(
                f"injected pool exhaustion at admit #{idx}")
        return self.inner.admit(req, slot)

    def step(self):
        """One decode round through the wrapper, honoring step faults."""
        idx = self.step_idx
        self.step_idx += 1
        firing = self._firing(STEP_KINDS, idx)
        self.last_step_seconds = self.budget_s
        poisoned = False
        for f in firing:
            self.injected[f.kind] += 1
            if f.kind == "step_error":
                raise TransientFault(f"injected step fault at step #{idx}")
            if f.kind == "stuck":
                self.last_step_seconds = f.factor * self.budget_s
                return []                     # no progress at all
            if f.kind == "slow":
                self.last_step_seconds = f.factor * self.budget_s
            if f.kind == "nonfinite":
                if self.inner.slots[f.slot] is not None:
                    poison_slot(self.inner, f.slot)
                    poisoned = True
        ret = self.inner.step()
        if poisoned:
            scrub_nonfinite(self.inner)
        return ret
