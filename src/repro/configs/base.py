"""Architecture/config schema shared by all assigned architectures.

Every architecture file under ``repro/configs`` exports ``CONFIG``
(the exact published configuration) — reduced smoke variants are derived
mechanically via :func:`smoke_config`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|vlm|audio|ssm|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # Block program: entry = "<mixer>[:<ffn>]", mixer in
    # {attn, attn_local, mamba, mlstm, slstm}, ffn in {dense, moe, none}.
    # Default ffn: "dense" if d_ff > 0 else "none". Cycled over layers.
    block_pattern: tuple = ("attn",)
    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_kind: str = "rope"          # rope|mrope|sinusoidal|none
    rope_theta: float = 1e4
    sliding_window: int = 1024
    ffn_act: str = "swiglu"          # swiglu|gelu
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    # SSM (Mamba)
    ssm_d_state: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> d_model // 16
    ssm_chunk: int = 128
    ssm_fuse: bool = True            # compute decay/input inside the scan
    # xLSTM
    xlstm_proj_factor: float = 2.0
    # embeddings / head
    tie_embeddings: bool = False
    embed_inputs: bool = True        # False: inputs are precomputed embeddings
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    # attention execution
    q_chunk: int = 512
    kv_chunk: int = 1024
    # capability flags
    long_context_ok: bool = False    # may run the long_500k shape
    # training execution defaults
    remat: str = "full"              # none|full|dots
    # decode: unroll the layer loop instead of lax.scan (lets XLA alias
    # per-layer cache buffers instead of double-buffering the scan carry)
    decode_unroll: bool = False

    # ---- derived ----
    @property
    def head_dim_eff(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, self.d_model // 16)

    @property
    def xlstm_d_inner(self) -> int:
        return int(self.xlstm_proj_factor * self.d_model)

    def layer_plan(self) -> tuple:
        """Block descriptor per layer, pattern cycled over n_layers."""
        out = []
        for i in range(self.n_layers):
            ent = self.block_pattern[i % len(self.block_pattern)]
            if ":" not in ent:
                ent = ent + (":dense" if self.d_ff > 0 else ":none")
            out.append(ent)
        return tuple(out)

    def scan_split(self) -> tuple:
        """(n_repeats, unit_len, n_tail) for scan-over-repeated-pattern."""
        u = len(self.block_pattern)
        return self.n_layers // u, u, self.n_layers % u

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train|prefill|decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list:
    """The shape cells that apply to this architecture (DESIGN.md §3.3)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.long_context_ok:
        out.append(SHAPES["long_500k"])
    return out


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    unit = len(cfg.block_pattern)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(unit, 2) if unit > 1 else 2,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff > 0 else 0,
        vocab_size=512,
        n_experts=min(4, cfg.n_experts),
        experts_per_token=min(2, cfg.experts_per_token),
        d_ff_expert=64 if cfg.d_ff_expert > 0 else 0,
        moe_group_size=64,
        ssm_d_state=8,
        ssm_dt_rank=8,
        ssm_chunk=16,
        sliding_window=16,
        q_chunk=16,
        kv_chunk=16,
        remat="none",
    )
