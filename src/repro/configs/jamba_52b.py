"""Jamba-v0.1 52B — Mamba+attention 1:7, MoE 16e top-2 every other layer
[arXiv:2403.19887; hf]. HF config: attn period 8 offset 4, expert period 2
offset 1; no positional encoding (Mamba provides position)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    block_pattern=(
        "mamba:dense", "mamba:moe", "mamba:dense", "mamba:moe",
        "attn:dense", "mamba:moe", "mamba:dense", "mamba:moe",
    ),
    n_experts=16, experts_per_token=2, d_ff_expert=14336,
    rope_kind="none",
    ssm_d_state=16, ssm_conv_dim=4, ssm_expand=2,
    long_context_ok=True,   # Mamba majority; attn 1:7 uses KV cache at decode
)
