"""Minitron-8B — width-pruned Nemotron-4, squared-ReLU MLP
[arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    ffn_act="relu2",
)
