"""Gemma-3 4B — 5:1 local:global attention, 262k vocab, tied embeddings
[hf:google/gemma-3-1b-pt; unverified]. head_dim=256 per the published HF
config (d_model/n_heads would give 320; Gemma decouples head_dim)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    block_pattern=("attn_local",) * 5 + ("attn",),
    sliding_window=1024, rope_theta=1e6,
    tie_embeddings=True,
    long_context_ok=True,   # sliding-window local layers dominate (5:1)
)
