"""Architecture registry: ``get_config("<arch-id>")`` returns the exact
published configuration; ``get_smoke_config`` the reduced same-family one."""

from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES, shapes_for,
                                smoke_config)

ARCH_MODULES = {
    "yi-9b": "yi_9b",
    "gemma3-4b": "gemma3_4b",
    "minitron-8b": "minitron_8b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "grok-1-314b": "grok1_314b",
    "musicgen-large": "musicgen_large",
    "xlstm-125m": "xlstm_125m",
    "jamba-v0.1-52b": "jamba_52b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return smoke_config(get_config(arch_id))
