"""xLSTM-125M — alternating mLSTM/sLSTM blocks, d_ff=0 (blocks carry their
own projections) [arXiv:2405.04517; unverified]. The published 125M config
does not pin the m:s ratio; we use 1:1 (noted in DESIGN.md)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    rope_kind="none",
    long_context_ok=True,   # O(1) recurrent state
)
