"""Qwen2-VL-7B backbone — M-RoPE, QKV bias [arXiv:2409.12191; hf].
Vision frontend is a STUB per assignment: input_specs provides token ids +
3D (t,h,w) M-RoPE position ids (patch embeddings precomputed upstream)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_kind="mrope", rope_theta=1e6,
)
