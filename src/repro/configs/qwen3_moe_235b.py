"""Qwen3-235B-A22B — MoE 128 experts top-8, q/k-norm, decoupled head_dim
[hf:Qwen/Qwen3-30B-A3B; hf]. d_ff=1536 is the per-expert ffn width; there
is no dense MLP path."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936,
    block_pattern=("attn:moe",),
    n_experts=128, experts_per_token=8, d_ff_expert=1536,
    qk_norm=True, rope_theta=1e6,
)
