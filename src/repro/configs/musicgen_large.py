"""MusicGen-large backbone — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. EnCodec frontend is a STUB per assignment:
input_specs provides precomputed frame embeddings (B, S, d_model); the
backbone is MHA (kv=32=H) with GELU MLP and sinusoidal positions."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    ffn_act="gelu", rope_kind="sinusoidal",
    embed_inputs=False,
)
