"""Sharded, async checkpointing with atomic commit + restart discovery.

Layout:  <dir>/step_<N>/
           manifest.json       — tree structure, shapes, dtypes, step
           leaf_<i>.npy        — one file per pytree leaf
           COMMIT              — written last; restore ignores dirs without it

Saves run on a background thread (double-buffered: at most one in flight,
a new save waits for the previous). Restore rebuilds arrays against the
live mesh sharding when one is provided, so a checkpoint written on one
mesh can restart on another (elastic re-shard path used by
repro.launch.faults).

Crash safety: every file lands via write-to-temp + ``os.replace`` and
the whole step directory is renamed into place only after its COMMIT
marker exists, so a writer killed at *any* point leaves either the
previous committed snapshot or a ``.tmp`` directory that restore
ignores — never a torn snapshot. A background-thread failure is
captured and re-raised by the next ``wait()``/``save()`` instead of
vanishing with the daemon thread.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# np.save stores ml_dtypes (bfloat16, fp8) as raw void; round-trip through
# a byte view with the true dtype recorded in the manifest.
_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
         "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW:
        return arr.view(_VIEW[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str):
    if name in _VIEW:
        return arr.view(getattr(ml_dtypes, name))
    return arr


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ----------------------------------------------------------------
    def busy(self) -> bool:
        """True while a background save is still writing.

        Lets latency-sensitive callers (the serve engines' ``snapshot``)
        decide *before* calling ``save`` whether they would stall on
        the previous write.
        """
        return self._thread is not None and self._thread.is_alive()

    def save(self, step: int, tree, *, block: bool = False,
             skip_if_busy: bool = False) -> bool:
        """Snapshot ``tree`` at ``step``; returns True iff a save started.

        Default behavior is double-buffered: at most one write in
        flight, a new save first waits for the previous.
        ``skip_if_busy=True`` turns that wait into a skip — the serving
        path snapshots opportunistically and must never stall a decode
        round on disk; a skipped save returns False and the caller
        simply tries again at the next snapshot point. (A *finished*
        background write is still joined either way, so write errors
        surface here rather than vanishing.)
        """
        if skip_if_busy and self.busy():
            return False
        self.wait()
        leaves, treedef = _flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # device->host copy
        self._thread = threading.Thread(
            target=self._guarded_write,
            args=(step, host_leaves, str(treedef)), daemon=True)
        self._thread.start()
        if block:
            self.wait()
        return True

    def _guarded_write(self, step: int, leaves, treedef_str: str):
        """Run ``_write`` capturing any failure for the next ``wait()``."""
        try:
            self._write(step, leaves, treedef_str)
        except BaseException as e:          # noqa: B036 - re-raised in wait
            self._error = e

    @staticmethod
    def _put(path: str, writer) -> None:
        """Write one file atomically: temp in the same dir + os.replace."""
        tmp = path + ".part"
        with open(tmp, "wb") as f:
            writer(f)
        os.replace(tmp, path)

    def _write(self, step: int, leaves, treedef_str: str):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        dtypes = []
        for i, l in enumerate(leaves):
            enc, name = _encode(l)
            dtypes.append(name)
            self._put(os.path.join(tmp, f"leaf_{i}.npy"),
                      lambda f, a=enc: np.save(f, a))
        manifest = {"step": step, "n_leaves": len(leaves),
                    "dtypes": dtypes, "treedef": treedef_str}
        self._put(os.path.join(tmp, "manifest.json"),
                  lambda f: f.write(json.dumps(manifest).encode()))
        # COMMIT last: restore only trusts directories that carry it, so
        # a crash anywhere above leaves a .tmp dir all_steps() ignores
        self._put(os.path.join(tmp, "COMMIT"), lambda f: f.write(b"ok"))
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("background checkpoint write failed") \
                from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.dir)):
            p = os.path.join(self.dir, name)
            # exact step_<digits> only: a crash can leave step_N.tmp
            # behind (even with COMMIT inside, if it died between the
            # marker write and the directory rename) — never loadable
            if not name.startswith("step_"):
                continue
            suffix = name[len("step_"):]
            if not suffix.isdigit():
                continue
            if os.path.exists(os.path.join(p, "COMMIT")):
                out.append(int(suffix))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, mesh=None, spec_tree=None):
        """Restore into the structure of `like_tree`; if mesh+specs given,
        leaves are placed with those shardings (elastic re-shard)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        leaves, treedef = jax.tree.flatten(like_tree)
        n = len(leaves)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        dtypes = manifest.get("dtypes", [None] * n)
        loaded = [_decode(np.load(os.path.join(d, f"leaf_{i}.npy")),
                          dtypes[i]) for i in range(n)]
        if mesh is not None and spec_tree is not None:
            specs = jax.tree.leaves(
                spec_tree, is_leaf=lambda x: hasattr(x, "index") or x is None)
            from jax.sharding import NamedSharding
            placed = []
            for arr, spec in zip(loaded, specs):
                sh = NamedSharding(mesh, spec)
                placed.append(jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx]))
            loaded = placed
        else:
            loaded = [jnp.asarray(a) for a in loaded]
        return jax.tree.unflatten(treedef, loaded)

    def restore_latest(self, like_tree, mesh=None, spec_tree=None):
        s = self.latest_step()
        if s is None:
            return None, None
        return s, self.restore(s, like_tree, mesh, spec_tree)
