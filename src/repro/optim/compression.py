"""Int8 error-feedback gradient compression (1-bit-Adam-family trick).

At multi-pod scale the per-step gradient all-reduce crosses the DCN
("pod") axis once; quantizing the payload bf16 -> int8 halves the wire
bytes again (4x vs fp32) at the cost of quantization noise, which the
error-feedback residual re-injects next step — the standard convergence
fix. The transform is applied to the gradient tree before the optimizer;
its T_coll effect is modeled in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to(x, m):
    n = x.size
    p = (m - n % m) % m
    return jnp.pad(x.reshape(-1), (0, p)), n


def quantize_int8(g: jax.Array):
    """Blockwise symmetric int8 quantization. Returns (q, scales, n)."""
    flat, n = _pad_to(g.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n, shape, dtype=jnp.float32):
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape).astype(dtype)


def compress_tree(grads, residual):
    """Error-feedback compression of a gradient pytree.

    Returns (decompressed_grads, new_residual): callers use the
    decompressed values (what the wire would deliver) and carry the
    residual to the next step.
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s, n = quantize_int8(v)
        d = dequantize_int8(q, s, n, g.shape)
        return d.astype(g.dtype), v - d

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
