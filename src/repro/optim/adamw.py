"""Sharded AdamW with fp32 moments over bf16 params, global-norm clipping,
and warmup-cosine schedule. States inherit the parameter sharding specs
(same tree structure), so FSDP sharding extends to the optimizer for free.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # "int8": blockwise-quantized moments (8-bit-Adam family) — cuts the
    # optimizer-state HBM residency 4x; scales stored per row (last-dim
    # blocks) so sharding specs derive from the parameter spec.
    moments_dtype: str = "float32"


def _row_quant(x: jax.Array):
    """Rowwise symmetric int8: scale over the last axis."""
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s


def _row_dequant(q, s):
    return q.astype(jnp.float32) * s


def lr_schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = oc.lr * (step + 1.0) / max(1, oc.warmup_steps)
    t = jnp.clip((step - oc.warmup_steps) /
                 max(1, oc.total_steps - oc.warmup_steps), 0.0, 1.0)
    cos = oc.lr * (oc.min_lr_frac + (1 - oc.min_lr_frac) *
                   0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, moments_dtype: str = "float32"):
    if moments_dtype == "int8":
        def z8(p):
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "s": jnp.zeros(p.shape[:-1] + (1,) if p.ndim else (1,),
                                   jnp.float32)}
        return {"m": jax.tree.map(z8, params),
                "v": jax.tree.map(z8, params)}
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(oc: OptConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(oc, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - oc.b1 ** t
    bc2 = 1.0 - oc.b2 ** t
    q8 = oc.moments_dtype == "int8"

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = _row_dequant(m["q"], m["s"]) if q8 else m
        v32 = _row_dequant(v["q"], v["s"]) if q8 else v
        m_new = oc.b1 * m32 + (1 - oc.b1) * g32
        v_new = oc.b2 * v32 + (1 - oc.b2) * jnp.square(g32)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        if q8:
            mq, ms = _row_quant(m_new)
            vq, vs = _row_quant(v_new)
            return (p_new.astype(p.dtype), {"q": mq, "s": ms},
                    {"q": vq, "s": vs})
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_m = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) if q8 \
        else None
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_m)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_m)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
