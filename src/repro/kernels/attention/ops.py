"""Public flash-attention wrapper with impl routing and a BHSD<->BSHD
adapter for the model stack (models use (B, S, H, Dh))."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention import flash as F
from repro.kernels.attention import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "impl", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, impl="auto",
                    bq=512, bk=512):
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return R.attention(q, k, v, causal=causal, window=window)
    return F.flash_attention(q, k, v, bq=bq, bk=bk, causal=causal,
                             window=window, interpret=not _on_tpu())


def flash_attention_bshd(q, k, v, **kw):
    """(B, S, H, Dh) adapter."""
    o = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), **kw)
    return jnp.swapaxes(o, 1, 2)
