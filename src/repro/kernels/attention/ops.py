"""Public attention-kernel wrappers: impl routing, MemTier-autotuned
tile defaults, and a BHSD<->BSHD adapter for the model stack.

Tile sizes are no longer hardcoded: when a caller does not pin
``bq``/``bk``/``n_splits``, the MemTier-driven autotuner
(``repro.kernels.tuning``) prices the candidates against the target
machine's memory ladder and the cheapest tiling wins. ``impl`` follows
the suite-wide rules in ``repro.kernels``: ``ref`` / ``pallas``
(interpret mode off-TPU) / ``auto`` (Pallas on TPU, reference
elsewhere).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode, use_pallas
from repro.kernels import tuning
from repro.kernels.attention import decode as D
from repro.kernels.attention import flash as F
from repro.kernels.attention import ref as R




def validate_tp_heads(h: int, hkv: int, dh: int, tp: int, *,
                      page_size: int | None = None) -> int:
    """Check the decode dispatchers shard cleanly over ``tp`` TP shards.

    The flash-decode kernels pack all GQA heads of one shard into a
    single ``(Hkv_shard * G, Dh)`` query tile and tile the KV stream
    themselves, so a head-sharded (``kvheads`` -> TP) cache splits the
    kernel embarrassingly — *iff* the head counts divide: each shard
    must own a whole number of KV heads, the query heads must follow
    their KV groups, and the per-shard head tile must still be
    non-empty (head-dim tiles divide the per-shard head count). The
    paged kernel adds no head-side constraint (its KV block is the
    page), so ``page_size`` participates only in the error message.
    Returns the per-shard KV head count; raises ``ValueError`` on any
    violation.
    """
    tp = max(1, int(tp))
    what = "paged " if page_size is not None else ""
    if hkv % tp != 0:
        raise ValueError(
            f"{what}decode cannot shard {hkv} KV heads over TP={tp}: "
            "kvheads must divide the TP degree (pad heads or shrink "
            "the model mesh axis)")
    if h % tp != 0:
        raise ValueError(
            f"{what}decode cannot shard {h} query heads over TP={tp}: "
            "GQA groups must stay whole per shard")
    hkv_shard = hkv // tp
    g = h // hkv
    if hkv_shard * g < 1 or dh < 1:
        raise ValueError(
            f"{what}decode: empty per-shard head tile "
            f"(hkv/tp={hkv_shard}, G={g}, Dh={dh})")
    return hkv_shard


@partial(jax.jit, static_argnames=("causal", "window", "impl", "bq", "bk",
                                   "machine"))
def flash_attention(q, k, v, *, causal=True, window=None, impl="auto",
                    bq=None, bk=None, machine=None):
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh).

    ``bq``/``bk`` default to the autotuned tiling for ``machine``
    (``tuning.default_machine()`` when unset) instead of the old
    hardcoded 512s.
    """
    if not use_pallas(impl):
        return R.attention(q, k, v, causal=causal, window=window)
    _, h, s, dh = q.shape
    if bq is None or bk is None:
        plan = tuning.flash_tiles(machine or tuning.default_machine(),
                                  s=s, dh=dh, h=h, hkv=k.shape[1],
                                  dtype=str(q.dtype))
        bq = bq or tuning.fit_block(plan.bq, s)
        bk = bk or tuning.fit_block(plan.bk, s)
    return F.flash_attention(q, k, v, bq=bq, bk=bk, causal=causal,
                             window=window, interpret=interpret_mode())


def flash_attention_bshd(q, k, v, **kw):
    """(B, S, H, Dh) adapter."""
    o = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                        jnp.swapaxes(v, 1, 2), **kw)
    return jnp.swapaxes(o, 1, 2)


def flash_decode(q, k, v, pos, *, window=None, impl="auto", bk=None,
                 n_splits=None, kv_len=None, machine=None):
    """Split-KV decode against a fixed-horizon KV cache, impl-routed.

    q: (B, Sq, H, Dh) — the model stack's decode layout; k, v: (B,
    Skv, Hkv, Dh); ``pos`` scalar or (B,) (see
    ``kernels.attention.decode.flash_decode``). ``kv_len`` is the
    static occupancy bound — the highest cache row any slot can touch
    this step (``max(pos) + Sq``); rows past it are never read, which
    is the kernel's block early-out expressed as a shape. It is
    rounded up to the KV block grid and clamped to ``Skv``.

    ``bk``/``n_splits`` default to the autotuned decode tiling for
    ``machine``. Routing: ``pallas`` runs the kernel (interpret mode
    off-TPU); ``ref``/``auto``-off-TPU run the occupancy-bounded
    pure-JAX oracle — same traffic bound, XLA-fused. Designed to be
    called under an enclosing ``jax.jit`` (the decode step), so it is
    not jitted itself.
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    bound = skv if kv_len is None else max(1, min(int(kv_len), skv))
    if bk is None or n_splits is None:
        plan = tuning.decode_tiles(machine or tuning.default_machine(),
                                   skv=bound, dh=dh, h=h, hkv=hkv,
                                   batch=b, dtype=str(q.dtype))
        bk = bk or plan.bk
        n_splits = n_splits or plan.n_splits
    bk = max(1, min(bk, skv))
    if kv_len is not None:
        bound = min(math.ceil(bound / bk) * bk, skv)
        k = k[:, :bound]
        v = v[:, :bound]
    if use_pallas(impl):
        return D.flash_decode(q, k, v, pos, window=window, bk=bk,
                              n_splits=n_splits,
                              interpret=interpret_mode())
    return D.ref_decode(q, k, v, pos, window=window)


def flash_decode_paged(q, k_pages, v_pages, block_tables, pos, *,
                       window=None, impl="auto", n_splits=None,
                       kv_len=None, machine=None):
    """Paged split-KV decode against a shared page pool, impl-routed.

    q: (B, Sq, H, Dh); ``k_pages``/``v_pages``: (P, page, Hkv, Dh);
    ``block_tables``: (B, NB) int32 (see
    ``kernels.attention.decode.flash_decode_paged``). ``kv_len`` bounds
    occupancy at *page* granularity: only the first
    ``ceil(kv_len / page)`` table columns are ever gathered — the
    paged analogue of the dense router's block rounding. The KV block
    is pinned to the page size (a page is the DMA unit), so only
    ``n_splits`` is autotuned; ``machine`` picks whose ladder tunes it.

    Routing matches :func:`flash_decode`: ``pallas`` runs the
    scalar-prefetched gather kernel (interpret mode off-TPU);
    ``ref``/``auto``-off-TPU gather pages in logical order and run the
    dense oracle. Call under an enclosing ``jax.jit``.
    """
    b, sq, h, dh = q.shape
    ps, hkv = k_pages.shape[1], k_pages.shape[2]
    nb = block_tables.shape[1]
    if kv_len is not None:
        nb_used = max(1, min(math.ceil(int(kv_len) / ps), nb))
        block_tables = block_tables[:, :nb_used]
        nb = nb_used
    if use_pallas(impl):
        if n_splits is None:
            plan = tuning.decode_tiles(machine or tuning.default_machine(),
                                       skv=nb * ps, dh=dh, h=h, hkv=hkv,
                                       batch=b, dtype=str(q.dtype))
            n_splits = plan.n_splits
        return D.flash_decode_paged(q, k_pages, v_pages, block_tables,
                                    pos, window=window, n_splits=n_splits,
                                    interpret=interpret_mode())
    return D.ref_decode_paged(q, k_pages, v_pages, block_tables, pos,
                              window=window)
