"""Pure-jnp oracle for the flash-attention kernel: exact masked softmax
attention in (B, H, S, Dh) layout (dense — test-scale sequence lengths)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True,
              window: int | None = None) -> jax.Array:
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, s, dh) * (1.0 / math.sqrt(dh))
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, s, dh).astype(q.dtype)
