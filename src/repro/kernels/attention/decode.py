"""Pallas TPU split-KV flash-decode kernel (GQA, per-slot positions).

The serve engine preallocates KV slots at the full decode horizon
(repro.serve), so the reference ``decode_attention`` reads and masks
**every** ``max_len`` cache row for every slot on every token — a slot
at ``pos=3`` pays the same DMA bill as one at ``pos=4095``, and the
dense ``(B, Hkv, G, 1, Skv)`` score tensor round-trips HBM at fusion
boundaries. This kernel is the WA-evasion-spirited fix at decode scale
(the CloverLeaf lesson: never move bytes you don't need):

* KV is tiled over the innermost grid dimension with **block-level
  early-out** — ``pl.when`` skips every KV block wholly beyond a
  slot's position (and, with a sliding window, wholly before it), so
  per-step work scales with cache *occupancy*, not horizon.
* The online-softmax accumulators (m, l, acc) live in VMEM scratch and
  never touch HBM; queries are a single token, so all GQA heads are
  packed into one ``(Hkv·G, Dh)`` tile (``(Sq·Hkv·G, Dh)`` for short
  multi-token tiles) instead of wasting a grid dimension on
  sub-sublane head tiles.
* Long caches shard over ``n_splits`` KV splits (flash-decoding): each
  split accumulates its own partial (m, l, acc) and a cross-split
  combine merges them outside the kernel.

Grid: (batch, n_splits, kv_blocks_per_split), KV innermost. ``pos`` is
scalar-prefetched so both the kernel and its masks see every slot's
position before any block work is issued.

Tile sizes come from the MemTier-driven autotuner
(``repro.kernels.tuning``), not constants; routing and CPU fallbacks
live in ``repro.kernels.attention.ops``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_scr, m_scr, l_scr, *, bk, bps, sq, g, hkv, scale,
                   window):
    """One (batch, split, kv-block) grid step of split-KV flash decode.

    Scratch carries the online-softmax state across the innermost
    (kv-block) grid dimension; rows of the packed query tile are
    ordered (Sq major, G minor) per kv head.
    """
    b = pl.program_id(0)
    s = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    pos_b = pos_ref[b]
    start = (s * bps + ik) * bk
    # block-level early-out: skip blocks wholly beyond the slot's last
    # query position (and wholly before its window, when sliding)
    live = start <= pos_b + (sq - 1)
    if window is not None:
        live = jnp.logical_and(live, start + bk - 1 > pos_b - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (Sq, H, dh)
        dh = q.shape[-1]
        # pack to (hkv, Sq*g, dh): kv-head batched, (Sq, g) rows minor
        qp = q.reshape(sq, hkv, g, dh).transpose(1, 0, 2, 3)
        qp = qp.reshape(hkv, sq * g, dh)
        k = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)  # (hkv,bk,dh)
        v = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)
        st = jax.lax.dot_general(qp, k, (((2,), (2,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        k_pos = start + jax.lax.iota(jnp.int32, bk)     # (bk,)
        # row j of the Sq tile queries absolute position pos_b + j
        q_pos = pos_b + jax.lax.iota(jnp.int32, sq * g) // g
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask = jnp.logical_and(
                mask, k_pos[None, :] > q_pos[:, None] - window)
        st = jnp.where(mask[None], st, NEG_INF)         # (hkv,Sq*g,bk)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, st.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(st - m_new[..., None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + jax.lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == bps - 1)
    def _finalize():
        dh = acc_scr.shape[-1]
        # unpack (hkv, Sq*g, ·) back to (Sq, H, ·)
        def unpack(x, trail):
            y = x.reshape((hkv, sq, g) + trail)
            return y.transpose((1, 0, 2) + tuple(
                3 + i for i in range(len(trail))))
        o_ref[0, 0] = unpack(acc_scr[...], (dh,)).reshape(sq, hkv * g, dh)
        m_ref[0, 0] = unpack(m_scr[...], ()).reshape(sq, hkv * g)
        l_ref[0, 0] = unpack(l_scr[...], ()).reshape(sq, hkv * g)


def flash_decode(q, k, v, pos, *, window: int | None = None,
                 bk: int = 128, n_splits: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Split-KV flash decode against a fixed-horizon KV cache.

    q: (B, Sq, H, Dh) — the current decode token(s); k, v: (B, Skv,
    Hkv, Dh) slot caches. ``pos`` is the absolute position of the
    *first* query token — a scalar, or a (B,) vector when slots decode
    at independent positions (continuous batching); query token ``j``
    attends cache rows ``<= pos + j`` (all Sq new keys are already in
    the cache, as in the model's decode flow). Returns (B, Sq, H, Dh)
    in q's dtype.

    ``Skv`` need not divide ``bk``: the cache is padded up to the
    block grid and padded rows are causally masked (``pos < Skv``
    always). Splits partition the KV blocks; each split's partial
    (m, l, acc) is merged by :func:`combine_splits`.
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    assert h == hkv * g and sq >= 1
    bk = max(1, min(bk, max(skv, 1)))
    nb = math.ceil(skv / bk)
    n_splits = max(1, min(n_splits, nb))
    bps = math.ceil(nb / n_splits)
    skv_pad = n_splits * bps * bk
    if skv_pad > skv:
        padding = [(0, 0), (0, skv_pad - skv), (0, 0), (0, 0)]
        k = jnp.pad(k, padding)
        v = jnp.pad(v, padding)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _decode_kernel, bk=bk, bps=bps, sq=sq, g=g, hkv=hkv, scale=scale,
        window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_splits, bps),
        in_specs=[
            pl.BlockSpec((1, sq, h, dh), lambda b_, s, ik, p: (b_, 0, 0, 0)),
            pl.BlockSpec((1, bk, hkv, dh),
                         lambda b_, s, ik, p, n=bps:
                         (b_, s * n + ik, 0, 0)),
            pl.BlockSpec((1, bk, hkv, dh),
                         lambda b_, s, ik, p, n=bps:
                         (b_, s * n + ik, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, sq, h, dh),
                         lambda b_, s, ik, p: (s, b_, 0, 0, 0)),
            pl.BlockSpec((1, 1, sq, h), lambda b_, s, ik, p: (s, b_, 0, 0)),
            pl.BlockSpec((1, 1, sq, h), lambda b_, s, ik, p: (s, b_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, sq * g, dh), jnp.float32),
            pltpu.VMEM((hkv, sq * g), jnp.float32),
            pltpu.VMEM((hkv, sq * g), jnp.float32),
        ])
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_splits, b, sq, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, b, sq, h), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, b, sq, h), jnp.float32),
        ],
        interpret=interpret)(pos_arr, q, k, v)
    return combine_splits(o_part, m_part, l_part).astype(q.dtype)


def _paged_decode_kernel(pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_scr, m_scr, l_scr, **kw):
    """Paged grid step: the block table is consumed by the BlockSpec
    index maps (physical page -> KV block), so the kernel body is the
    dense one verbatim — masking stays in *logical* coordinates."""
    del bt_ref
    _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_scr, m_scr, l_scr, **kw)


def flash_decode_paged(q, k_pages, v_pages, block_tables, pos, *,
                       window: int | None = None, n_splits: int = 1,
                       interpret: bool = False) -> jax.Array:
    """Split-KV flash decode against a paged KV pool (vLLM-style).

    q: (B, Sq, H, Dh); ``k_pages``/``v_pages``: (P, page, Hkv, Dh)
    physical page pools shared by every slot; ``block_tables``: (B, NB)
    int32 mapping each slot's logical page ``i`` (cache rows
    ``i*page .. (i+1)*page-1``) to a physical page. Both the block
    table and ``pos`` are scalar-prefetched: the KV BlockSpec index
    maps read the table, so each grid step DMAs exactly the physical
    page its logical block lives in — the gather *is* the block
    indexing, no materialized (B, NB*page, ...) cache ever exists.

    The KV block equals the page size (one page per grid step) and the
    block-level early-out is unchanged: it tests the *logical* block
    start against ``pos``, so out-of-order physical tables cost
    nothing. Entries beyond a slot's live pages may be arbitrary valid
    page ids (they are fetched but fully masked). Returns
    (B, Sq, H, Dh) in q's dtype.
    """
    b, sq, h, dh = q.shape
    ps, hkv = k_pages.shape[1], k_pages.shape[2]
    g = h // hkv
    assert h == hkv * g and sq >= 1
    nb = block_tables.shape[1]
    n_splits = max(1, min(n_splits, nb))
    bps = math.ceil(nb / n_splits)
    bt = jnp.asarray(block_tables, jnp.int32)
    bt = jnp.clip(bt, 0, k_pages.shape[0] - 1)
    if n_splits * bps > nb:
        # pad the table to the split grid; padded blocks are logically
        # past every pos (start >= nb*ps) so the early-out skips them
        bt = jnp.pad(bt, [(0, 0), (0, n_splits * bps - nb)])
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _paged_decode_kernel, bk=ps, bps=bps, sq=sq, g=g, hkv=hkv,
        scale=scale, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_splits, bps),
        in_specs=[
            pl.BlockSpec((1, sq, h, dh),
                         lambda b_, s, ik, p, t: (b_, 0, 0, 0)),
            pl.BlockSpec((1, ps, hkv, dh),
                         lambda b_, s, ik, p, t, n=bps:
                         (t[b_, s * n + ik], 0, 0, 0)),
            pl.BlockSpec((1, ps, hkv, dh),
                         lambda b_, s, ik, p, t, n=bps:
                         (t[b_, s * n + ik], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, sq, h, dh),
                         lambda b_, s, ik, p, t: (s, b_, 0, 0, 0)),
            pl.BlockSpec((1, 1, sq, h),
                         lambda b_, s, ik, p, t: (s, b_, 0, 0)),
            pl.BlockSpec((1, 1, sq, h),
                         lambda b_, s, ik, p, t: (s, b_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, sq * g, dh), jnp.float32),
            pltpu.VMEM((hkv, sq * g), jnp.float32),
            pltpu.VMEM((hkv, sq * g), jnp.float32),
        ])
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_splits, b, sq, h, dh), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, b, sq, h), jnp.float32),
            jax.ShapeDtypeStruct((n_splits, b, sq, h), jnp.float32),
        ],
        interpret=interpret)(pos_arr, bt, q, k_pages, v_pages)
    return combine_splits(o_part, m_part, l_part).astype(q.dtype)


def ref_decode_paged(q, k_pages, v_pages, block_tables, pos, *,
                     window: int | None = None) -> jax.Array:
    """Pure-JAX paged twin of :func:`flash_decode_paged` (off-TPU path).

    Gathers each slot's pages in logical order and delegates to the
    dense reference decode. Because every logical row keeps its
    position, masked rows contribute exact zeros and the result is
    identical to decoding the equivalent contiguous cache.
    """
    b = q.shape[0]
    hkv, dh = k_pages.shape[2], k_pages.shape[3]
    bt = jnp.asarray(block_tables, jnp.int32)
    k = k_pages[bt].reshape(b, -1, hkv, dh)
    v = v_pages[bt].reshape(b, -1, hkv, dh)
    return ref_decode(q, k, v, pos, window=window)


def combine_splits(o_part, m_part, l_part) -> jax.Array:
    """Merge per-split partial softmax states (flash-decoding combine).

    o_part: (S, B, Sq, H, Dh) unnormalized accumulators; m_part /
    l_part: (S, B, Sq, H) running max / sum per split. Splits whose
    blocks were all skipped carry (m=NEG_INF, l=0) and contribute
    exactly zero weight. Returns (B, Sq, H, Dh) f32.
    """
    m_max = m_part.max(axis=0)                           # (B,Sq,H)
    w = jnp.exp(m_part - m_max[None])                    # dead split -> 0
    l_tot = (l_part * w).sum(axis=0)
    o = (o_part * w[..., None]).sum(axis=0)
    return o / jnp.maximum(l_tot, 1e-30)[..., None]


def ref_decode(q, k, v, pos, *, window: int | None = None,
               kv_len: int | None = None) -> jax.Array:
    """Occupancy-bounded pure-JAX oracle for :func:`flash_decode`.

    Numerically the dense masked-GQA decode, but — like the kernel's
    block early-out — it only ever touches the first ``kv_len`` cache
    rows (a static bound the caller derives from occupancy, rounded to
    the block grid). With ``kv_len=None`` it degrades to the dense
    full-horizon read. This is the off-TPU execution path the ops
    router uses, and the parity target the kernel is tested against.
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    if kv_len is not None:
        kv_len = max(1, min(int(kv_len), skv))
        k = k[:, :kv_len]
        v = v[:, :kv_len]
        skv = kv_len
    qg = q.reshape(b, sq, hkv, g, dh) * (1.0 / math.sqrt(dh))
    st = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                    preferred_element_type=jnp.float32)
    k_pos = jnp.arange(skv)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1),
                            (b, 1))
    q_pos = posb + jnp.arange(sq)[None, :]               # (B, Sq)
    mask = k_pos[None, None, :] <= q_pos[..., None]      # (B, Sq, Skv)
    if window is not None:
        mask &= k_pos[None, None, :] > (q_pos[..., None] - window)
    st = jnp.where(mask[:, None, None, :, :], st, NEG_INF)
    p = jax.nn.softmax(st, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dh).astype(q.dtype)
