"""Pallas TPU flash attention (causal, GQA, optional sliding window).

The in-core/roofline analysis of the scan-based reference attention shows
it DMA-bound: every online-softmax step round-trips (scores, m, l, acc)
through HBM at fusion boundaries (~6 GB per layer-pass for yi-9b train_4k
vs ~150 MB of Q/K/V/O payload — see EXPERIMENTS.md §Perf). This kernel is
the WA-evasion-spirited fix: the (bq, bk) score tile, the running max/sum
and the output accumulator never leave VMEM; the TPU grid's sequential
innermost dimension carries the accumulator across KV blocks (scratch
persists across grid steps that map to the same output block).

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks), KV innermost.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = True
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = False

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq, bk, n_kv, scale, causal, window):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)
    k_pos = ik * bk + jax.lax.iota(jnp.int32, bk)

    # causal/window block skip: any work in this block?
    lo_q, hi_k = iq * bq, ik * bk
    live = True
    if causal:
        live = hi_k <= lo_q + bq - 1
    if window is not None:
        live = jnp.logical_and(live, (ik + 1) * bk - 1 > lo_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask = jnp.logical_and(
                mask, k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, bq: int | None = None,
                    bk: int | None = None, causal: bool = True,
                    window: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh) -> (B, H, S, Dh).

    ``bq``/``bk`` default to the MemTier-autotuned tiling for the
    default target machine (``repro.kernels.tuning``) — the historical
    hardcoded 512s survive only as an explicit caller choice.
    """
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    if bq is None or bk is None:
        from repro.kernels import tuning
        plan = tuning.flash_tiles(tuning.default_machine(), s=s, dh=dh,
                                  h=h, hkv=hkv, dtype=str(q.dtype))
        # snap to divisors of s — the grid below requires exact tiling
        bq = bq or tuning.fit_block(plan.bq, s)
        bk = bk or tuning.fit_block(plan.bk, s)
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=nk, scale=scale,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret)(q, k, v)
