"""Jitted public wrappers for the stream kernel suite.

``impl`` selects between the Pallas kernel (TPU target; interpret mode on
CPU) and the pure-jnp oracle. ``auto`` = Pallas on TPU, oracle elsewhere
(the oracle is what XLA would fuse anyway; the kernel exists to control
tiling and store alignment explicitly on TPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.stream import kernels as K
from repro.kernels.stream import ref as R


def _route(pallas_fn, ref_fn, impl, *args, **kw):
    if not use_pallas(impl):
        return ref_fn(*args, **kw)
    return pallas_fn(*args, interpret=interpret_mode(), **kw)


@partial(jax.jit, static_argnames=("shape", "dtype", "impl"))
def init(shape, scalar=3.0, dtype=jnp.float32, impl="auto"):
    if not use_pallas(impl):
        return R.init(shape, scalar, dtype)
    return K.init_store(shape, scalar, dtype, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("impl",))
def copy(b, impl="auto"):
    return _route(K.copy, R.copy, impl, b)


@partial(jax.jit, static_argnames=("impl",))
def add(b, c, impl="auto"):
    return _route(K.add, R.add, impl, b, c)


@partial(jax.jit, static_argnames=("impl",))
def update(a, s=2.0, impl="auto"):
    return _route(K.update, R.update, impl, a, s)


@partial(jax.jit, static_argnames=("impl",))
def stream_triad(b, c, s=2.0, impl="auto"):
    return _route(K.stream_triad, R.stream_triad, impl, b, c, s)


@partial(jax.jit, static_argnames=("impl",))
def schoenauer_triad(b, c, d, impl="auto"):
    return _route(K.schoenauer_triad, R.schoenauer_triad, impl, b, c, d)


@partial(jax.jit, static_argnames=("impl",))
def sum_reduction(a, impl="auto"):
    return _route(K.sum_reduction, R.sum_reduction, impl, a)


@partial(jax.jit, static_argnames=("n", "impl"))
def pi_integration(n, impl="auto"):
    if not use_pallas(impl):
        return R.pi_integration(n)
    return K.pi_integration(n, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("impl",))
def jacobi_2d5pt(u, impl="auto"):
    return _route(K.jacobi_2d5pt, R.jacobi_2d5pt, impl, u)


@partial(jax.jit, static_argnames=("impl",))
def jacobi_3d7pt(u, impl="auto"):
    return _route(K.jacobi_3d7pt, R.jacobi_3d7pt, impl, u)


@partial(jax.jit, static_argnames=("sweeps", "impl"))
def gauss_seidel_2d5pt(u, sweeps=1, impl="auto"):
    if not use_pallas(impl):
        return R.gauss_seidel_2d5pt(u, sweeps)
    return K.gauss_seidel_2d5pt(u, sweeps, interpret=interpret_mode())
