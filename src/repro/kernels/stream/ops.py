"""Jitted public wrappers for the stream kernel suite.

``impl`` selects between the Pallas kernel (TPU target; interpret mode on
CPU) and the pure-jnp oracle. ``auto`` = Pallas on TPU, oracle elsewhere
(the oracle is what XLA would fuse anyway; the kernel exists to control
tiling and store alignment explicitly on TPU).

Store-heavy wrappers (INIT/COPY/UPDATE/triad) additionally take
``flavor`` (``standard | nt | auto``): ``nt`` always runs the
full-tile-aligned NT store variant (interpret mode off-TPU, the parity
path), ``auto`` asks :mod:`repro.kernels.stores` to pick per machine and
executes NT only on a real TPU — elsewhere the selection is recorded in
plans/pricing but the standard kernel runs (modeled-only fallback).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode, use_pallas
from repro.kernels.stream import kernels as K
from repro.kernels.stream import ref as R


def _route(pallas_fn, ref_fn, impl, *args, **kw):
    if not use_pallas(impl):
        return ref_fn(*args, **kw)
    return pallas_fn(*args, interpret=interpret_mode(), **kw)


def _nt_route(nt_fn, pallas_fn, ref_fn, impl, flavor, *args, **kw):
    """_route plus the store-flavor leg: NT kernel when it resolves on."""
    from repro.kernels.stores import executed_flavor
    if executed_flavor(flavor) == "nt":
        return nt_fn(*args, interpret=interpret_mode(), **kw)
    return _route(pallas_fn, ref_fn, impl, *args, **kw)


@partial(jax.jit, static_argnames=("shape", "dtype", "impl", "flavor"))
def init(shape, scalar=3.0, dtype=jnp.float32, impl="auto",
         flavor="standard"):
    """INIT a[:] = s; ``flavor`` picks the store path (see module doc)."""
    from repro.kernels.stores import executed_flavor
    if executed_flavor(flavor) == "nt":
        return K.init_nt(shape, scalar, dtype, interpret=interpret_mode())
    if not use_pallas(impl):
        return R.init(shape, scalar, dtype)
    return K.init_store(shape, scalar, dtype, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("impl", "flavor"))
def copy(b, impl="auto", flavor="standard"):
    """COPY o = b through the selected store path."""
    return _nt_route(K.copy_nt, K.copy, R.copy, impl, flavor, b)


@partial(jax.jit, static_argnames=("impl",))
def add(b, c, impl="auto"):
    """ADD o = b + c."""
    return _route(K.add, R.add, impl, b, c)


@partial(jax.jit, static_argnames=("impl", "flavor"))
def update(a, s=2.0, impl="auto", flavor="standard"):
    """UPDATE o = s * a through the selected store path."""
    return _nt_route(K.update_nt, K.update, R.update, impl, flavor, a, s)


@partial(jax.jit, static_argnames=("impl", "flavor"))
def stream_triad(b, c, s=2.0, impl="auto", flavor="standard"):
    """STREAM triad o = b + s * c through the selected store path."""
    return _nt_route(K.stream_triad_nt, K.stream_triad, R.stream_triad,
                     impl, flavor, b, c, s)


@partial(jax.jit, static_argnames=("impl",))
def schoenauer_triad(b, c, d, impl="auto"):
    """Schoenauer triad o = b + c * d."""
    return _route(K.schoenauer_triad, R.schoenauer_triad, impl, b, c, d)


@partial(jax.jit, static_argnames=("impl",))
def sum_reduction(a, impl="auto"):
    """Full sum reduction of a."""
    return _route(K.sum_reduction, R.sum_reduction, impl, a)


@partial(jax.jit, static_argnames=("n", "impl"))
def pi_integration(n, impl="auto"):
    """Midpoint quadrature of 4/(1+x^2) with n points."""
    if not use_pallas(impl):
        return R.pi_integration(n)
    return K.pi_integration(n, interpret=interpret_mode())


@partial(jax.jit, static_argnames=("impl",))
def jacobi_2d5pt(u, impl="auto"):
    """2-D 5-point Jacobi sweep over the interior of u."""
    return _route(K.jacobi_2d5pt, R.jacobi_2d5pt, impl, u)


@partial(jax.jit, static_argnames=("impl",))
def jacobi_3d7pt(u, impl="auto"):
    """3-D 7-point Jacobi sweep over the interior of u."""
    return _route(K.jacobi_3d7pt, R.jacobi_3d7pt, impl, u)


@partial(jax.jit, static_argnames=("sweeps", "impl"))
def gauss_seidel_2d5pt(u, sweeps=1, impl="auto"):
    """Row-wavefront 2-D Gauss-Seidel, `sweeps` iterations."""
    if not use_pallas(impl):
        return R.gauss_seidel_2d5pt(u, sweeps)
    return K.gauss_seidel_2d5pt(u, sweeps, interpret=interpret_mode())
