"""Pure-jnp oracles for the paper's 13 streaming validation kernels
(paper §II: Jacobi stencils, ADD, COPY, Gauss-Seidel, pi, INIT,
Schoenauer triad, sum reduction, STREAM triad, UPDATE).

These are simultaneously (a) the correctness oracles for the Pallas
kernels, (b) the measurement subjects of the RPE harness (paper Fig. 3),
and (c) the store-traffic subjects of the WA study (paper Fig. 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(shape, scalar=3.0, dtype=jnp.float32):
    """a[:] = s — the paper's store-only WA benchmark."""
    return jnp.full(shape, scalar, dtype)


def copy(b):
    """COPY: o = b (materialized)."""
    return b + 0.0


def add(b, c):
    """ADD: o = b + c."""
    return b + c


def update(a, s=2.0):
    """UPDATE: o = s * a."""
    return a * s


def stream_triad(b, c, s=2.0):
    """STREAM triad: o = b + s * c."""
    return b + s * c


def schoenauer_triad(b, c, d):
    """Schoenauer triad: o = b + c * d."""
    return b + c * d


def sum_reduction(a):
    """Full sum reduction."""
    return jnp.sum(a)


def pi_integration(n: int, dtype=jnp.float32):
    """pi by midpoint integration of 4/(1+x^2) on [0,1]."""
    i = jnp.arange(n, dtype=dtype)
    x = (i + 0.5) / n
    return jnp.sum(4.0 / (1.0 + x * x)) / n


def jacobi_2d5pt(u):
    """(H, W) -> interior 5-point average."""
    return 0.25 * (u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:])


def jacobi_3d7pt(u):
    """(D, H, W) -> interior 7-point average."""
    c = 1.0 / 6.0
    return c * (u[:-2, 1:-1, 1:-1] + u[2:, 1:-1, 1:-1] +
                u[1:-1, :-2, 1:-1] + u[1:-1, 2:, 1:-1] +
                u[1:-1, 1:-1, :-2] + u[1:-1, 1:-1, 2:])


def jacobi_3d11pt(u):
    """7pt + second-neighbour along the two minor axes (r=2 star, 11 pts)."""
    c = 1.0 / 10.0
    i = u[2:-2, 2:-2, 2:-2]
    return c * (u[1:-3, 2:-2, 2:-2] + u[3:-1, 2:-2, 2:-2] +
                u[2:-2, 1:-3, 2:-2] + u[2:-2, 3:-1, 2:-2] +
                u[2:-2, 2:-2, 1:-3] + u[2:-2, 2:-2, 3:-1] +
                u[2:-2, 2:-2, :-4] + u[2:-2, 2:-2, 4:] +
                u[2:-2, :-4, 2:-2] + u[2:-2, 4:, 2:-2])


def jacobi_3d27pt(u):
    """(D, H, W) -> interior 27-point (full 3x3x3 box) average."""
    acc = 0.0
    for dz in (0, 1, 2):
        for dy in (0, 1, 2):
            for dx in (0, 1, 2):
                acc = acc + u[dz:dz + u.shape[0] - 2,
                              dy:dy + u.shape[1] - 2,
                              dx:dx + u.shape[2] - 2]
    return acc / 27.0


def gauss_seidel_2d5pt(u, sweeps: int = 1):
    """Row-wavefront Gauss-Seidel: row i uses already-updated row i-1.

    Sequential over rows (lax.scan) — the paper's latency-bound case
    (its OSACA model over-predicts this kernel because register renaming
    beats the modeled dependency; our LCD analysis has the same designed
    failure mode, reported in the RPE results).
    """
    def sweep(u, _):
        def row_step(prev_row, rows):
            cur, down = rows
            new_int = 0.25 * (prev_row[1:-1] + down[1:-1] +
                              cur[:-2] + cur[2:])
            # NOTE: cur.at[1:-1].set(new_int) here triggers an XLA:CPU
            # scan miscompilation in jax 0.8.2 (compiled result differs
            # from disable_jit); concatenate sidesteps the aliasing.
            new = jnp.concatenate([cur[:1], new_int, cur[-1:]])
            return new, new
        _, body = jax.lax.scan(row_step, u[0], (u[1:-1], u[2:]))
        return jnp.concatenate([u[:1], body, u[-1:]], axis=0), None
    u, _ = jax.lax.scan(sweep, u, None, length=sweeps)
    return u


KERNELS_13 = (
    "init", "copy", "add", "update", "stream_triad", "schoenauer_triad",
    "sum_reduction", "pi_integration", "jacobi_2d5pt", "jacobi_3d7pt",
    "jacobi_3d11pt", "jacobi_3d27pt", "gauss_seidel_2d5pt",
)
