"""Pallas TPU kernels for the paper's streaming benchmark suite.

Every kernel uses explicit BlockSpec VMEM tiling sized to the native
(8,128) tile grid. The INIT kernel is the paper's §III write-allocate
subject: `init_store` writes full aligned tiles (the TPU/Grace
"cache-line claim" regime, traffic ratio 1.0); `init_partial` deliberately
writes tile-misaligned blocks so the WA analyzer (repro.core.wa) charges
the RMW reads (the Zen-4-without-NT-stores regime).

Validated against repro.kernels.stream.ref in interpret mode on CPU
(tests/test_kernels_stream.py); compiled lowering targets TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:                      # element-indexed dims for stencil halos
    import jax._src.pallas.core as _pc
    Element = _pc.Element
except Exception:         # pragma: no cover - API drift guard
    Element = None

DEFAULT_BM = 256          # rows per block
DEFAULT_BN = 512          # cols per block (multiple of 128)

#: jnp dtype name -> the short name `repro.core.wa.native_tile` expects
_DTYPE_SHORT = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
                "int32": "s32", "int8": "s8", "uint8": "u8"}


def _grid2(shape, bm, bn):
    """(grid, bm, bn) for an exact block tiling of a 2-D shape."""
    m, n = shape
    bm = min(bm, m)
    bn = min(bn, n)
    assert m % bm == 0 and n % bn == 0, (shape, bm, bn)
    return (m // bm, n // bn), bm, bn


def _nt_grid2(shape, dtype, bm=DEFAULT_BM, bn=DEFAULT_BN):
    """Tile-granule-snapped blocking for the NT store path.

    Returns ``(grid, bm, bn, mp, np)``: block sizes snapped to
    multiples of the native (sublane, lane) store granule of ``dtype``
    and the padded extents ``(mp, np)`` they tile exactly — every
    store an NT kernel issues overwrites whole tiles (traffic ratio
    1.0 by construction, the TPU NT-store analogue; DESIGN.md §2).
    """
    from repro.core.wa import native_tile
    st, sl = native_tile(_DTYPE_SHORT.get(jnp.dtype(dtype).name, "f32"))
    m, n = shape
    bm = max(st, min((bm // st) * st, -(-m // st) * st))
    bn = max(sl, min((bn // sl) * sl, -(-n // sl) * sl))
    mp, npad = -(-m // bm) * bm, -(-n // bn) * bn
    return (mp // bm, npad // bn), bm, bn, mp, npad


def _nt_call(kernel, args, shape, dtype, *, interpret):
    """Run a 2-D elementwise kernel on the tile-padded NT grid.

    Inputs are zero-padded up to the snapped grid, every output block
    is a full aligned tile multiple, and the result is sliced back to
    ``shape`` — numerics identical to the standard-blocked variant,
    store traffic provably allocate-free on the tile grid.
    """
    grid, bm, bn, mp, npad = _nt_grid2(shape, dtype)
    m, n = shape
    pad = [(0, mp - m), (0, npad - n)]
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec] * len(args),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((mp, npad), dtype),
        interpret=interpret)(*(jnp.pad(a, pad) for a in args))
    return out[:m, :n]


# --- elementwise family -----------------------------------------------------

def _init_kernel(o_ref, *, scalar):
    o_ref[...] = jnp.full(o_ref.shape, scalar, o_ref.dtype)


def init_store(shape, scalar=3.0, dtype=jnp.float32, *, bm=DEFAULT_BM,
               bn=DEFAULT_BN, interpret=False):
    """a[:] = s with full-tile aligned stores (perfect WA evasion)."""
    grid, bm, bn = _grid2(shape, bm, bn)
    return pl.pallas_call(
        functools.partial(_init_kernel, scalar=scalar),
        grid=grid,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        interpret=interpret)()


def init_partial(shape, scalar=3.0, dtype=jnp.float32, *, interpret=False):
    """Store-only with tile-MISALIGNED blocks (7 x 100): every block edge
    forces a read-modify-write on the (8,128) tile grid — full WA."""
    m, n = shape
    bm, bn = 7, 100
    gm, gn = -(-m // bm), -(-n // bn)

    def k(o_ref):
        o_ref[...] = jnp.full(o_ref.shape, scalar, o_ref.dtype)

    padded = pl.pallas_call(
        k, grid=(gm, gn),
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), dtype),
        interpret=interpret)()
    return padded[:m, :n]


def init_nt(shape, scalar=3.0, dtype=jnp.float32, *, interpret=False):
    """INIT through the NT store path: tile-granule-snapped blocks.

    Handles arbitrary (also misaligned) shapes by writing the padded
    full-tile grid and slicing — the WA-evading counterpart of
    :func:`init_partial`, which deliberately pays the full allocate
    cost on the same shapes.
    """
    return _nt_call(functools.partial(_init_kernel, scalar=scalar), (),
                    shape, dtype, interpret=interpret)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def copy_nt(x, *, interpret=False):
    """COPY with NT (full-tile aligned, padded-grid) stores."""
    return _nt_call(_copy_kernel, (x,), x.shape, x.dtype,
                    interpret=interpret)


def copy(x, *, bm=DEFAULT_BM, bn=DEFAULT_BN, interpret=False):
    """COPY: o = x, standard block tiling."""
    grid, bm, bn = _grid2(x.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _copy_kernel, grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret)(x)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def add(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, interpret=False):
    """ADD: o = a + b, standard block tiling."""
    grid, bm, bn = _grid2(a.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _add_kernel, grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(a, b)


def _update_kernel(a_ref, o_ref, *, scalar):
    o_ref[...] = a_ref[...] * scalar


def update_nt(a, s=2.0, *, interpret=False):
    """UPDATE with NT (full-tile aligned, padded-grid) stores."""
    return _nt_call(functools.partial(_update_kernel, scalar=s), (a,),
                    a.shape, a.dtype, interpret=interpret)


def update(a, s=2.0, *, bm=DEFAULT_BM, bn=DEFAULT_BN, interpret=False):
    """UPDATE: o = s * a, standard block tiling."""
    grid, bm, bn = _grid2(a.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_update_kernel, scalar=s),
        grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret)(a)


def _triad_kernel(b_ref, c_ref, o_ref, *, scalar):
    o_ref[...] = b_ref[...] + scalar * c_ref[...]


def stream_triad_nt(b, c, s=2.0, *, interpret=False):
    """STREAM triad with NT (full-tile aligned, padded-grid) stores."""
    return _nt_call(functools.partial(_triad_kernel, scalar=s), (b, c),
                    b.shape, b.dtype, interpret=interpret)


def stream_triad(b, c, s=2.0, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                 interpret=False):
    """STREAM triad: o = b + s * c, standard block tiling."""
    grid, bm, bn = _grid2(b.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar=s),
        grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret)(b, c)


def _striad_kernel(b_ref, c_ref, d_ref, o_ref):
    o_ref[...] = b_ref[...] + c_ref[...] * d_ref[...]


def schoenauer_triad(b, c, d, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                     interpret=False):
    """Schoenauer triad: o = b + c * d (three loads, one store)."""
    grid, bm, bn = _grid2(b.shape, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _striad_kernel, grid=grid, in_specs=[spec, spec, spec],
        out_specs=spec, out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret)(b, c, d)


# --- reductions -------------------------------------------------------------

def _partial_sum_kernel(x_ref, o_ref):
    o_ref[0, 0] = jnp.sum(x_ref[...])


def sum_reduction(x, *, bm=DEFAULT_BM, bn=DEFAULT_BN, interpret=False):
    """Two-stage: per-block partials in the kernel, final sum outside."""
    grid, bm, bn = _grid2(x.shape, bm, bn)
    parts = pl.pallas_call(
        _partial_sum_kernel, grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        interpret=interpret)(x)
    return jnp.sum(parts)


def _pi_kernel(o_ref, *, n, bn):
    j = pl.program_id(0)
    i = j * bn + jax.lax.iota(jnp.float32, bn)
    x = (i + 0.5) / n
    o_ref[0, 0] = jnp.sum(4.0 / (1.0 + x * x))


def pi_integration(n, *, bn=4096, interpret=False):
    """Midpoint-rule quadrature of 4/(1+x^2) on [0,1) with n points."""
    assert n % bn == 0
    parts = pl.pallas_call(
        functools.partial(_pi_kernel, n=n, bn=bn),
        grid=(n // bn,),
        out_specs=pl.BlockSpec((1, 1), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n // bn, 1), jnp.float32),
        interpret=interpret)()
    return jnp.sum(parts) / n


# --- stencils ---------------------------------------------------------------

def _jacobi2d_kernel(u_ref, o_ref):
    blk = u_ref[...]
    o_ref[...] = 0.25 * (blk[:-2, 1:-1] + blk[2:, 1:-1] +
                         blk[1:-1, :-2] + blk[1:-1, 2:])


def jacobi_2d5pt(u, *, bm=64, interpret=False):
    """Row-tiled with a +-1 halo via element-indexed block dims."""
    h, w = u.shape
    m = h - 2
    bm = min(bm, m)
    assert m % bm == 0, (h, bm)
    if Element is None:   # jax without element-indexed dims: no halo
        # tiling available — run the same kernel as one whole-array block
        return pl.pallas_call(
            _jacobi2d_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((h, w), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((m, w - 2), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((m, w - 2), u.dtype),
            interpret=interpret)(u)
    return pl.pallas_call(
        _jacobi2d_kernel, grid=(m // bm,),
        in_specs=[pl.BlockSpec((Element(bm + 2), w), lambda i: (i * bm, 0))],
        out_specs=pl.BlockSpec((bm, w - 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, w - 2), u.dtype),
        interpret=interpret)(u)


def _jacobi3d_kernel(u_ref, o_ref):
    b = u_ref[...]
    o_ref[...] = (1.0 / 6.0) * (
        b[:-2, 1:-1, 1:-1] + b[2:, 1:-1, 1:-1] +
        b[1:-1, :-2, 1:-1] + b[1:-1, 2:, 1:-1] +
        b[1:-1, 1:-1, :-2] + b[1:-1, 1:-1, 2:])


def jacobi_3d7pt(u, *, bz=8, interpret=False):
    """3-D 7-point Jacobi sweep, depth-tiled with a +-1 halo."""
    d, h, w = u.shape
    m = d - 2
    bz = min(bz, m)
    assert m % bz == 0, (d, bz)
    if Element is None:   # see jacobi_2d5pt: whole-array fallback
        return pl.pallas_call(
            _jacobi3d_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((d, h, w), lambda i: (0, 0, 0))],
            out_specs=pl.BlockSpec((m, h - 2, w - 2),
                                   lambda i: (0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((m, h - 2, w - 2), u.dtype),
            interpret=interpret)(u)
    return pl.pallas_call(
        _jacobi3d_kernel, grid=(m // bz,),
        in_specs=[pl.BlockSpec((Element(bz + 2), h, w),
                               lambda i: (i * bz, 0, 0))],
        out_specs=pl.BlockSpec((bz, h - 2, w - 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h - 2, w - 2), u.dtype),
        interpret=interpret)(u)


def _gs_kernel(u_ref, o_ref, *, sweeps):
    """Gauss-Seidel row wavefront inside one kernel: LCD on the row loop.
    Row i reads the already-updated row i-1 straight from o_ref."""
    h = o_ref.shape[0]

    def one_sweep(_, carry):
        def row(i, c):
            prev = o_ref[pl.ds(i - 1, 1), :]             # updated row i-1
            cur = o_ref[pl.ds(i, 1), :]
            down = o_ref[pl.ds(i + 1, 1), :]             # old row i+1
            new_int = 0.25 * (prev[:, 1:-1] + down[:, 1:-1] +
                              cur[:, :-2] + cur[:, 2:])
            new = jnp.concatenate([cur[:, :1], new_int, cur[:, -1:]],
                                  axis=1)
            o_ref[pl.ds(i, 1), :] = new
            return c
        jax.lax.fori_loop(1, h - 1, row, 0)
        return carry

    o_ref[...] = u_ref[...]
    jax.lax.fori_loop(0, sweeps, one_sweep, 0)


def gauss_seidel_2d5pt(u, sweeps=1, *, interpret=False):
    """In-place 2-D 5-point Gauss-Seidel sweeps (row wavefront)."""
    return pl.pallas_call(
        functools.partial(_gs_kernel, sweeps=sweeps),
        grid=(1,),
        in_specs=[pl.BlockSpec(u.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(u.shape, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        interpret=interpret)(u)
