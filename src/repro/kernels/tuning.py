"""MemTier-driven tile autotuner for the attention kernels.

The flash kernels used to ship hardcoded ``bq=512, bk=512`` tiles — a
number that is right on exactly one machine. The paper's lesson (and
the ECM lineage behind ``core/memtier.py``) is that the tile size that
keeps a kernel fast is a *property of the memory ladder*, so this
module derives tiles from the machine registry instead. Three effects
are priced per candidate, each straight off the machine file:

* **KV re-streaming** — the causal flash kernel re-reads K/V once per
  query block, so backing-tier traffic scales with ``1/bq``: bigger
  query tiles amortize the stream.
* **Score-tile residency** — the f32 score tile plus the
  online-softmax accumulators resolve to a home tier
  (``memtier.resolve_home``). While that home is *core-private*
  storage (VMEM, L1, L2 — ``MemTier.shared_bw == 0``), the KV stream
  double-buffers behind compute and the terms overlap (``max``); once
  the tile spills to a shared tier (L3/DRAM), every score access
  contends with the stream itself and the terms serialize (``sum``,
  classic pessimistic ECM). This is what the hardcoded 512s got wrong
  on the small-L2 CPUs.
* **Split parallelism** (decode) — KV splits run concurrently, so on a
  many-core socket they engage more cores against the shared DRAM
  ceiling (the flash-decoding win); each split costs one extra
  accumulator combine. Single-busy-core machines keep ``n_splits=1``.

The cheapest candidate wins, ties breaking toward the larger tile
(fewer grid steps amortize launch overhead the model does not price).
Machines therefore disagree — a 128 MB-VMEM TPU keeps the big score
tiles while the 1 MB-L2 Zen 4 core is pushed smaller — and
``tests/test_decode_kernel.py`` pins that spread so the tuner can
never silently degrade back into a constant.

Everything here is pure Python over the registry (no jax at call
time), so the tuner is safe to call while tracing to pick static tile
arguments; plans are memoized per ``(machine name, shape)``.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import memtier
from repro.core.machine import MACHINES, get_machine, machine_fingerprint
from repro.utils.hw import dtype_bytes

#: manual tile-plan memo. Keyed on the machine's *content* fingerprint,
#: not its name: an lru_cache keyed on the name would keep serving the
#: old machine's tiles after a ``register(replace=True)`` with different
#: parameters — the exact staleness bug the plan-DB work audits away.
_TILE_MEMO: dict = {}
#: how tile requests were satisfied (mirrors planner.plan_stats)
_TILE_STATS = {"online": 0, "memo_hits": 0, "db_hits": 0}


def tile_stats() -> dict:
    """Counters of how tile plans were served since the last reset."""
    return dict(_TILE_STATS)


def reset_tile_stats() -> None:
    """Zero the tile-plan counters (tests and benchmarks)."""
    for k in _TILE_STATS:
        _TILE_STATS[k] = 0


def _memoized_tiles(kind: str, machine: str, kwargs: dict, compute):
    """Memo -> plan-DB -> online resolution for one tile request.

    The memo key folds ``machine_fingerprint`` so re-registered
    machines with changed parameters miss cleanly; an installed plan
    database (repro.serve.plandb) is consulted before computing, and a
    DB hit is memoized so repeat requests stay O(1) dict probes.
    """
    m = get_machine(machine)
    key = (kind, m.name, machine_fingerprint(machine),
           tuple(sorted(kwargs.items())))
    hit = _TILE_MEMO.get(key)
    if hit is not None:
        _TILE_STATS["memo_hits"] += 1
        return hit
    from repro.serve import plandb
    db = plandb.installed()
    if db is not None:
        plan = db.lookup_tiles(kind, m.name, kwargs)
        if plan is not None:
            _TILE_STATS["db_hits"] += 1
            _TILE_MEMO[key] = plan
            return plan
    _TILE_STATS["online"] += 1
    plan = compute()
    _TILE_MEMO[key] = plan
    return plan

#: candidate block sizes, kernel-friendly powers of two, largest first
#: so that cost ties keep the larger (launch-amortizing) tile
FLASH_BQ_CANDIDATES = (1024, 512, 256, 128)
FLASH_BK_CANDIDATES = (1024, 512, 256, 128)
DECODE_BK_CANDIDATES = (512, 256, 128, 64)
DECODE_SPLIT_CANDIDATES = (8, 4, 2, 1)


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One autotuned tiling and the model cost that selected it."""

    machine: str
    bq: int                   # query block (1 token for decode)
    bk: int                   # KV block
    n_splits: int             # KV splits (flash-decoding); 1 for prefill
    seconds: float            # modeled kernel time of the priced shape
    home_tier: str            # tier the resident tile set resolves to
    ws_bytes: float           # per-step resident working set
    store_flavor: str = "standard"   # selected store path (stores.py)


def default_machine() -> str:
    """The machine tiles are tuned for when the caller names none.

    On a real TPU backend the registered chip models are authoritative
    (``tpu_v5e`` is the fleet's default target); elsewhere prefer the
    ubench-calibrated ``host_cpu`` when it exists, falling back to the
    TPU default — the kernels only ever *execute* on TPU anyway.
    """
    from repro.kernels import on_tpu
    if not on_tpu() and "host_cpu" in MACHINES:
        return "host_cpu"
    return "tpu_v5e"


def _mxu_seconds(m, macs: float, backend: str | None = None) -> float:
    """Modeled matmul time of ``macs`` multiply-accumulates on a machine.

    ``backend=None`` keeps the historical closed-form balanced-port
    arithmetic; naming a scheduling backend (core/backends) prices the
    same µ-ops through it instead — ``tp_bound`` is numerically
    identical, ``mca_sched`` adds its dispatch/latency pessimism.
    """
    e = m.table.get("mxu")
    if e is None:
        return 0.0
    passes = macs / (128.0 ** 3)
    if backend is not None:
        from repro.core.backends import uops_seconds
        return uops_seconds(m, [("mxu", passes)], backend)
    return m.seconds(passes * e.cycles_per_unit / max(1, len(e.ports)))


def _vpu_seconds(m, elems: float, weight: float = 1.0,
                 backend: str | None = None) -> float:
    """Modeled elementwise time of ``elems`` f32 lanes (softmax etc.).

    ``backend`` as in :func:`_mxu_seconds`.
    """
    e = m.table.get("vpu")
    if e is None:
        return 0.0
    blocks = elems / (8.0 * 128.0)
    if backend is not None:
        from repro.core.backends import uops_seconds
        return uops_seconds(m, [("vpu", weight * blocks)], backend)
    return m.seconds(weight * blocks * e.cycles_per_unit
                     / max(1, len(e.ports)))


def _resident_ws(bq: int, bk: int, dh: int, eb: int) -> float:
    """Bytes resident across one KV-block step: the f32 score tile, two
    generations of the f32 online-softmax accumulators (acc, m, l —
    read side and update side both live through the rescale), and the
    operand blocks."""
    scores = bq * bk * 4.0
    accs = bq * (dh + 2) * 4.0
    operands = (bq * dh + 2 * bk * dh) * eb
    return scores + 2.0 * accs + operands


def _tier_bw(tier, cores_active: int = 1) -> float:
    """Effective load bandwidth of one tier under ``cores_active``."""
    ld, _ = memtier.effective_bw(tier, cores_active)
    return max(ld, 1.0)


def _overlap_ok(tiers, home) -> bool:
    """Streaming overlaps compute only while the resident tile set
    lives in core-private storage (the innermost tier, or any tier
    with no shared socket ceiling)."""
    return home is tiers[0] or home.shared_bw == 0


def flash_tiles(machine: str, *, s: int, dh: int, h: int, hkv: int,
                dtype: str = "bf16",
                backend: str | None = None) -> TilePlan:
    """Autotuned (bq, bk) for the prefill/training flash kernel.

    Prices the causal kernel at sequence length ``s`` per candidate:
    stream / resident / compute terms composed by the overlap rule
    (module docstring) over the causal half-grid. ``machine`` is a
    registered name — plans are memoized on its content fingerprint
    and resolved through an installed plan database first
    (:func:`_memoized_tiles`). ``backend`` routes the compute term
    through a scheduling backend (``tp_bound`` reproduces the default
    closed form; ``mca_sched`` opts into simulator pessimism); None
    keeps the historical arithmetic.
    """
    kwargs = dict(s=s, dh=dh, h=h, hkv=hkv, dtype=dtype, backend=backend)
    return _memoized_tiles(
        "flash", machine, kwargs,
        lambda: _flash_tiles_online(machine, s=s, dh=dh, h=h, hkv=hkv,
                                    dtype=dtype, backend=backend))


def _flash_tiles_online(machine: str, *, s: int, dh: int, h: int,
                        hkv: int, dtype: str,
                        backend: str | None) -> TilePlan:
    m = get_machine(machine)
    tiers = memtier.tiers_of(m)
    backing = tiers[-1]
    eb = dtype_bytes(dtype)
    # compute is tiling-invariant: total MACs of the causal half
    t_cmp = _mxu_seconds(m, s * s * dh * h, backend) \
        + _vpu_seconds(m, s * s * h / 2.0, 3.0, backend)
    best = None
    for bq in FLASH_BQ_CANDIDATES:
        for bk in FLASH_BK_CANDIDATES:
            cbq, cbk = min(bq, s), min(bk, s)
            nq = math.ceil(s / cbq)
            nk = math.ceil(s / cbk)
            steps = nq * max(1.0, nk / 2.0)     # causal half grid
            ws = _resident_ws(cbq, cbk, dh, eb)
            home = memtier.resolve_home(tiers, ws)
            # every step touches the resident set ~twice (read+update)
            t_res = steps * 2.0 * ws / _tier_bw(home)
            # each q block streams its causal KV prefix (the flash grid
            # runs per q head, so the stream repeats h times)
            kv_total = nq * (s / 2.0) * 2.0 * dh * eb * h
            t_stream = kv_total / _tier_bw(backing)
            if _overlap_ok(tiers, home):
                total = max(t_stream, t_res, t_cmp)
            else:
                total = t_stream + t_res + t_cmp
            cand = TilePlan(machine=m.name, bq=cbq, bk=cbk, n_splits=1,
                            seconds=total, home_tier=home.name,
                            ws_bytes=ws)
            if best is None or total < best.seconds * (1.0 - 1e-9):
                best = cand
    from repro.kernels.stores import select_store_flavor
    return dataclasses.replace(
        best, store_flavor=select_store_flavor(
            m.name, ws_bytes=s * 2.0 * dh * eb * hkv))


def decode_tiles(machine: str, *, skv: int, dh: int, h: int, hkv: int,
                 batch: int = 1, dtype: str = "bf16",
                 backend: str | None = None) -> TilePlan:
    """Autotuned (bk, n_splits) for the split-KV flash-decode kernel.

    The query tile is the packed (Hkv*G, Dh) head block — one token —
    so KV is streamed exactly once per step and the candidate choice
    trades per-block bookkeeping (favors big ``bk``) against score-row
    residency (favors small ``bk``) while ``n_splits`` buys concurrent
    cores against the shared backing-tier ceiling at the price of one
    cross-split combine pass per split. Memoized/DB-resolved and
    ``backend``-routed as in :func:`flash_tiles`.
    """
    kwargs = dict(skv=skv, dh=dh, h=h, hkv=hkv, batch=batch, dtype=dtype,
                  backend=backend)
    return _memoized_tiles(
        "decode", machine, kwargs,
        lambda: _decode_tiles_online(machine, skv=skv, dh=dh, h=h,
                                     hkv=hkv, batch=batch, dtype=dtype,
                                     backend=backend))


def _decode_tiles_online(machine: str, *, skv: int, dh: int, h: int,
                         hkv: int, batch: int, dtype: str,
                         backend: str | None) -> TilePlan:
    m = get_machine(machine)
    tiers = memtier.tiers_of(m)
    backing = tiers[-1]
    eb = dtype_bytes(dtype)
    cores = max(1, getattr(m, "cores", 1))
    t_cmp = _mxu_seconds(m, 2.0 * batch * h * skv * dh, backend) \
        + _vpu_seconds(m, batch * h * skv, 3.0, backend)
    best = None
    for bk in DECODE_BK_CANDIDATES:
        cbk = min(bk, max(1, skv))
        nb = math.ceil(skv / cbk)
        ws = _resident_ws(h, cbk, dh, eb)
        home = memtier.resolve_home(tiers, ws)
        # per-block bookkeeping: the accumulators and the score rows
        # are touched every KV block
        t_res = batch * nb * 2.0 * ws / _tier_bw(home)
        for n_splits in DECODE_SPLIT_CANDIDATES:
            if n_splits > nb:
                continue
            lanes = min(batch * n_splits, cores)
            kv_total = batch * nb * cbk * 2.0 * dh * eb * hkv
            t_stream = kv_total / _tier_bw(backing, lanes)
            # splits run concurrently; the combine reads every split's
            # partial accumulator back once
            combine = _vpu_seconds(m, n_splits * batch * h * dh, 2.0,
                                   backend)
            par = min(n_splits, cores)
            if _overlap_ok(tiers, home):
                total = max(t_stream, t_res / par, t_cmp / par) + combine
            else:
                total = t_stream + (t_res + t_cmp) / par + combine
            cand = TilePlan(machine=m.name, bq=1, bk=cbk,
                            n_splits=n_splits, seconds=total,
                            home_tier=home.name, ws_bytes=ws)
            if best is None or total < best.seconds * (1.0 - 1e-9):
                best = cand
    from repro.kernels.stores import select_store_flavor
    return dataclasses.replace(
        best, store_flavor=select_store_flavor(
            m.name, ws_bytes=batch * skv * 2.0 * dh * eb * hkv,
            cores_active=min(batch * best.n_splits, cores)))


def fit_block(block: int, s: int) -> int:
    """Largest divisor of ``s`` not exceeding ``block``.

    The prefill kernel's grid requires tiles that divide the sequence
    exactly; snapping to the *largest* admissible divisor keeps the
    snapped tile as close to the priced plan as possible (a plain gcd
    collapses e.g. ``(256, 1000)`` to 8-wide blocks — a silent cliff).
    O(sqrt(s)).
    """
    block = max(1, min(block, s))
    if s % block == 0:
        return block
    best = 1
    i = 1
    while i * i <= s:
        if s % i == 0:
            for d in (i, s // i):
                if best < d <= block:
                    best = d
        i += 1
    return best


def clear_cache() -> None:
    """Drop memoized tile plans (tests re-register machines).

    Content-fingerprinted keys already miss when a machine's
    *parameters* change; clearing reclaims memory and forces the next
    request back through an installed plan DB.
    """
    _TILE_MEMO.clear()
