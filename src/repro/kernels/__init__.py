"""Kernel suite shared plumbing: backend detection and impl routing.

Every kernel package under ``repro.kernels`` exposes jitted public
wrappers (``ops.py``) whose ``impl`` argument selects between the
Pallas kernel and a pure-jnp reference. The backend probe and the
``impl`` resolution rules live here so the packages cannot drift:

* ``impl="ref"``   — always the reference implementation.
* ``impl="pallas"`` — always the Pallas kernel; off-TPU it runs in
  interpret mode (slow, numerics-faithful — the CI parity path).
* ``impl="auto"``  — Pallas on TPU, reference elsewhere (the reference
  is what XLA would fuse anyway; the kernel exists to control tiling
  and traffic explicitly on TPU).
"""

from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def use_pallas(impl: str) -> bool:
    """Resolve an ``impl`` string to "run the Pallas kernel?".

    ``interpret_mode()`` tells the kernel how to run when this returns
    True. Unknown impl strings raise so typos fail loudly.
    """
    if impl not in ("ref", "pallas", "auto"):
        raise ValueError(f"unknown impl {impl!r} "
                         "(expected 'ref', 'pallas', or 'auto')")
    if impl == "ref":
        return False
    if impl == "pallas":
        return True
    return on_tpu()


def interpret_mode() -> bool:
    """Pallas kernels run in interpret mode everywhere but real TPUs."""
    return not on_tpu()
