"""Per-machine store-path selection and WA-evading store kernels.

The paper's headline finding (§III, Fig. 4) is that the three vendors
need three different *store paths* to evade write-allocate traffic:
Grace claims cache lines automatically (standard stores are already
optimal), Zen 4 evades only via explicit non-temporal stores, and
SPR's SpecI2M sits in between — it engages only once the memory
interface saturates, so NT stores pay off *below* that gate and are
redundant above it. ``core/wa.py`` models this; this module turns the
model into an optimization: a **selector** that picks the fastest
store flavor per machine straight off the registry's WA mode and
``MemTier`` residues, plus the **kernel variants** the selection
routes between.

Store flavors:

* ``"standard"`` — plain stores: the XLA dynamic-update-slice path for
  KV writers, natural block tiling for the stream kernels. Pays the
  machine's full Fig. 4 allocate cost wherever no automatic mechanism
  evades it.
* ``"nt"`` — the non-temporal/streaming analogue. On TPU there is no
  NT opcode; the analogue (DESIGN.md §2) is a store that provably
  overwrites full native tiles in place: the stream kernels pad their
  block grid to the (8,128) tile granule, and the KV writers run a
  Pallas kernel whose output *aliases* the cache
  (``input_output_aliases``) and whose grid touches exactly the
  written rows — nothing else is read, copied, or allocated.
* ``"auto"`` — per-machine selection: the flavor whose modeled ladder
  ratio (`wa.ladder_traffic_ratio`) is lower wins, ties to
  ``standard``. Zen 4 → ``nt``; Grace/TPU → ``standard``; SPR →
  ``nt`` only while the modeled saturation gate is closed.

Execution routing mirrors ``repro.kernels`` impl routing: ``"nt"``
always runs the aligned/aliased kernel (interpret mode off-TPU — the
parity/CI path); ``"auto"`` runs it only on a real TPU and falls back
to the standard path elsewhere (the *modeled-only* fallback: plans and
traffic reports still price the selected flavor, execution uses the
XLA path that off-TPU backends compile well).

Consumers: ``models/model.py`` (prefill cache fill + decode row
updates), ``serve/engine.py`` / ``serve/planner.py`` (plans record
their flavor), ``serve/kv_traffic.py`` (flavor-priced traffic),
``kernels/tuning.py`` (tile plans carry the flavor), and
``benchmarks/fig4b_ntstore.py`` (the CI gate that the selected path's
traffic matches ``wa.priced_store_traffic``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover - non-TPU pallas builds
    pltpu = None

from repro.core import wa
from repro.kernels import interpret_mode, on_tpu

#: the public flavor vocabulary; "auto" resolves per machine
STORE_FLAVORS = ("standard", "nt", "auto")

#: selection tolerance: "nt" must beat "standard" by more than this
#: ratio margin (ties and noise go to the standard path, which needs
#: no special kernel)
_SELECT_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class StorePlan:
    """One store-path decision and the modeled ratios behind it."""

    machine: str              # registered machine name
    flavor: str               # chosen flavor: "standard" | "nt"
    wa_mode: str              # the machine's Fig. 4 behavioural mode
    ratio_standard: float     # modeled traffic ratio, standard stores
    ratio_nt: float           # modeled traffic ratio, NT stores
    saturation: float         # modeled interface saturation used, 0..1
    ws_bytes: float | None    # working set the ratios were gated on

    @property
    def ratio(self) -> float:
        """Modeled traffic ratio of the *chosen* flavor."""
        return self.ratio_nt if self.flavor == "nt" \
            else self.ratio_standard


def flavor_ratios(machine, *, ws_bytes: float | None = None,
                  cores_active: int | None = None,
                  bw_utilization: float | None = None,
                  tile_full_frac: float = 1.0) -> tuple:
    """(standard, nt) modeled traffic ratios on one machine.

    Both ratios come from the shared ladder-residue path
    (`wa.ladder_traffic_ratio`), so the selector, fig4, and fig4b can
    never disagree about what a store costs.
    """
    kw = dict(ws_bytes=ws_bytes, cores_active=cores_active,
              bw_utilization=bw_utilization,
              tile_full_frac=tile_full_frac)
    return (wa.ladder_traffic_ratio(machine, nt_stores=False, **kw),
            wa.ladder_traffic_ratio(machine, nt_stores=True, **kw))


def plan_stores(machine=None, *, flavor: str = "auto",
                ws_bytes: float | None = None,
                cores_active: int | None = None,
                bw_utilization: float | None = None) -> StorePlan:
    """Resolve the store path for one machine into a :class:`StorePlan`.

    ``flavor="auto"`` picks the cheaper modeled flavor (ties →
    ``standard``); an explicit ``"standard"``/``"nt"`` is honoured but
    the plan still records both ratios. ``ws_bytes`` gates the SpecI2M
    saturation model on the real working set (omitted → the stream is
    assumed DRAM-bound at full saturation, the Fig. 4 default);
    ``machine`` defaults to the autotuner's target
    (`repro.kernels.tuning.default_machine`).
    """
    from repro.core.machine import get_machine
    from repro.core.memtier import modeled_saturation
    if machine is None:
        from repro.kernels.tuning import default_machine
        machine = default_machine()
    m = get_machine(machine) if isinstance(machine, str) else machine
    if flavor not in STORE_FLAVORS:
        raise ValueError(f"unknown store flavor {flavor!r} "
                         f"(expected one of {STORE_FLAVORS})")
    r_std, r_nt = flavor_ratios(m, ws_bytes=ws_bytes,
                                cores_active=cores_active,
                                bw_utilization=bw_utilization)
    if flavor == "auto":
        flavor = "nt" if r_nt < r_std - _SELECT_EPS else "standard"
    sat = bw_utilization
    if sat is None:
        sat = (modeled_saturation(m, ws_bytes, cores_active)
               if ws_bytes is not None else 1.0)
    return StorePlan(machine=m.name, flavor=flavor,
                     wa_mode=wa.wa_mode_of(m),
                     ratio_standard=r_std, ratio_nt=r_nt,
                     saturation=sat, ws_bytes=ws_bytes)


def select_store_flavor(machine=None, *, ws_bytes: float | None = None,
                        cores_active: int | None = None,
                        bw_utilization: float | None = None) -> str:
    """The cheaper modeled store flavor for one machine.

    Zen 4 (``explicit_only``, DRAM residue 0) always selects ``"nt"``;
    Grace and the TPUs (``auto_claim``) always ``"standard"``; SPR
    (``saturation_gated``) selects ``"nt"`` only while the modeled
    saturation gate is closed — once SpecI2M engages, its residue
    matches the NT residue and the tie goes to ``standard``.
    """
    return plan_stores(machine, flavor="auto", ws_bytes=ws_bytes,
                       cores_active=cores_active,
                       bw_utilization=bw_utilization).flavor


def resolve_flavor(flavor: str, machine=None, *,
                   ws_bytes: float | None = None,
                   cores_active: int | None = None) -> str:
    """Validate a flavor string and resolve ``"auto"`` per machine."""
    if flavor not in STORE_FLAVORS:
        raise ValueError(f"unknown store flavor {flavor!r} "
                         f"(expected one of {STORE_FLAVORS})")
    if flavor != "auto":
        return flavor
    return select_store_flavor(machine, ws_bytes=ws_bytes,
                               cores_active=cores_active)


def executed_flavor(flavor: str, machine=None, *,
                    ws_bytes: float | None = None) -> str:
    """The flavor the *runtime* path should execute.

    An explicit ``"nt"`` always runs the NT kernel (interpret mode
    off-TPU — the parity path); ``"auto"`` runs it only when the
    selected flavor is ``nt`` AND the backend is a real TPU, degrading
    to the standard XLA path elsewhere (modeled-only fallback — the
    plans still record and price the selection).
    """
    if flavor not in STORE_FLAVORS:
        raise ValueError(f"unknown store flavor {flavor!r} "
                         f"(expected one of {STORE_FLAVORS})")
    if flavor != "auto":
        return flavor
    if not on_tpu():
        return "standard"
    return select_store_flavor(machine, ws_bytes=ws_bytes)


# --- NT KV-row writer (Pallas, cache-aliased) ------------------------------

def _kv_row_kernel(pos_ref, u_ref, c_ref, o_ref):
    """Copy one (1, 1, Hkv, Dh) update row into its aliased cache slot.

    The cache ref is untouched: with ``input_output_aliases`` the
    output *is* the cache buffer, so rows the grid never visits keep
    their bytes without a single read — the NT-store contract.
    """
    del pos_ref, c_ref
    o_ref[...] = u_ref[...]


def _kv_write_nt(cache, update, pos, *, interpret: bool):
    """Aliased Pallas row write: grid (B, Sq), rows at ``pos[b] + j``.

    The scalar-prefetched per-slot positions drive the output block
    index map, so each grid step lands exactly on the row it writes;
    ``input_output_aliases`` donates the cache into the output. Only
    ``B * Sq`` (Hkv, Dh) rows move — no whole-buffer copy and no
    read-modify-write of untouched rows.
    """
    if pltpu is None:  # pragma: no cover - non-TPU pallas builds
        raise RuntimeError("pallas TPU frontend unavailable")
    b, _, hkv, dh = cache.shape
    sq = update.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    spec = pl.BlockSpec((1, 1, hkv, dh),
                        lambda i, j, pos_ref: (i, pos_ref[i] + j, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, sq),
        in_specs=[
            pl.BlockSpec((1, 1, hkv, dh),
                         lambda i, j, pos_ref: (i, j, 0, 0)),
            spec,
        ],
        out_specs=spec,
    )
    return pl.pallas_call(
        _kv_row_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},   # cache (after pos, update) -> out
        interpret=interpret)(pos, update.astype(cache.dtype), cache)


def kv_row_update(cache, update, pos, *, flavor: str = "standard",
                  machine=None):
    """Write ``update`` rows into a KV ``cache`` at per-slot positions.

    ``cache`` is (B, S, Hkv, Dh); ``update`` is (B, Sq, Hkv, Dh) and
    ``pos`` a scalar or (B,) int32 — row ``b`` lands at
    ``cache[b, pos[b]:pos[b]+Sq]``. This is the single door every KV
    writer goes through (decode in-place row updates in
    ``models/model.py``); the flavor picks the store path:

    * ``"standard"`` — the vmapped ``dynamic_update_slice`` XLA path
      (in place under jit donation), byte-identical to the historical
      serve path.
    * ``"nt"`` — the cache-aliased Pallas row writer (interpret mode
      off-TPU).
    * ``"auto"`` — the machine-selected flavor, NT kernel only on a
      real TPU (see :func:`executed_flavor`).
    """
    run = executed_flavor(flavor, machine,
                          ws_bytes=float(cache.size * cache.dtype.itemsize))
    if run == "nt":
        return _kv_write_nt(cache, update, pos,
                            interpret=interpret_mode())
    upd = update.astype(cache.dtype)
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, upd, pos, axis=1)
    row_dus = jax.vmap(
        lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, axis=0))
    return row_dus(cache, upd, pos)


def pad_to_horizon(x, cache_len: int, *, flavor: str = "standard",
                   machine=None):
    """Grow a prefill KV leaf (B, S, Hkv, Dh) to the decode horizon.

    The prefill cache fill is itself a store subject: the whole
    ``cache_len`` buffer is written once. ``"standard"`` keeps the
    historical ``jnp.pad``; ``"nt"`` builds the horizon buffer as an
    explicit full-overwrite — a zero fill plus an offset-0 (tile-
    aligned by construction) dynamic-update-slice, the donation-
    friendly lowering whose stores the WA scan classifies as full-tile.
    Both produce identical bytes; off-TPU ``"auto"`` stays standard.
    """
    b, s, hkv, dh = x.shape
    if cache_len <= s:
        return x
    run = executed_flavor(flavor, machine,
                          ws_bytes=float(b * cache_len * hkv * dh
                                         * x.dtype.itemsize))
    if run == "nt":
        buf = jnp.zeros((b, cache_len, hkv, dh), x.dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, x, 0, axis=1)
    return jnp.pad(x, [(0, 0), (0, cache_len - s), (0, 0), (0, 0)])
