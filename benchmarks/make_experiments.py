"""Regenerate the data-driven tables in EXPERIMENTS.md from results/.

Replaces the blocks between <!--GEN:<name>--> ... <!--END:<name>--> markers.
Run after the dry-run sweep / fig3 / perf iterations:
  PYTHONPATH=src:. python -m benchmarks.make_experiments
"""

from __future__ import annotations

import glob
import json
import os
import re

DRYRUN_DIR = "results/dryrun_final" \
    if os.path.isdir("results/dryrun_final") and \
    glob.glob("results/dryrun_final/*.json") else "results/dryrun"


def gen_dryrun() -> str:
    rows = ["| arch | shape | mesh | peak GB/dev | args+out GB/dev | "
            "flops/dev (model) | compile s |",
            "|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(f"{DRYRUN_DIR}/*.json")):
        r = json.load(open(path))
        m = r["memory"]
        steady = (m["argument_bytes"] + m["output_bytes"] -
                  m["alias_bytes"]) / 1e9
        pm = r.get("portmodel", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {m['peak_bytes']/1e9:.2f} | {steady:.2f} "
            f"| {pm.get('flops', 0):.3e} | {r['compile_s']:.0f} |")
    return "\n".join(rows)


def gen_roofline() -> str:
    import sys
    sys.path.insert(0, ".")
    from benchmarks.roofline_sweep import load_cells
    cells = load_cells(f"{DRYRUN_DIR}/*.json")
    rows = ["| arch | shape | mesh | T_comp | T_comp(port) | T_mem | T_coll "
            "| dominant | MF/HLO | peak-frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    lever = {
        "memory": "flash-attn kernel / fusion (see §Perf H1)",
        "compute(port)": "MXU utilization (bigger per-chip batch)",
        "collective": "resident-2D serve / compressed grads (§Perf H2)",
    }
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.mesh)):
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.t_compute*1e3:.1f}ms "
            f"| {c.t_compute_port*1e3:.1f}ms | {c.t_memory*1e3:.1f}ms "
            f"| {c.t_collective*1e3:.1f}ms | {c.dominant} "
            f"| {c.useful_ratio:.2f} | {c.peak_fraction:.1%} "
            f"| {lever.get(c.dominant, '-')} |")
    return "\n".join(rows)


def gen_fig3() -> str:
    path = "results/rpe_records.json"
    if not os.path.exists(path):
        return "(fig3 records not yet generated)"
    import sys
    sys.path.insert(0, "src")
    from repro.core import rpe
    recs = rpe.load_records(path)
    s = rpe.summarize(recs)
    out = []
    for model in ("port_model", "mca_sched", "naive_baseline"):
        st = s[model]
        if not st:
            out.append(f"- **{model}**: (no finite records)")
            continue
        out.append(f"- **{model}**: n={st['n']}, "
                   f"right-of-zero {st['right_of_zero_pct']:.0f}%, "
                   f"within +10% {st['within10_pct']:.0f}%, "
                   f"within +20% {st['within20_pct']:.0f}%, "
                   f">2x off {st['factor2_off']}, "
                   f"mean under-prediction RPE "
                   f"{st['mean_underpred_rpe']:.2f}")
    h = rpe.histogram(recs, "port")
    out.append("- port-model histogram: " +
               " ".join(f"{k}:{v}" for k, v in h.items()))
    h2 = rpe.histogram(recs, "naive")
    out.append("- naive-baseline histogram: " +
               " ".join(f"{k}:{v}" for k, v in h2.items()))
    return "\n".join(out)


def gen_perf() -> str:
    rows = ["| iteration | T_comp | T_mem | T_coll | peak GB/dev |",
            "|---|---|---|---|---|"]
    for path in sorted(glob.glob("results/perf/H*.json")):
        r = json.load(open(path))
        t = r.get("_terms")
        if not t:
            continue
        tag = os.path.basename(path)[:-5]
        rows.append(f"| {tag} | {t['T_comp_s']:.2f}s | {t['T_mem_s']:.2f}s "
                    f"| {t['T_coll_s']:.3f}s | {t['peak_gb']:.2f} |")
    return "\n".join(rows)


def main():
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    for name, gen in (("dryrun", gen_dryrun), ("roofline", gen_roofline),
                      ("fig3", gen_fig3), ("perf", gen_perf)):
        pat = re.compile(rf"(<!--GEN:{name}-->).*?(<!--END:{name}-->)",
                         re.S)
        if pat.search(doc):
            doc = pat.sub(lambda m, g=gen: m.group(1) + "\n" + g() + "\n" +
                          m.group(2), doc)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md tables regenerated from", DRYRUN_DIR)


if __name__ == "__main__":
    main()
