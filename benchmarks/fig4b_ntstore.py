"""Paper Fig. 4 (b): NT-store evasion — per-machine store-traffic ratio
of the *selected* store flavor vs the standard path, gated in CI.

For each of the paper's three machines (plus the TPU) the benchmark

1. asks the store-path selector (``repro.kernels.stores``) which
   flavor it picks for a DRAM-resident store stream,
2. prices both flavors through the shared ladder-residue path
   (``wa.ladder_traffic_ratio`` — the same arithmetic fig4 plots and
   ``wa.priced_store_traffic(flavor=...)`` uses), and
3. derives an interpret-mode traffic ratio for the NT stream kernel:
   the padded-tile store footprint of ``stream_triad_nt`` over its
   payload (every NT store is full-tile by construction, so the
   *kernel-side* ratio is the tile padding overhead — the machine-side
   allocate traffic on top of it is exactly what the model prices).

The gate (also asserted when run, so CI fails loudly):

* ordering Grace <= SPR <= Zen4-with-NT within the SpecI2M NT-residue
  tolerance (0.15): Grace 1.0, SPR 1.1, Zen4-NT 1.0,
* standard-flavor ordering strict: Grace 1.0 <= SPR <= 2.0 == Zen4,
* Zen4's NT path ~1.0 vs ~2.0 standard (the paper's headline delta),
* the selected-flavor ratio equals ``wa.priced_store_traffic`` on a
  full-tile store profile of the same payload within 1e-6 — the
  measured/modeled agreement the tentpole promises.

A measured host row (standard vs NT-shaped store lowering) rides along
like fig4's host experiment; it is reported, not gated — wall-clock on
a shared CI host is noise, the *traffic* model is the contract.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import wa
from repro.core.machine import get_machine
from repro.kernels.stores import plan_stores

#: registered machine name -> paper Fig. 4 curve label
_CURVES = (("neoverse_v2", "grace"), ("golden_cove", "spr"),
           ("zen4", "genoa"), ("tpu_v5e", "tpu"))

#: ordering tolerance: the SpecI2M NT residue (golden_cove DRAM-tier
#: ``wa_residue`` = 0.1, plus headroom) — SPR's best path keeps ~10%
#: allocate traffic that Grace and Zen4-with-NT fully evade
ORDER_TOL = 0.15

#: modeled-vs-priced agreement tolerance for the selected flavor
PRICE_TOL = 1e-6

N_ROWS, N_COLS = 1 << 8, 1 << 12      # 4 MiB f32 stream payload


def _kernel_tile_ratio(shape=(20, 300)) -> float:
    """Interpret-derived store-footprint ratio of the NT stream kernel.

    ``stream_triad_nt`` pads a deliberately tile-misaligned shape up to
    the native (8, 128) granule and stores only full tiles; the ratio
    of bytes stored (padded grid) to payload bytes is the kernel-side
    cost of guaranteeing allocate-free stores.
    """
    from repro.kernels.stream.kernels import _nt_grid2
    m, n = shape
    _, _, _, mp, npad = _nt_grid2(shape, jnp.float32)
    # run the kernel once in interpret mode so the path is exercised,
    # not just priced
    from repro.kernels.stream import kernels as K
    from repro.kernels.stream import ref as R
    b = jnp.ones(shape, jnp.float32)
    c = jnp.ones(shape, jnp.float32)
    out = K.stream_triad_nt(b, c, interpret=True)
    assert jnp.allclose(out, R.stream_triad(b, c)), "NT triad parity"
    return (mp * npad) / float(m * n)


def main(quick: bool = False):
    lines = []
    big = float(N_ROWS * N_COLS * 4) * 256   # clearly DRAM-resident
    ratios = {}
    for name, label in _CURVES:
        plan = plan_stores(name, ws_bytes=big)
        ratios[label] = plan
        lines.append(
            f"fig4b,{label},0,flavor={plan.flavor};"
            f"ratio={plan.ratio:.3f};std={plan.ratio_standard:.3f};"
            f"nt={plan.ratio_nt:.3f};sat={plan.saturation:.2f};"
            f"wa_mode={plan.wa_mode}")

        # the tentpole contract: the selected flavor's ratio must match
        # wa.priced_store_traffic on the same payload
        payload = float(N_ROWS * N_COLS * 4)
        prof = wa.store_profile((N_ROWS, N_COLS), "f32")
        priced = wa.priced_store_traffic(prof, get_machine(name),
                                         ws_bytes=big,
                                         flavor=plan.flavor)
        modeled = payload * plan.ratio
        assert abs(priced - modeled) <= PRICE_TOL * max(modeled, 1.0), (
            f"{name}: priced {priced} != modeled {modeled}")
        lines.append(f"fig4b,{label}_priced,0,"
                     f"priced_bytes={priced:.0f};"
                     f"modeled_bytes={modeled:.0f}")

    grace, spr, zen = ratios["grace"], ratios["spr"], ratios["genoa"]
    # selected-flavor ordering (paper Fig. 4): Grace <= SPR <= Zen4+NT
    # within the SpecI2M residue tolerance
    assert grace.ratio <= spr.ratio + ORDER_TOL, (grace, spr)
    assert spr.ratio <= zen.ratio + ORDER_TOL, (spr, zen)
    # standard-flavor ordering is strict
    assert grace.ratio_standard <= spr.ratio_standard <= \
        zen.ratio_standard, (grace, spr, zen)
    # Zen4 headline delta: NT ~1.0 vs standard ~2.0
    assert abs(zen.ratio_nt - 1.0) < 0.05, zen
    assert abs(zen.ratio_standard - 2.0) < 0.05, zen
    assert zen.flavor == "nt" and grace.flavor == "standard", (zen, grace)
    lines.append("fig4b,gate,0,ordering=ok;zen4_nt="
                 f"{zen.ratio_nt:.2f};zen4_std={zen.ratio_standard:.2f};"
                 f"tol={ORDER_TOL}")

    # interpret-derived kernel-side footprint of the NT path
    tile_ratio = _kernel_tile_ratio()
    lines.append(f"fig4b,nt_kernel_tile_footprint,0,"
                 f"padded_over_payload={tile_ratio:.3f}")

    # --- measured host: standard store lowering vs the NT-shaped one
    # (zero-fill + offset-0 full-tile update, the donation-friendly
    # lowering pad_to_horizon uses) — reported, not gated ---
    x = jnp.ones((N_ROWS, N_COLS), jnp.float32)
    std = jax.jit(lambda a: jnp.pad(a, [(0, N_ROWS), (0, 0)]))
    nt = jax.jit(lambda a: jax.lax.dynamic_update_slice(
        jnp.zeros((2 * N_ROWS, N_COLS), jnp.float32), a, (0, 0)))
    for fn, tag in ((std, "host_standard_pad"), (nt, "host_nt_fill")):
        jax.block_until_ready(fn(x))
        best = float("inf")
        for _ in range(3 if quick else 7):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        gb = 2 * N_ROWS * N_COLS * 4 / best / 1e9
        lines.append(f"fig4b,{tag},{best*1e6:.1f},bw={gb:.2f}GB/s")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
