"""Paper Fig. 3: RPE histograms — our port/ECM model vs the naive
cost_analysis baseline (the LLVM-MCA stand-in) over the validation suite.

Default (quick): 13 kernels x 2 variants x 2 sizes = 52 blocks.
--full: 13 x 8 x 4 = 416 blocks (the paper's count). Results are cached
to results/rpe_records.json so reruns are incremental.
"""

from __future__ import annotations

import math
import os

from repro.core import rpe

CACHE = "results/rpe_records.json"


def run(full: bool = False, cache: str = CACHE):
    variants = rpe.VARIANTS if full else ("jnp", "fori")
    sizes = tuple(rpe.SIZES) if full else ("S", "L")
    done = {}
    if os.path.exists(cache):
        # Only finite records count as done: failure sentinels (NaN /
        # null t_meas) are retried instead of pinning the cache to a
        # bad environment forever.
        for r in rpe.load_records(cache):
            if math.isfinite(r.t_meas):
                done[(r.kernel, r.variant, r.size)] = r
    records = []
    changed = False
    from repro.kernels.stream.ref import KERNELS_13
    for k in KERNELS_13:
        for v in variants:
            for s in sizes:
                kk = (k, v, s)
                if kk in done:
                    records.append(done[kk])
                    continue
                try:
                    r = rpe.run_block(k, v, s)
                except Exception:  # noqa: BLE001 — suite must finish
                    r = rpe.RpeRecord(k, v, s, float("nan"),
                                      float("nan"), float("nan"))
                records.append(r)
                if math.isfinite(r.t_meas):
                    done[kk] = r
                    changed = True
    if changed:
        # Persist every successful block ever measured (done spans
        # quick and --full sweeps), never the failure sentinels.
        rpe.save_records(sorted(done.values(), key=lambda r: (
            r.kernel, r.variant, r.size)), cache)
    return records


def main(quick: bool = True):
    records = run(full=not quick)
    s = rpe.summarize(records)
    lines = []
    for model in ("port_model", "naive_baseline"):
        st = s[model]
        if not st:          # every block failed — degrade, don't crash
            lines.append(f"fig3,{model},0,no_finite_records")
            continue
        lines.append(
            f"fig3,{model},0,"
            f"n={st['n']};right_of_zero={st['right_of_zero_pct']:.0f}%;"
            f"within10={st['within10_pct']:.0f}%;"
            f"within20={st['within20_pct']:.0f}%;"
            f"factor2_off={st['factor2_off']};"
            f"mean_underpred={st['mean_underpred_rpe']:.2f}")
    h = rpe.histogram(records, "port")
    lines.append("fig3,hist_port,0," +
                 ";".join(f"{k}:{v}" for k, v in h.items()))
    h2 = rpe.histogram(records, "naive")
    lines.append("fig3,hist_naive,0," +
                 ";".join(f"{k}:{v}" for k, v in h2.items()))
    return lines


if __name__ == "__main__":
    import sys
    print("\n".join(main(quick="--full" not in sys.argv)))
