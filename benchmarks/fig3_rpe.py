"""Paper Fig. 3: RPE histograms — our port/ECM model vs the naive
cost_analysis baseline (the LLVM-MCA stand-in) over the validation suite.

Default (quick): 13 kernels x 2 variants x 2 sizes = 52 blocks.
--full: 13 x 8 x 4 = 416 blocks (the paper's count). Results are cached
to results/rpe_records.json so reruns are incremental.
"""

from __future__ import annotations

import json
import os

from repro.core import rpe

CACHE = "results/rpe_records.json"


def run(full: bool = False, cache: str = CACHE):
    variants = rpe.VARIANTS if full else ("jnp", "fori")
    sizes = tuple(rpe.SIZES) if full else ("S", "L")
    done = {}
    if os.path.exists(cache):
        with open(cache) as f:
            for d in json.load(f):
                done[(d["kernel"], d["variant"], d["size"])] = d
    records = []
    changed = False
    from repro.kernels.stream.ref import KERNELS_13
    for k in KERNELS_13:
        for v in variants:
            for s in sizes:
                kk = (k, v, s)
                if kk in done:
                    d = done[kk]
                    records.append(rpe.RpeRecord(**d))
                    continue
                try:
                    r = rpe.run_block(k, v, s)
                except Exception:  # noqa: BLE001 — suite must finish
                    r = rpe.RpeRecord(k, v, s, float("nan"),
                                      float("nan"), float("nan"))
                records.append(r)
                done[kk] = r.__dict__
                changed = True
    if changed:
        os.makedirs(os.path.dirname(cache), exist_ok=True)
        with open(cache, "w") as f:
            json.dump([d if isinstance(d, dict) else d for d in
                       (x.__dict__ for x in records)], f, indent=1)
    return records


def main(quick: bool = True):
    records = run(full=not quick)
    s = rpe.summarize(records)
    lines = []
    for model in ("port_model", "naive_baseline"):
        st = s[model]
        lines.append(
            f"fig3,{model},0,"
            f"n={st['n']};right_of_zero={st['right_of_zero_pct']:.0f}%;"
            f"within10={st['within10_pct']:.0f}%;"
            f"within20={st['within20_pct']:.0f}%;"
            f"factor2_off={st['factor2_off']};"
            f"mean_underpred={st['mean_underpred_rpe']:.2f}")
    h = rpe.histogram(records, "port")
    lines.append("fig3,hist_port,0," +
                 ";".join(f"{k}:{v}" for k, v in h.items()))
    h2 = rpe.histogram(records, "naive")
    lines.append("fig3,hist_naive,0," +
                 ";".join(f"{k}:{v}" for k, v in h2.items()))
    return lines


if __name__ == "__main__":
    import sys
    print("\n".join(main(quick="--full" not in sys.argv)))
