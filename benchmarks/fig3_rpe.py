"""Paper Fig. 3: RPE histograms — both in-core prediction engines
(analytical ``tp_bound`` port model and the ``mca_sched`` cycle
simulator, the repro's OSACA-vs-LLVM-MCA comparison) vs the naive
cost_analysis baseline over the validation suite.

Default (quick): 13 kernels x 2 variants x 2 sizes = 52 blocks.
--full: 13 x 8 x 4 = 416 blocks (the paper's count). Results are cached
to results/rpe_records.json so reruns are incremental; records written
before the backend split lack the simulator prediction and are re-run
once to backfill it.
"""

from __future__ import annotations

import math
import os

from repro.core import rpe

CACHE = "results/rpe_records.json"


def _complete(r) -> bool:
    """A cache entry counts as done only when fully populated: finite
    measurement AND a finite simulator prediction (legacy pre-backend
    records carry NaN ``t_mca`` and are re-run to backfill)."""
    return math.isfinite(r.t_meas) and math.isfinite(r.t_mca)


def run(full: bool = False, cache: str = CACHE):
    """Run (or resume) the Fig. 3 grid; returns the record list."""
    variants = rpe.VARIANTS if full else ("jnp", "fori")
    sizes = tuple(rpe.SIZES) if full else ("S", "L")
    done = {}
    keep = {}       # every finite measurement ever — what gets persisted
    if os.path.exists(cache):
        # Only complete records count as done: failure sentinels (NaN /
        # null t_meas) are retried instead of pinning the cache to a
        # bad environment forever. Legacy records (finite t_meas, no
        # t_mca) are re-run to backfill the simulator prediction but
        # stay in `keep` so a failed backfill cannot delete a
        # previously measured block from the cache.
        for r in rpe.load_records(cache):
            if math.isfinite(r.t_meas):
                keep[(r.kernel, r.variant, r.size)] = r
                if _complete(r):
                    done[(r.kernel, r.variant, r.size)] = r
    records = []
    changed = False
    from repro.kernels.stream.ref import KERNELS_13
    for k in KERNELS_13:
        for v in variants:
            for s in sizes:
                kk = (k, v, s)
                if kk in done:
                    records.append(done[kk])
                    continue
                try:
                    r = rpe.run_block(k, v, s)
                except Exception:  # noqa: BLE001 — suite must finish
                    nan = float("nan")
                    r = rpe.RpeRecord(k, v, s, nan, nan, nan)
                if _complete(r):
                    records.append(r)
                    done[kk] = r
                    keep[kk] = r
                    changed = True
                else:
                    # failed (back)fill: fall back to the legacy record
                    # if one exists — its finite measurement still
                    # feeds the port/naive summaries
                    records.append(keep.get(kk, r))
    if changed:
        # Persist every successful block ever measured (keep spans
        # quick and --full sweeps), never the failure sentinels.
        rpe.save_records(sorted(keep.values(), key=lambda r: (
            r.kernel, r.variant, r.size)), cache)
    return records


def main(quick: bool = True):
    """Emit the fig3 CSV lines: per-backend summaries + histograms."""
    records = run(full=not quick)
    s = rpe.summarize(records)
    lines = []
    for model in ("port_model", "mca_sched", "naive_baseline"):
        st = s[model]
        if not st:          # every block failed — degrade, don't crash
            lines.append(f"fig3,{model},0,no_finite_records")
            continue
        lines.append(
            f"fig3,{model},0,"
            f"n={st['n']};right_of_zero={st['right_of_zero_pct']:.0f}%;"
            f"within10={st['within10_pct']:.0f}%;"
            f"within20={st['within20_pct']:.0f}%;"
            f"factor2_off={st['factor2_off']};"
            f"mean_rpe={st['mean_rpe']:.2f};"
            f"mean_underpred={st['mean_underpred_rpe']:.2f}")
    for which in ("port", "mca", "naive"):
        h = rpe.histogram(records, which)
        lines.append(f"fig3,hist_{which},0," +
                     ";".join(f"{k}:{v}" for k, v in h.items()))
    return lines


if __name__ == "__main__":
    import sys
    print("\n".join(main(quick="--full" not in sys.argv)))
