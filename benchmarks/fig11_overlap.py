"""Fig. 11: overlapped serving runtime — double-buffered decode dispatch
vs serial rounds, plus the offline plan database.

Three measured claims, one per section of the overlapped runtime
(repro.serve.engine pipeline mode, repro.serve.staging,
repro.serve.plandb):

1. **Dispatch overlap** — with ``pipeline=2`` the engine enqueues round
   N+1 while round N is still executing, so the host gap between
   consecutive decode-dispatch *enqueues* shrinks and wall-clock
   tokens/s rises. Gated on the container host for the dense engine
   (gap reduction > 1 and tokens/s >= serial by the median of paired
   interleaved repeats — robust to shared-host load noise);
   the paged engine is gated leniently (its per-round host work —
   block-table assembly — is a larger fraction of the gap). Token
   streams must be byte-identical between modes: the overlap is a
   scheduling change, never a numerics change.

2. **Priced per-machine prediction** — pipelined mode cannot donate the
   KV cache (a donated still-pending input blocks the enqueue, the
   exact stall the mode exists to remove), so it pays the
   copy-first cache update. That copy's WA-priced store traffic
   (repro.serve.kv_traffic.kv_update_traffic, ``delta_bytes``) is the
   per-machine *cost* of overlap, and must keep the paper's
   store-traffic ordering: Grace <= SPR <= Zen 4 — Grace's auto-claim
   writes spill least, Zen 4's explicit-only WA pays full allocate
   traffic.

3. **Plan database** — an offline sweep (both planner backends)
   persisted and reinstalled must make admission planning O(1): after a
   sweep covering the serving point, planning for every registered
   machine is a DB hit with *zero* online plans (pinned by the planner
   stats counters) and the returned plan is bit-identical to the online
   planner's. The tp_bound-vs-mca_sched disagreement count is reported.

Like fig6/fig9, the host wall-clock numbers are a smoke anchor — this
container is not a Grace/SPR/Genoa socket — while the priced rows carry
the cross-vendor prediction.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request, ServeEngine
from repro.serve.kv_traffic import kv_update_traffic

ARCH = "yi-9b"                    # GQA: distinct n_heads / n_kv_heads
SLOTS, CHUNK, GEN, PROMPT = 16, 8, 96, 12
ORDER = ("neoverse_v2", "golden_cove", "zen4")   # Grace, SPR, Genoa


def _requests(cfg, seed: int) -> list:
    """One full batch of seeded random-prompt requests."""
    rng = np.random.default_rng(seed)
    return [Request(rid=f"r{i}",
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, PROMPT)),
                    max_new_tokens=GEN)
            for i in range(SLOTS)]


def _run_once(eng, cfg, seed: int):
    """One timed serve of a full batch; returns (wall_s, gap_s, results).

    Gap counters are reset per run so each repeat measures its own mean
    enqueue-to-enqueue gap (the engine accumulates across its life).
    """
    eng.dispatch_gap_s, eng.gap_rounds = 0.0, 0
    eng._t_enqueued = None
    reqs = _requests(cfg, seed)
    t0 = time.perf_counter()
    results = eng.run(reqs)
    wall = time.perf_counter() - t0
    gap = eng.stats()["mean_dispatch_gap_s"]
    return wall, gap, results


def _measure_pair(engs: dict, cfg, repeats: int, seed: int) -> dict:
    """Warmup both engines, then best-of-``repeats`` with the modes
    *interleaved* (serial, pipelined, serial, ...) so slow host-load
    drift hits both equally — back-to-back blocks let a load spike
    land entirely on one mode and flip the relative gate on noise.
    Returns {mode: (min wall, median gap, results)} — the gap uses the
    median across repeats because a min lets one lucky serial run
    erase a stable ~15% reduction."""
    for eng in engs.values():                       # compile + warm caches
        _run_once(eng, cfg, seed)
    walls = {m: [] for m in engs}
    gaps = {m: [] for m in engs}
    results = {}
    for _ in range(repeats):
        for mode, eng in engs.items():
            w, g, results[mode] = _run_once(eng, cfg, seed)
            walls[mode].append(w)
            gaps[mode].append(g)
    out = {m: (min(walls[m]), sorted(gaps[m])[repeats // 2], results[m])
           for m in engs}
    out["pair_speedups"] = sorted(
        ws / wp for ws, wp in zip(walls["serial"], walls["pipelined"]))
    return out


def _stream_key(results: dict) -> tuple:
    return tuple((rid, tuple(int(t) for t in results[rid]))
                 for rid in sorted(results))


def _overlap_rows(cfg, params, repeats: int) -> list:
    """Serial vs pipelined on dense + paged engines; gates inside."""
    lines = []
    for kind, mk in (("dense", lambda **kw: ServeEngine(cfg, params, **kw)),
                     ("paged", lambda **kw: PagedServeEngine(
                         cfg, params, page_size=8, **kw))):
        engs = {mode: mk(max_slots=SLOTS, max_len=PROMPT + GEN,
                         chunk=CHUNK, pipeline=pipeline)
                for mode, pipeline in (("serial", 0), ("pipelined", 2))}
        runs = _measure_pair(engs, cfg, repeats, seed=7)
        if kind == "dense" and runs["pair_speedups"][repeats // 2] < 1.0:
            # a transient load storm can bury the (few-percent) win in
            # one measurement block; one independent re-measure with
            # doubled pairs must confirm before the gate fails
            runs = _measure_pair(engs, cfg, 2 * repeats, seed=7)
        (w_s, g_s, r_s), (w_p, g_p, r_p) = runs["serial"], runs["pipelined"]
        pairs = runs["pair_speedups"]
        assert _stream_key(r_s) == _stream_key(r_p), \
            f"{kind}: pipelined token streams diverged from serial"
        tok_s, tok_p = SLOTS * GEN / w_s, SLOTS * GEN / w_p
        gap_red = g_s / max(g_p, 1e-12)
        # the tokens/s gate uses the MEDIAN of the paired per-repeat
        # ratios: adjacent-in-time pairs cancel common-mode host load,
        # and the median tolerates a minority of polluted pairs — the
        # best-of mins (reported below) still flip the comparison on a
        # single lucky serial repeat on a noisy shared host
        speedup = pairs[len(pairs) // 2]
        lines.append(
            f"fig11,overlap.{kind},{w_p*1e6:.0f},"
            f"slots={SLOTS};chunk={CHUNK};gen={GEN};repeats={repeats};"
            f"tok_s_serial={tok_s:.1f};tok_s_pipelined={tok_p:.1f};"
            f"speedup_median_paired={speedup:.3f};"
            f"gap_serial_ms={g_s*1e3:.3f};"
            f"gap_pipelined_ms={g_p*1e3:.3f};gap_reduction={gap_red:.2f};"
            f"streams=IDENTICAL")
        if kind == "dense":
            assert gap_red > 1.0, \
                f"dense: no dispatch-gap reduction ({gap_red:.2f}x)"
            assert speedup >= 1.0, \
                f"dense: pipelined slower (median paired {speedup:.3f}x, " \
                f"pairs {[round(p, 3) for p in pairs]})"
        else:
            # paged per-round host work (block-table assembly) dilutes
            # the overlap win; gate leniently, report honestly
            assert speedup >= 0.9, \
                f"paged: pipelined regressed badly ({speedup:.3f}x)"
    return lines


def _priced_rows(cfg) -> list:
    """The per-machine priced copy cost of overlap, ordering-gated."""
    rows = {r["machine"]: r for r in kv_update_traffic(
        cfg, SLOTS, PROMPT + GEN, flavor="auto", machines=ORDER)}
    tri = [rows[m]["delta_bytes"] for m in ORDER]
    ok = tri[0] <= tri[1] <= tri[2]
    line = (
        "fig11,priced_copy_cost,0,"
        + ";".join(f"{m}={rows[m]['delta_bytes']:.0f}"
                   f"({rows[m]['wa_mode']})" for m in ORDER)
        + f";grace_le_spr_le_zen4={'OK' if ok else 'VIOLATED'}")
    if not ok:
        raise AssertionError(
            f"overlap copy-cost WA ordering violated: {tri}")
    return [line]


def _plandb_rows(cfg) -> list:
    """Sweep -> install -> every-machine plan is a DB hit, zero online."""
    from repro.core.machine import registered_names
    from repro.serve import plandb
    from repro.serve.planner import (plan_chunk_size, plan_stats,
                                     reset_plan_stats)
    t0 = time.perf_counter()
    db = plandb.sweep(cfg, batches=(SLOTS,), max_lens=(PROMPT + GEN,),
                      tps=(1,))
    sweep_s = time.perf_counter() - t0
    machines = registered_names()
    # online reference plans (DB not installed yet)
    ref = {m: plan_chunk_size(cfg, SLOTS, PROMPT + GEN, machine=m)
           for m in machines}
    prev = plandb.installed()
    try:
        plandb.install(db)
        reset_plan_stats()
        t0 = time.perf_counter()
        hits = {m: plan_chunk_size(cfg, SLOTS, PROMPT + GEN, machine=m)
                for m in machines}
        lookup_s = time.perf_counter() - t0
        stats = plan_stats()
    finally:
        plandb.install(prev)
    assert stats["online_plans"] == 0, \
        f"plan DB hit still planned online: {stats}"
    assert stats["db_hits"] == len(machines), f"missed DB hits: {stats}"
    for m in machines:
        assert hits[m] == ref[m], \
            f"{m}: DB plan differs from online plan"
    dis = plandb.backend_disagreements(db)
    return [
        f"fig11,plandb,{lookup_s*1e6:.0f},"
        f"entries={len(db)};machines={len(machines)};"
        f"sweep_ms={sweep_s*1e3:.0f};lookup_us={lookup_s*1e6:.0f};"
        f"online_plans={stats['online_plans']};db_hits={stats['db_hits']};"
        f"bit_identical=OK;backend_disagreements={len(dis)}"]


def main(quick: bool = False) -> list:
    """Emit the fig11 overlap table as benchmark CSV lines."""
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    repeats = 9 if quick else 15
    lines = _overlap_rows(cfg, params, repeats)
    lines.extend(_priced_rows(cfg))
    lines.extend(_plandb_rows(cfg))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer timed repeats (CI overlap-smoke job)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.smoke)))
