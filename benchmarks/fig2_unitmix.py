"""Paper Fig. 2 adapted: sustained throughput vs execution-unit mix.

TPUs do not throttle clocks by ISA width (the paper's Fig. 2 phenomenon is
x86-specific — DESIGN.md §2), so the TPU-relevant question becomes: how
much does co-issuing other unit classes degrade each unit's sustained
rate? We measure the host's matmul-only / vector-only / transcendental-
only rates and then the 1:1 mixes; the "sustained fraction" column is the
analogue of the paper's sustained-frequency fraction (e.g. SPR AVX-512 at
53% of turbo).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

N = 1 << 16
MAT = 384


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _chain(op, k=64):
    def f(*args):
        def body(_, x):
            return op(x, *args[1:])
        return jax.lax.fori_loop(0, k, body, args[0])
    return jax.jit(f), k


def main(quick: bool = False):
    key = jax.random.PRNGKey(0)
    x = jnp.abs(jax.random.normal(key, (N,), jnp.float32)) + 0.5
    m = jax.random.normal(key, (MAT, MAT), jnp.float32) * 0.02

    mm, k1 = _chain(lambda a, w: a @ w, 16)
    vec, k2 = _chain(lambda v, c: v * 0.999 + c, 64)
    xlu, k3 = _chain(lambda v: jnp.exp(-v), 64)

    def mixed_op(a, w, v):
        return a @ w, v * 0.999 + 0.5

    def mixed(k=16):
        def f(a, w, v):
            def body(_, c):
                aa, vv = c
                return (aa @ w, vv * 0.999 + 0.5)
            return jax.lax.fori_loop(0, k, body, (a, v))
        return jax.jit(f), k

    mixfn, k4 = mixed()

    t_mm = _time(mm, m, m) / k1
    t_vec = _time(vec, x, x * 0.5) / k2
    t_xlu = _time(xlu, x) / k3
    t_mix = _time(mixfn, m, m, x) / k4

    # sustained fraction: mixed time vs sum-of-parts ideal (perfect overlap
    # = max(parts); no overlap = sum(parts))
    ideal = max(t_mm, t_vec)
    serial = t_mm + t_vec
    frac = (serial - t_mix) / max(serial - ideal, 1e-12)  # 1 = full overlap
    lines = [
        f"fig2,matmul_only,{t_mm*1e6:.1f},gflops={2*MAT**3/t_mm/1e9:.1f}",
        f"fig2,vector_only,{t_vec*1e6:.1f},gelem={N/t_vec/1e9:.2f}",
        f"fig2,xlu_only,{t_xlu*1e6:.1f},gelem={N/t_xlu/1e9:.2f}",
        f"fig2,mixed_mm_vec,{t_mix*1e6:.1f},overlap_frac={frac:.2f}",
        "fig2,tpu_note,0,TPU clocks are fixed; paper Fig.2 freq-vs-ISA "
        "has no TPU analogue (DESIGN.md)",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
