"""Fig. 9: traffic-scale serving — replica router under seeded arrival
traces, measured tail latency and tokens/s/chip vs the planner.

A closed-loop generator replays a *seeded* arrival trace (Poisson or
bursty, mixed prompt/gen lengths) against a :class:`ReplicaRouter`
over N engine replicas, each sharded over the host-device-count mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=K`` fakes K chips
on CPU; with one device the mesh is (1, 1) and the engines take the
bit-exact single-device path). Arrivals are indexed in router rounds —
deterministic under a seed — while latencies are measured on the wall
clock: a request's latency spans from the round it became due (queue
wait included, backpressure deferrals included) to the round it
retired.

Reported per trace: p50/p95/p99 latency, measured tokens/s/chip, and
the planner's predicted tokens/s/chip on the plan machine — the same
predicted-vs-measured pairing as fig6, and like fig6 the host
measurement is a smoke anchor for the cross-vendor predictions, not a
validation (this container is not a Grace/SPR/Genoa socket). What *is*
gated here: percentile ordering, token conservation across the router,
and the sharded pricing invariants — the per-shard KV stream shrinks
with TP degree and the per-step collective's WA-priced bytes keep the
Grace <= SPR <= Zen 4 store-traffic ordering.
"""

from __future__ import annotations

import math
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import QueueFull, ReplicaRouter, Request, ServeEngine
from repro.serve.kv_traffic import collective_traffic, kv_row_bytes
from repro.utils.sharding import mesh_axis_sizes, tp_degree

ARCH = "gemma3-4b"           # local+global attention: both cache kinds
SLOTS, MAX_LEN = 2, 48


def make_trace(kind: str, n: int, seed: int, *, mean_gap_rounds: float = 1.5,
               burst: int = 4) -> list:
    """Seeded arrival trace: (arrive_round, prompt_len, gen_len) tuples.

    ``poisson`` draws exponential inter-arrival gaps (in router rounds);
    ``bursty`` releases ``burst`` back-to-back arrivals per gap —
    identical offered load, maximally different short-term queue
    pressure.
    Prompt and gen lengths are mixed per request (short/long prompts,
    1..12 token budgets) from the same seeded stream.
    """
    rng = np.random.default_rng(seed)
    if kind == "poisson":
        gaps = rng.exponential(mean_gap_rounds, size=n)
        times = np.floor(np.cumsum(gaps)).astype(int)
    elif kind == "bursty":
        n_bursts = -(-n // burst)
        starts = np.floor(np.cumsum(
            rng.exponential(mean_gap_rounds * burst, size=n_bursts))
        ).astype(int)
        times = np.repeat(starts, burst)[:n]
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    out = []
    for t in times:
        plen = int(rng.choice([6, 10, 16]))
        glen = int(rng.integers(1, 13))
        out.append((int(t), plen, glen))
    return out


def _percentiles(xs: list) -> dict:
    v = np.asarray(sorted(xs), float)
    return {p: float(np.percentile(v, p)) for p in (50, 95, 99)}


def run_trace(router: ReplicaRouter, trace: list, vocab: int,
              seed: int) -> dict:
    """Drive one trace through the router on a round-indexed clock."""
    rng = np.random.default_rng(seed + 1)
    due = [(t, Request(rid=f"t{i}",
                       prompt=tuple(int(x) for x in
                                    rng.integers(0, vocab, plen)),
                       max_new_tokens=glen))
           for i, (t, plen, glen) in enumerate(trace)]
    budgets = {r.rid: r.max_new_tokens for _, r in due}
    due.sort(key=lambda p: p[0])
    arrive_wall: dict = {}
    latencies, served_tokens = [], 0
    rnd, i = 0, 0
    t0 = time.time()
    deferred: list = []
    while i < len(due) or deferred or router.busy():
        now = time.time() - t0
        todo, deferred = deferred, []
        while i < len(due) and due[i][0] <= rnd:
            todo.append(due[i][1])
            i += 1
        for req in todo:
            arrive_wall.setdefault(req.rid, now)
            try:
                router.submit(req)
            except QueueFull:
                deferred.append(req)     # closed loop: retry next round
        for rid, toks in router.step():
            done = time.time() - t0
            latencies.append(done - arrive_wall[rid])
            assert len(toks) == budgets[rid], \
                f"{rid}: served {len(toks)} of {budgets[rid]} tokens"
            served_tokens += len(toks)
        rnd += 1
    wall = time.time() - t0
    assert len(latencies) == len(trace), "router lost requests"
    return {"wall_s": wall, "served_tokens": served_tokens,
            "rounds": rnd, "latency_s": _percentiles(latencies)}


def build_router(cfg, params, *, replicas: int, chunk: int = 2):
    """Replicated engines over the host-device-count mesh."""
    n_dev = jax.device_count()
    tp = n_dev if (cfg.n_kv_heads % n_dev == 0
                   and cfg.n_heads % n_dev == 0) else 1
    mesh = jax.make_mesh((1, tp), ("data", "model")) if tp > 1 else None
    engines = [ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                           chunk=chunk, mesh=mesh)
               for _ in range(replicas)]
    return ReplicaRouter(engines, policy="least_loaded",
                         max_queue=SLOTS * 2), mesh


def _sharding_gates(cfg) -> list:
    """Pricing invariants the sharded planner must keep (CSV lines)."""
    lines = []
    # per-shard KV stream: strictly 1/tp of the unsharded row bytes
    row = kv_row_bytes(cfg, SLOTS)
    for tp in (2, 4):
        assert row / tp < row, "per-shard KV stream must shrink with TP"
    # collective store traffic: WA residues keep the machine ordering
    rows = {r["machine"]: r for r in collective_traffic(cfg, SLOTS, 2)}
    triple = [rows[m]["coll_bytes"]
              for m in ("neoverse_v2", "golden_cove", "zen4")]
    ok = triple[0] <= triple[1] <= triple[2]
    lines.append(
        "fig9,collective_ordering,0,"
        f"grace={triple[0]:.0f};spr={triple[1]:.0f};zen4={triple[2]:.0f};"
        f"grace_le_spr_le_zen4={'OK' if ok else 'VIOLATED'}")
    if not ok:
        raise AssertionError(
            f"collective WA ordering violated: {triple}")
    return lines


def main(quick: bool = False, replicas: int = 2) -> list:
    """Emit the fig9 load table as benchmark CSV lines."""
    cfg = get_smoke_config(ARCH)
    k_params = jax.random.PRNGKey(0)
    params = M.init_params(cfg, k_params)
    n_req = 8 if quick else 24
    router, mesh = build_router(cfg, params, replicas=replicas)
    tp = tp_degree(mesh_axis_sizes(mesh)) if mesh is not None else 1
    chips = tp * replicas
    # planner prediction for the plan machine: slots tokens per step,
    # every replica decoding concurrently, divided per chip
    from repro.serve.planner import plan_chunk_size
    plan = plan_chunk_size(cfg, SLOTS, MAX_LEN, mesh=mesh)
    pred_tok_s_chip = SLOTS * replicas / max(plan.t_step_seconds,
                                            1e-12) / chips
    lines = []
    for kind in ("poisson", "bursty"):
        trace = make_trace(kind, n_req, seed=42)
        rec = run_trace(router, trace, cfg.vocab_size, seed=42)
        lat = rec["latency_s"]
        assert lat[50] <= lat[95] <= lat[99], "percentile ordering"
        tok_s_chip = rec["served_tokens"] / max(rec["wall_s"], 1e-9) / chips
        ratio = tok_s_chip / pred_tok_s_chip
        lines.append(
            f"fig9,load.{kind},{rec['wall_s']*1e6:.0f},"
            f"n={n_req};replicas={replicas};tp={tp};chips={chips};"
            f"p50_ms={lat[50]*1e3:.1f};p95_ms={lat[95]*1e3:.1f};"
            f"p99_ms={lat[99]*1e3:.1f};rounds={rec['rounds']};"
            f"tok_s_chip={tok_s_chip:.1f};"
            f"pred_tok_s_chip={pred_tok_s_chip:.0f};"
            f"pred_machine={plan.machine};ratio={ratio:.2e}")
        assert math.isfinite(ratio) and ratio > 0, "degenerate ratio"
    lines.extend(_sharding_gates(cfg))
    st = router.stats()
    lines.append(
        "fig9,router,0," + ";".join(
            f"r{s['replica']}={s['completed']}/{s['submitted']}"
            for s in st))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short traces (CI shard-smoke job)")
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()
    print("\n".join(main(quick=args.smoke, replicas=args.replicas)))
