"""Fig. 10: chaos serving — fault-injected traces through the
fault-tolerant router, with rescue/conservation/degradation gates.

The fig9 arrival traces (seeded Poisson/bursty) are replayed twice
through identical replica fleets on the virtual clock: once fault-free
(the baseline) and once with injected faults (a wedged replica that
must be detected, ejected, and its in-flight requests rescued; a
NaN-poisoned decode the in-graph guard must quarantine; transient
admission errors and a saturated page pool the admission path must
absorb). Three properties are *gated*, not just reported:

- **No silent loss** — every submitted request is accounted for:
  completed + shed + deadline-shed + deadline-cancelled == submitted,
  with rescue events reconciling requests that moved between replicas.
- **Rescue identity** — every completed stream, including every
  rescued one, is byte-identical to the fault-free baseline (greedy
  decoding: replaying prompt + tokens-so-far reproduces the stream).
- **Budgeted degradation** — chaos p99 stays within the planner-derived
  budget for a 1-of-N replica outage: baseline p99 plus the modeled
  detection window (``eject_threshold`` strikes at the latency-cap
  round time) plus the modeled replay drain at N-1 capacity. And every
  degraded-mode decision (keep / re-planned chunk / shed) carries its
  priced comparison in the artifact (``fig10,degrade`` lines).

All latencies are virtual-clock seconds (the router advances ``now_s``
by the slowest stepped replica's reported round seconds), so the gates
are deterministic — no wall-clock flakiness in CI.
"""

from __future__ import annotations

import math

import jax
import numpy as np

from benchmarks.fig9_load import make_trace
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import (FaultSpec, FaultTolerantRouter, FaultyEngine,
                         HealthConfig, QueueFull, Request, ServeEngine,
                         deadline_for, planned_round_seconds)

ARCH = "xlstm-125m"
SLOTS, MAX_LEN = 2, 64
REPLICAS = 2
SEED = 7
# generous completion deadlines: the outage inflates every in-flight
# latency by the detection window, and fig10 gates rescue identity —
# deadline shed/cancel behavior is pinned by tests/test_health.py
DEADLINE_SLACK = 2000.0


def build_fleet(cfg, params, faults_per_replica):
    """FT router over FaultyEngine-wrapped planned dense replicas."""
    engines = []
    for fl in faults_per_replica:
        inner = ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                            machine="neoverse_v2")
        engines.append(FaultyEngine(inner, fl))
    return FaultTolerantRouter(engines, policy="least_loaded",
                               max_queue=SLOTS * 4, health=HealthConfig())


def run_trace(router, trace, vocab: int, seed: int, plan) -> dict:
    """Drive one arrival trace on the virtual clock; latencies in now_s."""
    rng = np.random.default_rng(seed + 1)
    due = []
    for i, (t, plen, glen) in enumerate(trace):
        due.append((t, Request(
            rid=f"t{i}",
            prompt=tuple(int(x) for x in rng.integers(0, vocab, plen)),
            max_new_tokens=glen,
            deadline_s=deadline_for(plan, glen, slack=DEADLINE_SLACK))))
    due.sort(key=lambda p: p[0])
    arrive_v: dict = {}
    results: dict = {}
    latencies: dict = {}
    rnd, i = 0, 0
    deferred: list = []
    while i < len(due) or deferred or router.busy():
        todo, deferred = deferred, []
        while i < len(due) and due[i][0] <= rnd:
            todo.append(due[i][1])
            i += 1
        for req in todo:
            arrive_v.setdefault(req.rid, router.now_s)
            try:
                router.submit(req)
            except QueueFull:
                deferred.append(req)     # closed loop: retry next round
        for rid, toks in router.step():
            results[rid] = np.asarray(toks)
            latencies[rid] = router.now_s - arrive_v[rid]
        rnd += 1
    return {"results": results, "latencies": latencies, "rounds": rnd,
            "events": router.drain_events()}


def _p99(latencies: dict) -> float:
    return float(np.percentile(sorted(latencies.values()), 99))


def _conservation(rec, router, n_req: int) -> None:
    """Gate (a): every submitted request is accounted for, exactly once."""
    completed = set(rec["results"])
    shed = set(router.shed_rids)
    deadline = {e["rid"] for e in rec["events"]
                if e["kind"] in ("deadline_shed", "deadline_cancel")}
    assert not router.quarantined, \
        "FT router must rescue quarantined streams, not park them"
    assert completed.isdisjoint(shed), "completed and shed overlap"
    accounted = completed | shed | deadline
    missing = {f"t{i}" for i in range(n_req)} - accounted
    assert not missing, f"requests silently lost: {sorted(missing)}"
    assert len(completed) + len(shed | deadline) == n_req, \
        "request accounting does not add up"


def chaos_faults(stuck_from: int) -> list:
    """Per-replica fault schedules for the 1-of-N outage scenario.

    Replica 0 wedges for a window long enough to strike through
    quarantine into ejection (rescue path), then recovers. Replica 1
    sees one NaN-poisoned decode (non-finite guard + rescue), one
    transient admission error, and one injected pool exhaustion
    (priced degradation decision).
    """
    return [
        [FaultSpec("stuck", frozenset(range(stuck_from, stuck_from + 8)))],
        [FaultSpec("nonfinite", frozenset({stuck_from + 1}), slot=0),
         FaultSpec("admit_error", frozenset({3})),
         FaultSpec("pool_exhausted", frozenset({5}))],
    ]


def main(quick: bool = False) -> list:
    """Emit the fig10 chaos table as gated benchmark CSV lines."""
    cfg = get_smoke_config(ARCH)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    lines = []
    for kind in ("poisson", "bursty"):
        trace = make_trace(kind, n_req, seed=SEED)
        base_rt = build_fleet(cfg, params, [[] for _ in range(REPLICAS)])
        plan = base_rt.replicas[0].plan
        base = run_trace(base_rt, trace, cfg.vocab_size, SEED, plan)
        assert len(base["results"]) == n_req, "baseline lost requests"
        chaos_rt = build_fleet(cfg, params, chaos_faults(stuck_from=4))
        rec = run_trace(chaos_rt, trace, cfg.vocab_size, SEED, plan)

        _conservation(rec, chaos_rt, n_req)                     # gate (a)

        rescued = {e["rid"] for e in rec["events"]
                   if e["kind"] == "rescued_complete"}
        assert rescued, "chaos scenario must exercise the rescue path"
        mismatched = [rid for rid, toks in rec["results"].items()
                      if not np.array_equal(toks, base["results"][rid])]
        assert not mismatched, \
            f"streams diverged from fault-free baseline: {mismatched}"

        base_p99, chaos_p99 = _p99(base["latencies"]), \
            _p99(rec["latencies"])                              # gate (c)
        hc = chaos_rt.health_cfg
        round_s = planned_round_seconds(plan)
        detect_s = hc.eject_threshold * hc.latency_factor * round_s
        max_gen = max(g for _, _, g in trace)
        replay_s = (math.ceil(max_gen / plan.chunk) + hc.cooldown_rounds) \
            * round_s * REPLICAS / (REPLICAS - 1)
        budget_p99 = 1.5 * (base_p99 + detect_s + replay_s)
        assert chaos_p99 <= budget_p99, \
            (f"p99 degradation {chaos_p99:.4f}s exceeds planner budget "
             f"{budget_p99:.4f}s ({kind})")

        n_rescue = sum(e["kind"] == "rescue" for e in rec["events"])
        lines.append(
            f"fig10,chaos.{kind},{chaos_p99 * 1e6:.0f},"
            f"n={n_req};replicas={REPLICAS};"
            f"base_p99_ms={base_p99 * 1e3:.2f};"
            f"chaos_p99_ms={chaos_p99 * 1e3:.2f};"
            f"budget_p99_ms={budget_p99 * 1e3:.2f};"
            f"rescues={n_rescue};rescued_done={len(rescued)};"
            f"shed={len(chaos_rt.shed_rids)};"
            f"deadline_shed={chaos_rt.deadline_shed};"
            f"identical={'OK' if not mismatched else 'FAIL'}")

        # every shed was a justified, priced decision — and every priced
        # decision is in the artifact
        shed_events = [e for e in rec["events"] if e["kind"] == "shed"]
        just = [d for d in chaos_rt.degrade_log if d["choice"] == "shed"]
        assert len(shed_events) == len(just), \
            "unjustified shed: no priced comparison recorded"
        assert chaos_rt.degrade_log, \
            "pool-exhaustion injection must leave a priced decision"
        for d in chaos_rt.degrade_log:
            opts = ";".join(
                f"{name}_round_us={o['round_s'] * 1e6:.1f};"
                f"{name}_drain_us={o['drain_s'] * 1e6:.1f}"
                for name, o in sorted(d["options"].items()))
            lines.append(
                f"fig10,degrade.{kind},0,"
                f"trigger={d['trigger']};choice={d['choice']};"
                f"chunk={d['chunk']};backlog={d['backlog_tokens']};"
                f"up={d['replicas_up']};{opts}")
        states = ">".join(
            s for _, _, s in chaos_rt.health[0].transitions) or "healthy"
        lines.append(
            f"fig10,health.{kind},0,"
            f"replica0={states};"
            + ";".join(f"r{s['replica']}={s['health']}"
                       f"/f{s['failed']}" for s in chaos_rt.stats()))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short traces (CI chaos-smoke job)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.smoke)))
