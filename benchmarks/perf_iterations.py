"""§Perf hillclimbing driver: baseline + hypothesis-driven variants for the
three chosen cells (worst peak fraction / most collective-bound / most
paper-representative), each re-lowered+re-analysed per iteration.

Run in a fresh process (needs 512 placeholder devices):
  PYTHONPATH=src python -m benchmarks.perf_iterations [--cell H1|H2|H3|H4]

Results land in results/perf/<tag>.json; summarize with --report, or
emit the whole hillclimb as one machine-readable artifact with
``--trajectory BENCH_perf_trajectory.json`` (the CI perf-trajectory job
uploads exactly that file: per-cell iteration sequences with their
roofline terms and the bound-term delta vs each cell's base).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

from repro.utils.hw import HBM_BW, ICI_BW, PEAK_FLOPS

OUT = "results/perf"


def terms(rec):
    pm = rec["portmodel"]
    t_c = pm["flops"] / PEAK_FLOPS
    t_m = pm["bytes_hbm"] * rec["wa_ratio"] / HBM_BW
    t_x = sum(pm["coll_bytes"].values()) / (ICI_BW * 4)
    return {"T_comp_s": t_c, "T_mem_s": t_m, "T_coll_s": t_x,
            "bound_s": max(t_c, t_m, t_x),
            "peak_gb": rec["memory"]["peak_bytes"] / 1e9,
            "flops": pm["flops"], "bytes": pm["bytes_hbm"],
            "coll": pm["coll_bytes"], "wa": rec["wa_ratio"]}


def run(tag, **kw):
    from repro.launch.dryrun import run_cell
    path = os.path.join(OUT, tag + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    rec = run_cell(**kw)
    rec["_terms"] = terms(rec)
    os.makedirs(OUT, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def attn_loop_flash_substitution(rec, cfg, shape, accum):
    """Analytic iteration H1.1: replace the scan-based attention inner
    loops (identified from per-loop byte accounting: whiles with <= S/kv
    trips moving >= 8 MB/iter) with the Pallas flash kernel's Q/K/V/O
    payload. Returns adjusted memory term."""
    pm = rec["portmodel"]
    loops = rec.get("loop_bytes") or pm.get("loop_bytes") or {}
    attn_bytes = 0.0
    for name, (n, b_iter, f_iter) in loops.items():
        if 2 <= n <= max(2, shape.seq_len // cfg.kv_chunk) and b_iter > 8e6:
            attn_bytes += n * b_iter
    # the layer scans multiply these loops; loop_bytes entries are
    # per-parent-visit, so scale by layer-count x accum x (fwd+remat+bwd)
    passes = cfg.n_layers * accum * 4
    s_loc = shape.seq_len
    b_loc = max(1, shape.global_batch // 16 // accum)
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff
    qkvo = b_loc * s_loc * (2 * h + 2 * hkv) * dh * 2 / 16  # TP-sharded
    flash_bytes = qkvo * passes
    return attn_bytes, flash_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--trajectory", default="",
                    help="after running the selected cells, write the "
                         "aggregated hillclimb trajectory (every "
                         "results/perf/*.json, grouped per cell, with "
                         "bound-term deltas vs the cell base) to this "
                         "JSON path — the BENCH_*.json CI artifact")
    args = ap.parse_args()

    if args.report:
        report()
        return
    run_cells(args.cell)
    if args.trajectory:
        write_trajectory(args.trajectory)


def run_cells(cell: str):
    """Run the hypothesis cells selected by ``cell`` ('all' or H1..H4)."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.optim.adamw import OptConfig

    # ---- H1: yi-9b train_4k (paper-representative, memory-bound) ----
    if cell in ("all", "H1"):
        cfg = get_config("yi-9b")
        base = run("H1_base", arch="yi-9b", shape_name="train_4k",
                   multi_pod=False, cfg=cfg)
        # it2: remat=dots — hypothesis: T_comp(port) -25% (no fwd
        # recompute), peak memory up
        run("H1_it2_remat_dots", arch="yi-9b", shape_name="train_4k",
            multi_pod=False, cfg=dataclasses.replace(cfg, remat="dots"))
        # it3: chunk geometry — hypothesis (to refute): score traffic is
        # invariant to chunk size, only the kernel fusion removes it
        run("H1_it3_bigchunks", arch="yi-9b", shape_name="train_4k",
            multi_pod=False,
            cfg=dataclasses.replace(cfg, q_chunk=2048, kv_chunk=4096))

    # ---- H2: qwen1.5-110b decode_32k (most collective-bound) ----
    if cell in ("all", "H2"):
        cfg = get_config("qwen1.5-110b")
        run("H2_base", arch="qwen1.5-110b", shape_name="decode_32k",
            multi_pod=False, cfg=cfg, serve_variant="gather")
        # it1: 16-token in-graph decode — hypothesis: the per-layer FSDP
        # weight all-gather is loop-invariant -> T_coll/token ~ /16
        # (REFUTED: hoisting would materialize all 80 layers' gathered
        # weights = 1.1 TB; XLA correctly refuses)
        run("H2_it1_loop16", arch="qwen1.5-110b", shape_name="decode_32k",
            multi_pod=False, cfg=cfg, decode_loop=16,
            serve_variant="gather")
        # it2: resident 2D-sharded weights + activation resharding —
        # hypothesis: all-gather (GBs of weights) replaced by activation
        # all-reduces (MBs)
        run("H2_it2_resident2d", arch="qwen1.5-110b",
            shape_name="decode_32k", multi_pod=False, cfg=cfg,
            serve_variant="resident2d")

    # ---- H3: jamba train_4k (worst peak fraction, WA-heavy) ----
    if cell in ("all", "H3"):
        cfg = get_config("jamba-v0.1-52b")
        run("H3_base_unfused", arch="jamba-v0.1-52b", shape_name="train_4k",
            multi_pod=False, cfg=dataclasses.replace(cfg, ssm_fuse=False))
        # it1: fuse decay/input into the scan — hypothesis: the
        # (B,T,d_inner,N) tensors disappear from HBM -> T_mem down ~2x on
        # mamba layers
        run("H3_it1_fused", arch="jamba-v0.1-52b", shape_name="train_4k",
            multi_pod=False, cfg=dataclasses.replace(cfg, ssm_fuse=True))
        # it2: MoE dispatch geometry — capacity 1.25->1.0, groups 2x
        run("H3_it2_moegeom", arch="jamba-v0.1-52b", shape_name="train_4k",
            multi_pod=False,
            cfg=dataclasses.replace(cfg, ssm_fuse=True,
                                    capacity_factor=1.0,
                                    moe_group_size=2048))

    # ---- H4: qwen3-moe train fit enabler (int8 moments) ----
    if cell in ("all", "H4"):
        cfg = get_config("qwen3-moe-235b-a22b")
        run("H4_base", arch="qwen3-moe-235b-a22b", shape_name="train_4k",
            multi_pod=False, cfg=cfg)
        run("H4_it1_int8_moments", arch="qwen3-moe-235b-a22b",
            shape_name="train_4k", multi_pod=False, cfg=cfg,
            oc=OptConfig(moments_dtype="int8"))


def write_trajectory(path: str) -> dict:
    """Aggregate every results/perf/*.json into one trajectory artifact.

    Grouped per hypothesis cell (tag prefix up to the first ``_``), each
    iteration carries its roofline terms plus ``bound_vs_base`` — the
    bound-term ratio against the cell's base record — so the artifact
    answers "did the hillclimb move the bound?" without re-running
    anything. Written as versioned JSON; returns the payload.
    """
    import glob
    cells: dict = {}
    for rec_path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        with open(rec_path) as f:
            rec = json.load(f)
        t = rec.get("_terms")
        if not t:
            continue
        tag = os.path.basename(rec_path)[:-5]
        cells.setdefault(tag.split("_", 1)[0], []).append(
            {"tag": tag, "terms": t})
    for iters in cells.values():
        base = next((i for i in iters if "base" in i["tag"]), iters[0])
        b = max(base["terms"]["bound_s"], 1e-12)
        for i in iters:
            i["bound_vs_base"] = i["terms"]["bound_s"] / b
    payload = {"version": 1, "format": "repro-perf-trajectory",
               "n_cells": len(cells),
               "n_iterations": sum(len(v) for v in cells.values()),
               "cells": cells}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"trajectory: {payload['n_iterations']} iterations over "
          f"{payload['n_cells']} cells -> {path}")
    return payload


def report():
    import glob
    for path in sorted(glob.glob(os.path.join(OUT, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        t = rec.get("_terms")
        if not t:
            continue
        tag = os.path.basename(path)[:-5]
        print(f"{tag:28s} Tc={t['T_comp_s']:8.2f}s Tm={t['T_mem_s']:9.2f}s "
              f"Tx={t['T_coll_s']:7.2f}s peak={t['peak_gb']:6.2f}GB "
              f"wa={t['wa']:.2f}")


if __name__ == "__main__":
    main()
