"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. ``--only <tag>`` runs one;
``--full`` runs the complete (slow) variants, e.g. the 416-block Fig. 3
suite.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ("table1_machines", "table2_ports", "table3_instructions",
           "fig2_unitmix", "fig3_rpe", "fig4_wa", "fig4b_ntstore",
           "fig5_memladder", "fig6_serve", "fig7_decode", "fig8_paged",
           "fig9_load", "fig10_chaos", "fig11_overlap", "roofline_sweep")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            quick = not args.full
            lines = mod.main(quick=quick)
            for ln in lines:
                print(ln)
            print(f"_meta,{mod_name},{(time.time()-t0)*1e6:.0f},ok",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"_meta,{mod_name},{(time.time()-t0)*1e6:.0f},FAILED",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
