"""Paper Fig. 4: write-allocate evasion — traffic ratio vs core count for a
store-only kernel, across the three behavioural machine modes, plus the
TPU tile-level RMW model and a measured host experiment.

Modeled curves reproduce the paper's findings:
  * Grace/TPU (auto_claim): flat 1.0 (perfect evasion)
  * SPR (saturation_gated): 2.0 falling toward the DRAM-tier residue
    (1.1) only near saturation; NT stores leave the same ~10% residue
  * Genoa (explicit_only): flat 2.0; NT stores exact 1.0

The ratios come from ``wa.ladder_traffic_ratio`` — the per-tier
``MemTier.wa_residue`` path that ``benchmarks/fig4b_ntstore.py`` and
the store-flavor selector (``repro.kernels.stores``) also price
through, so fig4, fig4b, and the selector can never disagree.

Measured host experiment: store-only INIT into a fresh buffer vs a
donated (in-place) buffer — donation is the NT-store/cache-line-claim
analogue at the XLA buffer level.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.machine import get_machine
from repro.core.wa import ladder_traffic_ratio, store_profile

N = 1 << 22     # 16 MiB store

# registered machine name -> paper Fig. 4 curve label
_CURVES = (("neoverse_v2", "grace"), ("golden_cove", "spr"),
           ("zen4", "genoa"))


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = False):
    lines = []
    # --- modeled cross-machine curves (paper Fig. 4): the behavioural
    # mode now comes from each registered machine's wa_mode tag ---
    machines = [(get_machine(name), label) for name, label in _CURVES]
    for cores_frac in (0.1, 0.25, 0.5, 0.75, 1.0):
        parts = []
        for m, label in machines:
            r = ladder_traffic_ratio(m, bw_utilization=cores_frac)
            parts.append(f"{label}={r:.2f}")
            if m.wa_mode != "auto_claim":   # NT stores only change those
                r_nt = ladder_traffic_ratio(m, nt_stores=True,
                                            bw_utilization=cores_frac)
                parts.append(f"{label}_nt={r_nt:.2f}")
        lines.append(f"fig4,model_utilization_{cores_frac:.2f},0,"
                     + ";".join(parts))

    # --- TPU tile-level RMW (the WA analogue, DESIGN.md §2) ---
    full = store_profile((4096, 4096), "f32")
    part = store_profile((4095, 4090), "f32")
    mis = store_profile((7, 100), "f32", offset_aligned=False)
    lines.append(f"fig4,tpu_tile_full,0,ratio={full.ratio:.3f}")
    lines.append(f"fig4,tpu_tile_partial_edge,0,ratio={part.ratio:.3f}")
    lines.append(f"fig4,tpu_tile_misaligned_7x100,0,ratio={mis.ratio:.3f}")

    # --- measured host: fresh store vs donated in-place store ---
    x = jnp.zeros((N,), jnp.float32)
    fresh = jax.jit(lambda: jnp.full((N,), 3.0, jnp.float32))
    inplace = jax.jit(lambda a: a * 0.0 + 3.0, donate_argnums=(0,))
    t_fresh = _time(fresh)
    # donation consumes the buffer: re-make per rep
    ts = []
    for _ in range(5):
        buf = jnp.zeros((N,), jnp.float32)
        jax.block_until_ready(buf)
        t0 = time.perf_counter()
        buf = inplace(buf)
        jax.block_until_ready(buf)
        ts.append(time.perf_counter() - t0)
    t_inplace = min(ts)
    ratio = t_fresh / max(t_inplace, 1e-12)
    lines.append(f"fig4,host_fresh_store,{t_fresh*1e6:.1f},"
                 f"bw={4*N/t_fresh/1e9:.2f}GB/s")
    lines.append(f"fig4,host_donated_store,{t_inplace*1e6:.1f},"
                 f"bw={4*N/t_inplace/1e9:.2f}GB/s;fresh_over_donated="
                 f"{ratio:.2f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
