"""Paper Table II analog: in-core features / port models of every
registered machine — the three TPU generations, the paper's three CPUs
(Zen 4, Golden Cove, Neoverse V2), and the ubench-calibrated host."""

from __future__ import annotations

from repro.core.machine import registered_models
from repro.core.ubench import calibrated_host_model


def main(quick: bool = False):
    lines = []
    calibrated_host_model()         # registers `host_cpu`
    for m in registered_models():
        n_mxu = len(m.entry("mxu").ports)
        n_vpu = len(m.entry("vpu").ports)
        n_ls = len(m.entry("vlsu").ports)
        lines.append(
            f"table2,{m.name},0,"
            f"vendor={m.vendor or 'host'};ports={len(m.ports)};"
            f"fma_or_mxu={n_mxu};simd_or_vpu={n_vpu};ldst={n_ls};"
            f"issue_width={m.issue_width};"
            f"simd_bytes={m.simd_width_bytes};wa_mode={m.wa_mode};"
            f"mxu_cyc_per_pass={m.entry('mxu').cycles_per_unit:.0f};"
            f"vdiv_port={m.entry('vdiv').ports[0]};"
            f"vpu_lat={m.entry('vpu').latency:.0f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
