"""Paper Table II analog: in-core features / port models of the machines."""

from __future__ import annotations

from repro.core.machine import MACHINES
from repro.core.ubench import calibrated_host_model


def main(quick: bool = False):
    lines = []
    machines = dict(MACHINES)
    machines["host_cpu"] = calibrated_host_model()
    for name, m in machines.items():
        n_mxu = sum(1 for p in m.ports if p.startswith("MXU"))
        n_vpu = sum(1 for p in m.ports if p.startswith("VPU"))
        lines.append(
            f"table2,{name},0,"
            f"ports={len(m.ports)};mxu={n_mxu};vpu={n_vpu};"
            f"simd_bytes={m.simd_width_bytes};"
            f"mxu_cyc_per_pass={m.table['mxu'].cycles_per_unit:.0f};"
            f"vpu_lat={m.table['vpu'].latency:.0f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
