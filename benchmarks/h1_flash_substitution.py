"""H1.it1 — flash-attention substitution, reproducibly derived from the
H1 baseline record's per-loop byte attribution (EXPERIMENTS.md §Perf H1).

The attention inner kv-scans are the whiles with trips in [2, S/kv_chunk]
inside the layer loops; their bytes are replaced by the Pallas kernel's
Q/K/V/O payload.

  PYTHONPATH=src:. python -m benchmarks.h1_flash_substitution
"""

import json

from repro.configs import get_config
from repro.utils.hw import HBM_BW


def main(path="results/perf/H1_base.json"):
    rec = json.load(open(path))
    cfg = get_config("yi-9b")
    pm = rec["portmodel"]
    accum = rec.get("accum_steps", 16)
    s, kvc = 4096, cfg.kv_chunk
    max_trips = max(2, s // kvc)

    # 1) attention-scan bytes per layer-loop visit (loop_bytes holds the
    # per-visit totals of each distinct loop body)
    attn_per_visit = 0.0
    layer_loops = []
    for name, (n, b, f) in pm["loop_bytes"].items():
        if 2 <= n <= max_trips and b > 8e6:
            attn_per_visit += n * b
        elif n == cfg.n_layers:
            layer_loops.append((name, n, b))
    # trip-1 chunks are unrolled (not whiles): scale by the q-chunk census —
    # chunks with >=2 kv trips carry (nq - nq_trip1)/nq of the traffic
    nq = s // cfg.q_chunk
    trip1 = sum(1 for i in range(nq)
                if (i * cfg.q_chunk + cfg.q_chunk + kvc - 1) // kvc == 1)
    scale = nq / max(1, nq - trip1)
    attn_per_visit *= scale

    # 2) the attention whiles live inside the layer-loop bodies (fwd AND
    # bwd bodies both contribute distinct while names to loop_bytes, so
    # attn_per_visit already covers one visit of each). Each body runs
    # n_layers times per microbatch, and there are accum microbatches.
    total_attn = attn_per_visit * cfg.n_layers * accum

    # 3) flash kernel replacement payload: Q,K,V,O per layer-pass, TP/16
    b_loc = max(1, 256 // 16 // accum)
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_eff
    qkvo = b_loc * s * (2 * h + 2 * hkv) * dh * 2 / 16
    flash_total = qkvo * cfg.n_layers * accum * 4      # fwd+remat+bwd(2x)

    before = pm["bytes_hbm"]
    after = before - total_attn + flash_total
    print(f"attention-scan bytes (attributed): {total_attn:.3e} "
          f"({total_attn/before:.1%} of step)")
    print(f"flash Q/K/V/O payload            : {flash_total:.3e}")
    print(f"step bytes  : {before:.3e} -> {after:.3e}")
    print(f"T_mem       : {before/HBM_BW:.2f} s -> {after/HBM_BW:.2f} s "
          f"({(after-before)/before:+.1%})")
    return {"before": before, "after": after,
            "attn_bytes": total_attn, "flash_bytes": flash_total}


if __name__ == "__main__":
    main()
