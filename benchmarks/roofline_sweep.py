"""§Roofline: build the three-term table for every dry-run record under
results/dryrun (produced by repro.launch.dryrun --all --both-meshes)."""

from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core import roofline


def load_cells(pattern: str = "results/dryrun/*.json"):
    cells = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            rec = json.load(f)
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        pm = rec.get("portmodel")
        rep = None
        if pm is not None:
            from repro.core.portmodel import Report
            rep = Report(
                tp_cycles=pm["tp_cycles"], cp_cycles=pm["cp_cycles"],
                serial_cycles=pm["serial_cycles"],
                port_occupation=pm.get("top_ports", {}),
                flops=pm["flops"], bytes_hbm=pm["bytes_hbm"],
                coll_bytes=pm["coll_bytes"], n_instrs=pm["n_instrs"],
                unknown_ops=pm["unknown_ops"], trips_seen=pm.get("trips", {}))
        cells.append(roofline.analyze_cell(rec, cfg, shape, report=rep))
    return cells


def main(quick: bool = False):
    cells = load_cells()
    lines = []
    for c in sorted(cells, key=lambda c: (c.arch, c.shape, c.mesh)):
        lines.append(
            f"roofline,{c.arch}.{c.shape}.{c.mesh},{c.bound*1e6:.0f},"
            f"Tc={c.t_compute*1e3:.2f}ms;Tc_port={c.t_compute_port*1e3:.2f}ms;"
            f"Tm={c.t_memory*1e3:.2f}ms;Tx={c.t_collective*1e3:.2f}ms;"
            f"dom={c.dominant};useful={c.useful_ratio:.2f};"
            f"peak_frac={c.peak_fraction:.3f};wa={c.wa_ratio:.2f}")
    if not lines:
        lines = ["roofline,no_records,0,run repro.launch.dryrun first"]
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
