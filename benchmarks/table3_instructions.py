"""Paper Table III analog: per-op-class throughput and latency.

For the TPU machines the entries are the machine-model values in
DP-elements/cycle (the paper's unit); for the host they are ubench-
measured. The paper's observation structure carries over: the widest
machine (v5p) wins vector throughput, latency is flat across generations
(fixed-function units), gather is cache-line/tile limited.
"""

from __future__ import annotations

from repro.core.machine import MACHINES
from repro.core.ubench import calibrated_host_model, measure_host_rates

VPU_BLOCK = 8 * 128
CLASSES = ("vpu", "xlu", "vdiv", "vlsu", "gather4", "mxu")


def main(quick: bool = False):
    lines = []
    for name, m in MACHINES.items():
        for cls in CLASSES:
            e = m.table[cls]
            # effective port count matches the Analyzer's weighted
            # occupation: the slowest (highest-weight) port bounds TP
            if e.port_weights:
                n_ports = sum(e.port_weights) / max(e.port_weights)
            else:
                n_ports = len(e.ports)
            if cls == "mxu":
                # elements/cy for a dense 128x128x128 pass
                per_cy = 128 * 128 * n_ports / e.cycles_per_unit
            else:
                per_cy = VPU_BLOCK * n_ports / e.cycles_per_unit / 2  # DP

            lines.append(f"table3,{name}.{cls},0,"
                         f"dp_elems_per_cy={per_cy:.1f};lat_cy={e.latency:.0f}")
    rates = measure_host_rates()
    raw = rates.pop("_raw")
    for cls in CLASSES:
        if cls in rates:
            lines.append(f"table3,host_cpu.{cls},0,"
                         f"units_per_s={rates[cls]:.3e}")
    lines.append(f"table3,host_cpu.matmul,{raw['matmul_s']*1e6:.1f},"
                 f"gflops={raw['flops_matmul']/1e9:.1f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
