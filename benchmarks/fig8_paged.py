"""Fig. 8 (extension): paged KV cache — live-token memory, prefix
sharing, and MemTier-priced page traffic.

The dense serve engine preallocates ``max_slots x max_len`` KV rows, so
its peak cache footprint scales with the decode *horizon* whether or
not any request ever gets there. The paged engine
(``repro.serve.engine.PagedServeEngine`` over ``repro.serve.pages``)
maps fixed-size physical pages through per-slot block tables: memory
scales with *live tokens*, identical prompt prefixes share refcounted
pages (copy-on-write on divergence), and recycled pages are re-admitted
with their stale rows still in place — no zero-fill pass, the serve
path's write-allocate-evasion story. This benchmark records, per cell:

* the dense vs paged peak KV bytes at two horizons (the paged pool is
  sized by live tokens and does not move when the horizon doubles);
* a differential serve run — the paged engine must emit exactly the
  dense engine's token streams while its page pool conserves;
* admission stats for a shared-prefix workload (page maps, zero copies)
  and the engine's own gathered-page counter against an independent
  re-derivation of the dispatch arithmetic;
* the per-machine *priced* page traffic (``serve.kv_traffic``): gather
  + table reads per step, CoW copy cost, and the recycled-vs-zero-fill
  admission store savings on every registered machine.

Three assertions gate CI: (a) peak cache bytes scale with live tokens,
not ``horizon x slots`` — and the paged streams are token-identical to
dense; (b) admitting a request whose prompt shares a full-page prefix
maps the shared pages and copies nothing; (c) the engine's measured
gather traffic matches the priced arithmetic, CoW shows up only when
streams diverge, and recycled admission beats zero-fill on every
machine with the paper ordering on the gather step. As with fig6/fig7
the host run is a functional anchor, not a cross-vendor validation —
predicted and measured ride side by side so real hardware can score
them.
"""

from __future__ import annotations

import math
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.machine import registered_names
from repro.models import model as M
from repro.serve import (PagedServeEngine, Request, ServeEngine,
                         cow_fork_traffic, page_admission_traffic,
                         page_gather_traffic)
from repro.serve.kv_traffic import page_bytes
from repro.serve.pages import dense_kv_bytes, paged_kv_bytes

ARCH = "yi-9b"                 # pure-GQA attention stack: clean KV story
PAPER_CPUS = ("zen4", "golden_cove", "neoverse_v2")

PS = 4                         # page size (tokens) for the serve runs
SLOTS, HORIZON, CHUNK = 2, 24, 3


def _params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _prompt(cfg, n, seed):
    rng = np.random.default_rng(seed)
    return tuple(int(t) for t in rng.integers(0, cfg.vocab_size, n))


def _engines(cfg, params, **kw):
    dense = ServeEngine(cfg, params, max_slots=SLOTS, max_len=HORIZON,
                        chunk=CHUNK, **kw)
    paged = PagedServeEngine(cfg, params, max_slots=SLOTS,
                             max_len=HORIZON, chunk=CHUNK,
                             page_size=PS, **kw)
    return dense, paged


# --- gate (a): memory scales with live tokens, streams identical -----------

def memory_lines(cfg) -> list:
    """Peak KV bytes, dense vs paged, across a horizon doubling."""
    slots, occ, ps = 4, 64, 8
    lines = []
    for hor in (256, 512):
        live_pages = slots * math.ceil(occ / ps)
        d = dense_kv_bytes(cfg, slots, hor)
        p = paged_kv_bytes(cfg, live_pages, ps)
        lines.append(
            f"fig8,kv_bytes.hor{hor},0,dense={d};paged={p};"
            f"ratio={d / p:.2f};occ={occ};slots={slots};page={ps}")
    d1, d2 = dense_kv_bytes(cfg, slots, 256), dense_kv_bytes(cfg, slots, 512)
    p_live = paged_kv_bytes(cfg, slots * math.ceil(occ / ps), ps)
    if d2 != 2 * d1:
        raise AssertionError(f"dense bytes not horizon-bound: {d1} -> {d2}")
    # the pool is sized by live tokens: horizon-free, and the dense
    # cache at the 4x-larger horizon costs ~4x the quarter-full pool
    if not d1 / p_live > 3.9:
        raise AssertionError(
            f"paged bytes not live-token-bound: dense={d1} paged={p_live}")
    return lines


def differential_lines(cfg, params) -> list:
    """Dense vs paged on a mixed shared-prefix workload: identical
    streams, conserved pool, wall-clock anchor for both engines."""
    base = _prompt(cfg, 8, 1)                       # 2 full pages at PS=4
    reqs = [Request("a", base, 6),
            Request("b", base + _prompt(cfg, 2, 2), 8),   # shares 2 pages
            Request("c", _prompt(cfg, 7, 3), 5),          # partial page
            Request("d", base, 4)]                        # shares again
    dense, paged = _engines(cfg, params)
    t0 = time.perf_counter()
    want = dense.run(list(reqs))
    t_dense = time.perf_counter() - t0
    t0 = time.perf_counter()
    got = paged.run(list(reqs))
    t_paged = time.perf_counter() - t0
    if set(got) != set(want):
        raise AssertionError(f"request sets differ: {set(got)} {set(want)}")
    for rid in want:
        if not np.array_equal(got[rid], want[rid]):
            raise AssertionError(f"paged stream {rid!r} diverged from dense")
    paged.check_pool()                              # conservation invariant
    st = paged.pool.stats
    return [
        f"fig8,measured.dense_run,{t_dense * 1e6:.0f},requests={len(reqs)}",
        f"fig8,measured.paged_run,{t_paged * 1e6:.0f},"
        f"shared_maps={st['shared_maps']};cow={st['cow_copies']};"
        f"fresh={st['fresh_allocs']};recycled={st['recycled_allocs']}",
        "fig8,gates.identity,0,streams_identical=OK;pool_conserved=OK",
    ]


# --- gate (b): shared-prefix admission copies nothing ----------------------

def sharing_lines(cfg, params) -> list:
    """Admit the same prompt twice: the second admission maps the full
    prompt pages and allocates/copies nothing."""
    _, eng = _engines(cfg, params)
    prompt = _prompt(cfg, 8, 1)                     # exactly 2 full pages
    eng.admit(Request("a", prompt, 4))
    before = dict(eng.pool.stats)
    eng.admit(Request("b", prompt, 4))
    d = {k: eng.pool.stats[k] - before[k] for k in before}
    if d["shared_maps"] != len(prompt) // PS:
        raise AssertionError(f"expected {len(prompt) // PS} shared page "
                             f"maps, got {d['shared_maps']}")
    if d["fresh_allocs"] or d["recycled_allocs"] or d["cow_copies"]:
        raise AssertionError(f"shared-prefix admission moved pages: {d}")
    eng.run([])                                     # drain cleanly
    return [f"fig8,gates.shared_admission,0,"
            f"maps={d['shared_maps']};allocs=0;copies=0"]


# --- gate (c): counted gather == arithmetic; CoW on divergence -------------

def _expected_gather(prompt_len, budget, chunk, ps, pps) -> int:
    """Re-derive the engine's dispatch loop: live pages summed over
    chunked dispatches for one solo request (independent arithmetic)."""
    mapped = math.ceil(prompt_len / ps)
    pos, rem, total = prompt_len, budget - 1, 0
    while rem > 0:
        take = min(chunk, rem)
        mapped = max(mapped, min((pos + take - 1) // ps + 1, pps))
        total += mapped
        pos += chunk
        rem -= take
    return total


def traffic_lines(cfg, params) -> list:
    lines = []
    # engine-counted gather vs the independent re-derivation
    eng = PagedServeEngine(cfg, params, max_slots=1, max_len=HORIZON,
                           chunk=CHUNK, page_size=PS,
                           share_prefixes=False)
    s, g = 7, 9
    eng.run([Request("solo", _prompt(cfg, s, 5), g)])
    want = _expected_gather(s, g, CHUNK, PS, eng.pages_per_slot)
    if eng.gather_pages != want:
        raise AssertionError(
            f"gather counter {eng.gather_pages} != arithmetic {want}")
    gathered = eng.gather_pages * page_bytes(cfg, PS)
    lines.append(f"fig8,measured.gather_pages,0,pages={eng.gather_pages};"
                 f"bytes={gathered:.0f};expected={want}")
    # priced per-step gather: bytes consistent with the counter's unit,
    # paper ordering on the WA-priced total
    rows = {r["machine"]: r
            for r in page_gather_traffic(cfg, SLOTS, 256, 64, 8,
                                         machines=PAPER_CPUS)}
    for name, r in rows.items():
        if r["gather_read_bytes"] != (page_bytes(cfg, 8)
                                      * r["live_pages"] * SLOTS):
            raise AssertionError(f"gather pricing unit drifted on {name}")
        lines.append(f"fig8,pred.gather.{name},{r['gather_seconds']*1e6:.2f},"
                     f"total={r['total_bytes']:.0f};"
                     f"read_ratio={r['read_ratio']:.2f}")
    if not (rows["neoverse_v2"]["total_bytes"]
            <= rows["golden_cove"]["total_bytes"]
            <= rows["zen4"]["total_bytes"]):
        raise AssertionError("paper ordering broken on gather step")
    # CoW surfaces exactly when streams diverge: fork + temperature>0
    eng = PagedServeEngine(cfg, params, max_slots=2, max_len=HORIZON,
                           chunk=CHUNK, page_size=PS, temperature=0.7)
    eng.admit(Request("a", _prompt(cfg, 7, 6), 6))  # partial last page
    eng.fork("a", "b")
    eng.run([])
    if eng.pool.stats["cow_copies"] < 1:
        raise AssertionError("diverging fork produced no CoW copy")
    lines.append(f"fig8,measured.fork_cow,0,"
                 f"cow={eng.pool.stats['cow_copies']}")
    for r in cow_fork_traffic(cfg, 8, machines=PAPER_CPUS):
        lines.append(f"fig8,pred.cow.{r['machine']},"
                     f"{r['copy_seconds']*1e6:.2f},"
                     f"total={r['total_bytes']:.0f}")
    # recycled admission beats zero-fill on EVERY registered machine
    bad = []
    for r in page_admission_traffic(cfg, 64, 256, 8,
                                    machines=registered_names()):
        if not r["recycled_bytes"] < r["zero_fill_bytes"]:
            bad.append(r["machine"])
        if r["machine"] in PAPER_CPUS:
            lines.append(f"fig8,pred.admission.{r['machine']},0,"
                         f"recycled={r['recycled_bytes']:.0f};"
                         f"zero_fill={r['zero_fill_bytes']:.0f};"
                         f"savings={r['savings_ratio']:.2f}")
    if bad:
        raise AssertionError(f"zero-fill beat recycling on: {bad}")
    lines.append("fig8,gates.traffic,0,gather_counter=OK;"
                 "paper_order=OK;fork_cow=OK;recycle_beats_zero_fill=OK")
    return lines


def main(quick: bool = False):
    """Emit the fig8 paged-KV table as benchmark CSV lines."""
    cfg = get_smoke_config(ARCH)
    params = _params(cfg)
    lines = memory_lines(cfg)
    lines += differential_lines(cfg, params)
    lines += sharing_lines(cfg, params)
    lines += traffic_lines(cfg, params)
    return lines


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
