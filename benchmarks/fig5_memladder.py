"""Fig. 5 (extension): cache/memory-ladder sweep across the paper CPUs.

A STREAM-triad-shaped traffic profile (2 bytes loaded : 1 byte stored
per byte of working set) is swept over working sets that resolve to
each level of every machine's memory ladder (core/memtier.py). Per
machine and per working set the table reports the home tier, the
effective load/store bandwidth of the bottleneck leg, the WA-adjusted
store traffic, and the composed ECM memory term.

The paper's qualitative WA result must survive tier resolution: the
WA-adjusted store traffic obeys Grace <= SPR <= Zen 4 at *every* tier
(Grace claims lines at every level; SpecI2M only helps SPR at a
saturated DRAM interface; Zen 4 standard stores always allocate). The
sweep asserts the ordering per working set and emits a verdict row.
"""

from __future__ import annotations

from repro.core import memtier
from repro.core.machine import get_machine

#: The three paper CPUs, innermost ordering of the WA comparison.
CPUS = ("neoverse_v2", "golden_cove", "zen4")

#: Working-set points chosen to land on L1 / L2 / L3 / DRAM for all
#: three CPUs at once (capacities differ, so points sit inside the
#: smallest respective level: Zen 4 L1 32 KiB, L2 1 MiB, L3 32 MiB).
SWEEP = (
    ("L1", 16 * 1024),
    ("L2", 256 * 1024),
    ("L3", 8 * 2**20),
    ("DRAM", 1 << 30),
)


def ladder_rows(nt_stores: bool = False) -> list:
    """One dict per (working set, machine): the fig5 ladder table.

    `store_traffic` is the WA-adjusted store traffic crossing the home
    tier's boundary for 1 byte of stored payload per 3 bytes of working
    set (the triad mix), so rows are comparable across machines.
    """
    rows = []
    for label, ws in SWEEP:
        for name in CPUS:
            m = get_machine(name)
            loads, stores = 2.0 * ws, 1.0 * ws
            res = memtier.transfer_time(
                m, ws_bytes=ws, load_bytes=loads, store_bytes=stores,
                nt_stores=nt_stores, cores_active=m.cores)
            home_leg = res.legs[-1]
            rows.append({
                "ws_label": label, "ws_bytes": ws, "machine": name,
                "home": res.home, "bottleneck": res.bottleneck_tier,
                "saturation": res.saturation,
                "load_bw": home_leg.load_bw, "store_bw": home_leg.store_bw,
                "wa_ratio": home_leg.wa_ratio,
                "store_traffic": home_leg.store_bytes,
                "ecm_seconds": res.seconds,
            })
    return rows


def ordering_ok(rows: list) -> dict:
    """{ws_label: bool} — Grace <= SPR <= Zen 4 store traffic per tier."""
    verdict = {}
    by_ws: dict = {}
    for r in rows:
        by_ws.setdefault(r["ws_label"], {})[r["machine"]] = r
    for label, per_m in by_ws.items():
        t = {n: per_m[n]["store_traffic"] for n in CPUS if n in per_m}
        verdict[label] = (
            len(t) == len(CPUS)
            and t["neoverse_v2"] <= t["golden_cove"] <= t["zen4"])
    return verdict


def main(quick: bool = False):
    """Emit the fig5 ladder table as benchmark CSV lines."""
    lines = []
    rows = ladder_rows()
    for r in rows:
        lines.append(
            f"fig5,{r['machine']}.{r['ws_label']},"
            f"{r['ecm_seconds']*1e6:.1f},"
            f"home={r['home']};bneck={r['bottleneck']};"
            f"sat={r['saturation']:.2f};"
            f"ld_bw={r['load_bw']/1e9:.1f}GB/s;"
            f"st_bw={r['store_bw']/1e9:.1f}GB/s;"
            f"wa={r['wa_ratio']:.2f};"
            f"st_traffic={r['store_traffic']/1e6:.1f}MB")
    verdicts = ordering_ok(rows)
    for label, ok in verdicts.items():
        lines.append(f"fig5,ordering_{label},0,"
                     f"grace<=spr<=zen4={'OK' if ok else 'VIOLATED'}")
    if not quick:
        # NT-store variant: Zen 4 evades fully, the ordering inverts at
        # DRAM — reported for completeness, not asserted
        for r in ladder_rows(nt_stores=True):
            lines.append(
                f"fig5,nt.{r['machine']}.{r['ws_label']},"
                f"{r['ecm_seconds']*1e6:.1f},"
                f"wa={r['wa_ratio']:.2f};"
                f"st_traffic={r['store_traffic']/1e6:.1f}MB")
    if not all(verdicts.values()):
        raise AssertionError(f"WA ladder ordering violated: {verdicts}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
