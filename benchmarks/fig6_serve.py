"""Fig. 6 (extension): serve-engine tokens/s — predicted vs measured —
with WA-priced KV-cache update traffic per machine.

The continuous-batching engine (repro.serve) decodes a smoke config on
the host; the same decode chunk's compiled HLO is fanned across every
registered machine by `portmodel.compare`, and each machine's
tier-resolved bound (`Report.tier_bound_seconds`) becomes a predicted
tokens/s. Alongside, the per-decode-step KV-update traffic is priced
through `wa.store_profile` in both regimes — donated (in-place
dynamic-update-slice) and copied (the whole-cache copy a non-donated
buffer forces, the system-scale write allocate of DESIGN.md §2) — so
the donation delta is reported per machine in bytes per step.

The host measurement is a functional smoke + sanity anchor, not a
validation of the cross-vendor predictions (this container is not a
Grace/SPR/Genoa socket); the record keeps both sides so a run on real
hardware can score them (paper Fig. 3 methodology).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.machine import get_machine, registered_names
from repro.models import model as M
from repro.serve import Request, ServeEngine, decode_step_hlo
from repro.serve.kv_traffic import kv_update_traffic
from repro.serve.planner import plan_chunk_size

ARCH = "gemma3-4b"           # local+global attention: both cache kinds
BATCH, PROMPT = 4, 16


def serve_record(gen: int = 32) -> dict:
    """Run the engine once and assemble the fig6 record."""
    cfg = get_smoke_config(ARCH)
    max_len = PROMPT + gen
    key = jax.random.PRNGKey(0)
    k_params, k_prompts = jax.random.split(key)
    params = M.init_params(cfg, k_params)
    prompts = np.asarray(jax.random.randint(
        k_prompts, (BATCH, PROMPT), 0, cfg.vocab_size))

    hlo1 = decode_step_hlo(cfg, BATCH, max_len, n_tokens=1)
    plan = plan_chunk_size(cfg, BATCH, max_len, hlo_text=hlo1,
                           max_chunk=min(16, gen - 1))
    eng = ServeEngine(cfg, params, max_slots=BATCH, max_len=max_len,
                      chunk=plan.chunk)
    reqs = [Request(rid=str(i), prompt=tuple(int(t) for t in prompts[i]),
                    max_new_tokens=gen) for i in range(BATCH)]
    eng.run(list(reqs))                # warm-up: compile prefill + decode
    eng.decode_dispatches = eng.prefill_dispatches = 0
    t0 = time.time()
    out = eng.run(list(reqs))          # slots all retired: re-admit
    dt = time.time() - t0
    assert all(len(v) == gen for v in out.values())

    measured_tok_s = BATCH * gen / dt
    # predicted: per-machine tier-resolved seconds of one 1-token decode
    # step; a chunk of n costs n steps (the scan floor multiplies trips)
    pred = {name: BATCH / max(t, 1e-12)
            for name, t in plan.per_machine.items()}
    kv = kv_update_traffic(cfg, BATCH, max_len)
    return {"arch": ARCH, "batch": BATCH, "gen": gen,
            "chunk": plan.chunk, "plan_machine": plan.machine,
            "dispatches": eng.decode_dispatches,
            "measured_tok_s": measured_tok_s, "wall_s": dt,
            "pred_tok_s": pred, "kv_rows": kv}


def main(quick: bool = False):
    """Emit the fig6 serve table as benchmark CSV lines."""
    rec = serve_record(gen=16 if quick else 32)
    lines = [
        f"fig6,measured.host,{rec['wall_s']*1e6:.0f},"
        f"tok_s={rec['measured_tok_s']:.1f};arch={rec['arch']};"
        f"batch={rec['batch']};gen={rec['gen']};chunk={rec['chunk']};"
        f"dispatches={rec['dispatches']};plan={rec['plan_machine']}"
    ]
    kv_by_machine = {r["machine"]: r for r in rec["kv_rows"]}
    for name in registered_names():
        if name not in rec["pred_tok_s"]:
            continue
        t_step = 1.0 / rec["pred_tok_s"][name] * rec["batch"]
        kv = kv_by_machine.get(name)
        kv_part = (f"kv_donated={kv['donated_bytes']/1e3:.1f}kB;"
                   f"kv_copied={kv['copied_bytes']/1e6:.2f}MB;"
                   f"kv_delta={kv['delta_bytes']/1e6:.2f}MB;"
                   f"wa_mode={kv['wa_mode']}" if kv else "kv=n/a")
        lines.append(
            f"fig6,pred.{name},{t_step*1e6:.1f},"
            f"tok_s={rec['pred_tok_s'][name]:.0f};{kv_part}")
    # the WA story must hold on the serve path: donation strictly cheaper
    # than copying on every machine
    bad = [r["machine"] for r in rec["kv_rows"]
           if not r["delta_bytes"] > 0]
    lines.append(f"fig6,donation_delta,0,"
                 f"positive_on_all={'OK' if not bad else bad}")
    if bad:
        raise AssertionError(f"donation delta non-positive on: {bad}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
