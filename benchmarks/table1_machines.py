"""Paper Table I analog: core-feature comparison of the three target TPU
generations + the measured host envelope (theoretical vs achieved peak)."""

from __future__ import annotations

from repro.core.ubench import calibrated_host_model, host_peaks, mem_tiers
from repro.utils.hw import CHIPS, CPU_CHIPS


def rows():
    out = []
    for name in ("tpu_v5e", "tpu_v4", "tpu_v5p"):
        c = CHIPS[name]
        out.append({
            "machine": name,
            "bf16_tflops": c.bf16_flops / 1e12,
            "hbm_gb": c.hbm_bytes / 1e9,
            "hbm_gbs": c.hbm_bw / 1e9,
            "ici_gbs_per_link": c.ici_link_bw / 1e9,
            "vmem_mb": c.vmem_bytes / 2**20,
            "clock_ghz": c.clock_hz / 1e9,
            "mxu": c.n_mxu, "vpu": c.n_vpu,
        })
    calibrated_host_model()
    peak, bw = host_peaks()
    out.append({
        "machine": "host_cpu(measured)",
        "bf16_tflops": peak / 1e12,       # f32 matmul achieved
        "hbm_gb": 0, "hbm_gbs": bw / 1e9,
        "ici_gbs_per_link": 0, "vmem_mb": 0, "clock_ghz": 1.0,
        "mxu": 1, "vpu": 1,
    })
    return out


def cpu_rows():
    """Paper Table I: the three actual CPUs, per-core FP32 peak."""
    out = []
    for c in CPU_CHIPS.values():
        lanes = c.simd_width_bytes / 4
        core_gflops = 2 * c.n_fma * lanes * c.clock_hz / 1e9
        out.append({
            "machine": c.name,
            "core_gflops_f32": core_gflops,
            "socket_tflops_f32": core_gflops * c.cores / 1e3,
            "mem_gbs": c.mem_bw / 1e9,
            "clock_ghz": c.clock_hz / 1e9,
            "cores": c.cores, "wa_mode": c.wa_mode,
        })
    return out


def main(quick: bool = False):
    lines = []
    for r in rows():
        lines.append(
            f"table1,{r['machine']},0,"
            f"tflops={r['bf16_tflops']:.1f};bw={r['hbm_gbs']:.0f}GB/s;"
            f"ici={r['ici_gbs_per_link']:.0f}GB/s;clock={r['clock_ghz']:.2f}GHz")
    for r in cpu_rows():
        lines.append(
            f"table1,{r['machine']},0,"
            f"core_gflops={r['core_gflops_f32']:.0f};"
            f"socket_tflops={r['socket_tflops_f32']:.1f};"
            f"bw={r['mem_gbs']:.0f}GB/s;clock={r['clock_ghz']:.2f}GHz;"
            f"cores={r['cores']};wa={r['wa_mode']}")
    def _cap(c):
        return str(int(c)) if c != float("inf") else "inf"

    tiers = ";".join(
        f"{t.name}[{_cap(t.capacity_bytes)}]:"
        f"{(t.load_bw + t.store_bw)/1e9:.1f}GB/s" for t in mem_tiers())
    lines.append(f"table1,host_mem_tiers,0,{tiers}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
