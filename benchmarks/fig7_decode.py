"""Fig. 7 (extension): split-KV decode cost scales with cache occupancy,
not horizon — predicted vs measured, dense vs kernel path.

The serve engine preallocates KV slots at the full decode horizon, so
the dense decode path reads and masks every ``max_len`` cache row per
slot per token regardless of how full the cache is. The split-KV
flash-decode path (repro.kernels.attention) bounds that traffic by
occupancy: KV blocks wholly beyond a slot's position are skipped, so a
step at 12% occupancy moves ~12% of the bytes. This benchmark sweeps
cache occupancy x batch on the host and records, per cell:

* measured per-step decode time and tokens/s of the dense path and of
  the occupancy-bounded kernel path (both through the serve chunked
  decode step — the real dispatch, cache donation included);
* the per-machine *predicted* step times for both paths
  (``serve.planner.plan_chunk_size`` with and without ``occupancy``);
* the per-machine predicted KV-read traffic ratio dense/split
  (``serve.kv_traffic.decode_read_traffic``) — the WA-lesson headline
  number, > 1 whenever the cache is not full.

Two assertions gate CI: the measured split-path step cost must grow
with occupancy while beating the dense path at occupancy <= 25% of the
horizon, and the predicted read ratio must exceed 1 on all three paper
CPUs. As with fig6, the host measurement is a functional anchor, not a
cross-vendor validation — the record keeps predicted and measured side
by side so real hardware can score them (paper Fig. 3 methodology).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serve.decode import make_chunked_decode_step
from repro.serve.kv_traffic import decode_read_traffic
from repro.serve.planner import kv_read_seconds, plan_chunk_size

ARCH = "yi-9b"                 # pure-GQA attention stack: clean KV story
PAPER_CPUS = ("zen4", "golden_cove", "neoverse_v2")


#: tokens per measured dispatch — amortizes the multi-ms CPU dispatch
#: overhead so the per-step attention term is the signal, not the noise
CHUNK = 8


def _measure_pair(steps: dict, params, caches: dict, tok, pos, key,
                  iters: int) -> dict:
    """Best-of-N wall seconds per path, sampled *interleaved* (A/B/A/B)
    so container load drift hits both paths alike; min is the
    noise-robust estimator for container microbenchmarks."""
    for _ in range(3):                                       # compile + warm
        for name, fn in steps.items():
            toks, caches[name], _ = fn(params, caches[name], tok, pos,
                                       key)
            jax.block_until_ready(toks)
    times = {name: [] for name in steps}
    for _ in range(iters):
        for name, fn in steps.items():
            t0 = time.perf_counter()
            toks, caches[name], _ = fn(params, caches[name], tok, pos,
                                       key)
            jax.block_until_ready(toks)
            times[name].append(time.perf_counter() - t0)
    return {name: float(np.min(ts)) for name, ts in times.items()}


def decode_record(batch: int, max_len: int, occupancies: tuple,
                  iters: int = 20) -> dict:
    """Measure dense vs split-KV decode dispatches across occupancies.

    Each dispatch decodes a CHUNK-token in-graph chunk whose last token
    lands at the cell's occupancy; recorded times are per *token*.
    """
    cfg = get_smoke_config(ARCH)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tok = jnp.zeros((batch, 1), jnp.int32)
    dense_step = jax.jit(make_chunked_decode_step(cfg, CHUNK),
                         donate_argnums=(1,))
    cells = []
    for occ in occupancies:
        pos = jnp.full((batch,), occ - CHUNK, jnp.int32)
        split_step = jax.jit(
            make_chunked_decode_step(cfg, CHUNK, attn_impl="auto",
                                     kv_len=occ),
            donate_argnums=(1,))
        t = _measure_pair(
            {"dense": dense_step, "split": split_step}, params,
            {"dense": M.init_cache(cfg, batch, max_len),
             "split": M.init_cache(cfg, batch, max_len)},
            tok, pos, key, iters)
        t_dense, t_split = t["dense"] / CHUNK, t["split"] / CHUNK
        plan_split = plan_chunk_size(cfg, batch, max_len, occupancy=occ)
        cells.append({
            "occ": occ, "occ_frac": occ / max_len,
            "t_dense": t_dense, "t_split": t_split,
            "tok_s_dense": batch / t_dense, "tok_s_split": batch / t_split,
            "pred_split": dict(plan_split.per_machine),
            "pred_dense": dict(plan_split.per_machine_dense),
        })
    kv = decode_read_traffic(cfg, batch, max_len,
                             max(1, occupancies[0]))
    return {"arch": ARCH, "batch": batch, "max_len": max_len,
            "cells": cells, "kv_rows": kv}


def paper_scale_lines(batch: int = 8, max_len: int = 4096,
                      occ: int = 512) -> list:
    """Per-machine predicted KV-stream seconds at the *published* model
    scale (no lowering/measurement — pure ladder arithmetic), where the
    KV term actually dominates the decode step and the dense-vs-split
    gap is the figure's headline."""
    cfg = get_config(ARCH)
    lines = []
    for name in PAPER_CPUS:
        t_dense = kv_read_seconds(cfg, batch, max_len, name,
                                  max_len=max_len)
        t_split = kv_read_seconds(cfg, batch, occ, name, max_len=max_len)
        lines.append(
            f"fig7,pred_kv_full.{name},{t_split*1e6:.0f},"
            f"dense_us={t_dense*1e6:.0f};"
            f"speedup={t_dense/max(t_split, 1e-12):.2f};"
            f"arch={ARCH};batch={batch};max_len={max_len};occ={occ}")
    return lines


def main(quick: bool = False):
    """Emit the fig7 decode table as benchmark CSV lines."""
    max_len = 1024 if quick else 2048
    occupancies = tuple(max_len * f // 16 for f in (1, 4, 8, 16))
    batches = (4,) if quick else (2, 4)
    lines = []
    for batch in batches:
        rec = decode_record(batch, max_len, occupancies,
                            iters=10 if quick else 20)
        for c in rec["cells"]:
            tag = f"b{batch}.occ{c['occ']}"
            lines.append(
                f"fig7,measured.dense.{tag},{c['t_dense']*1e6:.0f},"
                f"tok_s={c['tok_s_dense']:.1f};occ_frac={c['occ_frac']:.2f}")
            lines.append(
                f"fig7,measured.split.{tag},{c['t_split']*1e6:.0f},"
                f"tok_s={c['tok_s_split']:.1f};occ_frac={c['occ_frac']:.2f}")
            for name in PAPER_CPUS:
                if name not in c["pred_split"]:
                    continue
                lines.append(
                    f"fig7,pred.{name}.{tag},"
                    f"{c['pred_split'][name]*1e6:.2f},"
                    f"dense_us={c['pred_dense'][name]*1e6:.2f};"
                    f"speedup={c['pred_dense'][name]/c['pred_split'][name]:.2f}")
        for r in rec["kv_rows"]:
            if r["machine"] not in PAPER_CPUS:
                continue
            lines.append(
                f"fig7,kv_ratio.b{batch}.{r['machine']},0,"
                f"dense_over_split={r['read_ratio']:.2f};bk={r['bk']};"
                f"n_splits={r['n_splits']};occ={r['occupancy']}")

        # gates: occupancy-bounded cost must (a) grow with occupancy,
        # (b) beat the dense path while the cache is <= 25% full, and
        # (c) save predicted KV reads on every paper CPU
        cells = rec["cells"]
        lo, hi = cells[0], cells[-1]
        if not lo["t_split"] < hi["t_split"]:
            raise AssertionError(
                f"split cost not occupancy-bound: {lo['t_split']:.2e}s at "
                f"occ {lo['occ']} vs {hi['t_split']:.2e}s at {hi['occ']}")
        bad = [c["occ"] for c in cells
               if c["occ_frac"] <= 0.25 and not c["t_split"] < c["t_dense"]]
        if bad:
            raise AssertionError(
                f"split path loses to dense at low occupancy: {bad}")
        bad = [r["machine"] for r in rec["kv_rows"]
               if r["machine"] in PAPER_CPUS and not r["read_ratio"] > 1]
        if bad:
            raise AssertionError(f"KV read ratio <= 1 on: {bad}")
        lines.append(f"fig7,gates.b{batch},0,"
                     f"occupancy_bound=OK;low_occ_beats_dense=OK;"
                     f"kv_ratio_gt1=OK")
    lines.extend(paper_scale_lines())
    return lines


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
