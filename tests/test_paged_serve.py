"""Paged-KV serve path: property-based differential tests.

Three layers, mirroring the subsystem's own:

* **PagePool invariants** — randomized admit/write/fork/release
  schedules against a host-side contents model: refcounts conserve
  exactly (``check_conservation``), copy-on-write never mutates a page
  another holder can see, prefix matches always hand back pages holding
  the expected chain content, and releasing everything leaks nothing.
* **Engine differential** — randomized admission/decode/cancel
  schedules applied to a dense :class:`ServeEngine` and a
  :class:`PagedServeEngine` must produce token-identical streams (the
  paged ref decode path falls through to the same dense computation, so
  equality is exact, not approximate). Fork clones must continue
  exactly like their greedy parent, and CoW must leave the parent
  stream untouched.
* **MemTier pricing** — the paged traffic classes stay finite, ordered
  Grace <= SPR <= Zen 4 (the WA-priced store side), and recycled
  admission strictly undercuts the dense zero-fill on every registered
  machine.

Runs under real hypothesis or the deterministic stub
(tests/_hypothesis_stub.py) — conftest tags each test with the engine
that drove it.
"""

import functools
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core.machine import registered_names
from repro.models import model as M
from repro.serve import (PagedServeEngine, PagePool, Request, ServeEngine,
                         cow_fork_traffic, make_chunked_decode_step,
                         page_admission_traffic, page_gather_traffic,
                         plan_chunk_size)
from repro.serve import pages as PG

PAPER_CPUS = ["neoverse_v2", "golden_cove", "zen4"]
PS = 4                                   # page size used throughout
MAX_LEN = 24
CHUNK = 3
SLOTS = 2


# plain cached helpers instead of pytest fixtures: @given-wrapped tests
# (stub or real) cannot take fixture parameters through the wrapper
@functools.lru_cache(maxsize=None)
def _cfg():
    return get_smoke_config("yi-9b")     # dense FFN: streams bit-exact


@functools.lru_cache(maxsize=None)
def _params():
    return M.init_params(_cfg(), jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _engines():
    """One dense/paged pair reused across examples (compile once).

    Reuse is safe — and deliberate: after a drained schedule both
    engines have every slot free, and the paged pool's only residue is
    its retained prefix index, so later examples exercise cross-example
    prefix sharing on top of the differential check.
    """
    kw = dict(max_slots=SLOTS, max_len=MAX_LEN, chunk=CHUNK, seed=0)
    return (ServeEngine(_cfg(), _params(), **kw),
            PagedServeEngine(_cfg(), _params(), page_size=PS, **kw))


# a small closed set of prompts: repeats trigger prefix sharing, jit
# retraces stay bounded by the distinct lengths. Ids must stay inside
# the smoke vocab (512): OOB ids NaN-fill the embedding gather, which
# used to make BOTH engines emit all-NaN logits (greedy argmax -> 0 on
# each, so the differential held vacuously); admission now rejects
# them and the serve guard quarantines any stream that slips through.
_PROMPT_RNG = np.random.default_rng(42)
PROMPTS = [tuple(int(t) for t in _PROMPT_RNG.integers(0, 512, n))
           for n in (3, 4, 6, 8, 8, 9)]


# ---------------------------------------------------------------------------
# PagePool invariants under random schedules (host-only, no device work)
# ---------------------------------------------------------------------------

def _chain_val(prompt, j, ps=PS):
    """Model content of full prompt page j: its chain prefix."""
    return ("chain", prompt[:(j + 1) * ps])


_POOL_OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 5), st.integers(0, 7)),
    min_size=1, max_size=50)


@given(_POOL_OPS)
def test_pool_schedule_invariants(ops):
    """Random admit/write/fork/release schedules conserve the pool and
    never let a write reach a page another holder still sees."""
    n_pages = 10
    pool = PagePool(n_pages, PS)
    contents: dict = {}                   # phys -> model payload
    holders: list = []                    # [{"pages", "prompt", "view"}]
    stamp = 0
    for kind, a, b in ops:
        kind %= 5
        if kind == 0:                                     # admit
            prompt = PROMPTS[a % len(PROMPTS)]
            npg = -(-len(prompt) // PS)
            if pool.available() < npg:
                continue
            shared = pool.match_prefix(prompt)
            for j, p in enumerate(shared):                # matched pages
                assert contents[p] == _chain_val(prompt, j), \
                    f"stale prefix match on page {p}"
            fresh = pool.allocate(npg - len(shared))
            held = list(shared) + list(fresh)
            full = len(prompt) // PS
            view = {}
            for j in range(npg):
                if j >= len(shared):
                    contents[held[j]] = (_chain_val(prompt, j)
                                         if j < full else ("partial", stamp))
                    stamp += 1
                view[j] = contents[held[j]]
            pool.register_prefix(prompt, held[:full])
            holders.append({"pages": held, "prompt": prompt, "view": view})
        elif kind == 1 and holders:                       # release
            h = holders.pop(a % len(holders))
            pool.release(h["pages"])
        elif kind == 2 and holders:                       # fork
            h = holders[a % len(holders)]
            pool.fork(h["pages"])
            holders.append({"pages": list(h["pages"]),
                            "prompt": h["prompt"],
                            "view": dict(h["view"])})
        elif kind == 3 and holders:                       # write (maybe CoW)
            h = holders[a % len(holders)]
            lg = b % len(h["pages"])
            if pool.available() < 1:
                continue
            page, copied = pool.prepare_write(h["pages"][lg])
            if copied:
                contents[page] = contents[h["pages"][lg]]
                h["pages"][lg] = page
            contents[page] = ("w", stamp)
            h["view"][lg] = contents[page]
            stamp += 1
        else:                                             # audit
            pool.check_conservation([h["pages"] for h in holders])
        # CoW soundness: every holder still sees exactly its own view
        for h in holders:
            for lg, p in enumerate(h["pages"]):
                assert contents[p] == h["view"][lg], \
                    f"holder view of logical page {lg} mutated"
    pool.check_conservation([h["pages"] for h in holders])
    for h in holders:                     # full teardown leaks nothing
        pool.release(h["pages"])
    pool.check_conservation([])


def test_pool_exhaustion_and_lru_eviction():
    pool = PagePool(2, PS)
    prompt = PROMPTS[1]                   # 4 tokens = 1 full page
    held = pool.match_prefix(prompt) or pool.allocate(1)
    pool.register_prefix(prompt, held[:1])
    pool.release(held)                    # page survives as retained index
    assert pool.available() == 2          # 1 free + 1 evictable
    got = pool.allocate(2)                # forces the LRU eviction
    assert len(got) == 2
    assert pool.stats["evictions"] == 1
    assert pool.match_prefix(prompt) == []   # evicted = no longer matchable
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.allocate(1)
    pool.release(got)
    with pytest.raises(RuntimeError, match="unheld"):
        pool.release(got[:1])


# ---------------------------------------------------------------------------
# Engine differential: paged == dense, token for token
# ---------------------------------------------------------------------------

def _apply_schedule(eng, sched):
    """Deterministically interpret one schedule; returns {rid: tokens}."""
    results, rid = {}, 0
    for kind, a, b in sched:
        kind %= 3
        if kind == 0 and eng.free_slots():                # admit
            prompt = PROMPTS[a % len(PROMPTS)]
            budget = 1 + b % 8
            eng.admit(Request(f"r{rid}", prompt, budget))
            rid += 1
        elif kind == 1:                                   # decode round
            for r, toks in eng.step():
                results[r] = toks
        elif kind == 2:                                   # cancel
            act = sorted(s.rid for s in eng.slots if s is not None)
            if act:
                r = act[a % len(act)]
                results[r] = eng.cancel(r)
    while any(s is not None for s in eng.slots):          # drain
        for r, toks in eng.step():
            results[r] = toks
    return results


_ENGINE_OPS = st.lists(
    st.tuples(st.integers(0, 2), st.integers(0, 5), st.integers(0, 7)),
    min_size=2, max_size=10)


@given(_ENGINE_OPS)
def test_paged_engine_differential(ops):
    """The same admission/decode/cancel schedule on dense and paged
    engines yields identical rids and bit-identical token streams."""
    dense, paged = _engines()
    rd = _apply_schedule(dense, ops)
    rp = _apply_schedule(paged, ops)
    paged.check_pool()
    assert set(rd) == set(rp)
    for r in rd:
        np.testing.assert_array_equal(
            rd[r], rp[r], err_msg=f"stream {r} diverged under paging")


def test_shared_prefix_admission_copies_nothing():
    """Identical prompts map the same physical pages: the second
    admission allocates only the partial page and copies zero pages."""
    eng = PagedServeEngine(_cfg(), _params(), max_slots=SLOTS, max_len=MAX_LEN,
                           chunk=CHUNK, page_size=PS)
    prompt = PROMPTS[4]                   # 8 tokens = 2 full pages
    eng.admit(Request("a", prompt, 2))
    before = dict(eng.pool.stats)
    eng.admit(Request("b", prompt, 2))
    after = eng.pool.stats
    assert after["shared_maps"] - before["shared_maps"] == 2
    assert after["cow_copies"] == before["cow_copies"] == 0
    allocs = (after["fresh_allocs"] + after["recycled_allocs"]
              - before["fresh_allocs"] - before["recycled_allocs"])
    assert allocs == 0                    # fully shared: no new pages
    assert list(eng.block_tables[0][:2]) == list(eng.block_tables[1][:2])
    res = eng.run([])
    eng.check_pool()
    assert np.array_equal(res["a"], res["b"])


def test_fork_cow_parent_stream_undisturbed():
    """A forked clone decodes exactly like its parent (greedy), CoW
    fires on the shared partial page, and the parent's stream matches a
    solo dense run bit for bit."""
    prompt = PROMPTS[5]                   # 9 tokens: partial last page
    eng = PagedServeEngine(_cfg(), _params(), max_slots=SLOTS, max_len=MAX_LEN,
                           chunk=CHUNK, page_size=PS)
    eng.admit(Request("x", prompt, 8))
    eng.fork("x", "y")
    res = _apply_schedule(eng, [])
    eng.check_pool()
    assert eng.pool.stats["cow_copies"] >= 1
    np.testing.assert_array_equal(res["x"], res["y"])
    dense = ServeEngine(_cfg(), _params(), max_slots=SLOTS, max_len=MAX_LEN,
                        chunk=CHUNK)
    ref = dense.run([Request("x", prompt, 8)])
    np.testing.assert_array_equal(res["x"], ref["x"])


def test_cancel_recycles_pages():
    eng = PagedServeEngine(_cfg(), _params(), max_slots=SLOTS, max_len=MAX_LEN,
                           chunk=CHUNK, page_size=PS, share_prefixes=False)
    eng.admit(Request("a", PROMPTS[3], 8))
    held = [int(p) for p in eng.block_tables[0] if p >= 0]
    assert held
    out = eng.cancel("a")
    assert out is not None and out.shape[0] >= 1
    assert eng.cancel("a") is None
    eng.check_pool()
    assert all(eng.pool.refcount[p] == 0 for p in held)
    eng.admit(Request("b", PROMPTS[3], 4))      # recycles, never zero-fills
    assert eng.pool.stats["recycled_allocs"] >= 1
    res = eng.run([])
    dense = ServeEngine(_cfg(), _params(), max_slots=SLOTS, max_len=MAX_LEN,
                        chunk=CHUNK)
    ref = dense.run([Request("b", PROMPTS[3], 4)])
    np.testing.assert_array_equal(res["b"], ref["b"])


# ---------------------------------------------------------------------------
# Paged decode step: donation stays in place
# ---------------------------------------------------------------------------

def test_paged_decode_cache_update_stays_in_place():
    """The paged chunk step must not copy the page pools per dispatch:
    donation aliases them exactly like the dense cache leaves."""
    n_pages, pps = SLOTS * (MAX_LEN // PS) + 1, MAX_LEN // PS
    step = make_chunked_decode_step(_cfg(), CHUNK, paged=True)
    cshapes = PG.paged_cache_shapes(_cfg(), SLOTS, n_pages, PS)
    args = (M.param_shapes(_cfg()), cshapes,
            jax.ShapeDtypeStruct((SLOTS, pps), jnp.int32),
            jax.ShapeDtypeStruct((SLOTS, 1), jnp.int32),
            jax.ShapeDtypeStruct((SLOTS,), jnp.int32),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    kv_leaf = jax.tree.leaves(cshapes)[0]
    sig = "bf16[" + ",".join(str(d) for d in kv_leaf.shape) + "]"

    def arg_copies(txt):
        return [ln for ln in txt.splitlines()
                if re.search(r"= " + re.escape(sig) + r"\S* copy\(", ln)
                and "%Arg_" in ln]

    donated = jax.jit(step, donate_argnums=(1,)).lower(
        *args).compile().as_text()
    plain = jax.jit(step).lower(*args).compile().as_text()
    assert "input_output_alias" in donated
    assert len(arg_copies(plain)) >= 2      # detector sanity: K and V pools
    assert len(arg_copies(donated)) == 0    # in-place with donation


# ---------------------------------------------------------------------------
# MemTier pricing of the paged traffic classes
# ---------------------------------------------------------------------------

def test_page_gather_pricing_ordered_and_bounded():
    rows = page_gather_traffic(_cfg(), 4, 256, 64, 8, machines=PAPER_CPUS)
    by = {r["machine"]: r for r in rows}
    assert set(by) == set(PAPER_CPUS)
    for r in rows:
        assert r["read_ratio"] > 1.0         # quarter-full cache: 4x fewer
        assert r["gather_seconds"] > 0.0
        assert r["table_read_bytes"] < r["gather_read_bytes"]
    # paper ordering rides on the WA-priced store side of the step
    assert (by["neoverse_v2"]["total_bytes"]
            <= by["golden_cove"]["total_bytes"]
            <= by["zen4"]["total_bytes"])
    # full cache: the gather equals the dense payload exactly; the only
    # overhead left is the block-table entries themselves, so the ratio
    # sits just below 1 (the dense path never issues that dependent load)
    full = page_gather_traffic(_cfg(), 4, 256, 256, 8, machines=PAPER_CPUS)
    for r in full:
        assert r["gather_read_bytes"] == r["dense_read_bytes"]
        assert 0.99 < r["read_ratio"] < 1.0


def test_cow_pricing_grace_cheapest():
    rows = cow_fork_traffic(_cfg(), 8, n_copies=3, machines=PAPER_CPUS)
    by = {r["machine"]: r for r in rows}
    for r in rows:
        assert r["total_bytes"] >= 2 * r["read_bytes"] - 1e-9  # r+w floor
        assert r["copy_seconds"] > 0.0
    assert (by["neoverse_v2"]["total_bytes"]
            <= by["golden_cove"]["total_bytes"]
            <= by["zen4"]["total_bytes"])


def test_recycled_admission_beats_zero_fill_everywhere():
    """On every registered machine, admitting into recycled pages is
    strictly cheaper than the dense horizon zero-fill whenever the
    prompt's pages cover less than the horizon."""
    rows = page_admission_traffic(_cfg(), 20, 256, 8,
                                  machines=registered_names())
    assert len(rows) >= 3
    for r in rows:
        assert r["recycled_bytes"] < r["zero_fill_bytes"], r["machine"]
        assert r["recycled_bytes"] <= r["fresh_bytes"]
        assert r["savings_ratio"] > 1.0
    # sharing shrinks it further; full sharing stores nothing
    shared = page_admission_traffic(_cfg(), 16, 256, 8, shared_pages=2,
                                    machines=PAPER_CPUS)
    for r in shared:
        assert r["shared_pages"] == 2
        assert r["recycled_bytes"] < rows[0]["zero_fill_bytes"]
    allshared = page_admission_traffic(_cfg(), 16, 256, 8, shared_pages=4,
                                       machines=PAPER_CPUS)
    assert all(r["recycled_bytes"] == 0.0 for r in allshared)


def test_planner_threads_page_size():
    dense = plan_chunk_size(_cfg(), 4, 256, occupancy=40)
    paged = plan_chunk_size(_cfg(), 4, 256, occupancy=40, page_size=8)
    assert dense.page_size is None and paged.page_size == 8
    assert paged.chunk >= 1
    # page-grid rounding can only tighten the bound vs the dense KV
    # block (pages are <= the autotuned block in every current tuning)
    for name, t in paged.per_machine.items():
        assert t <= dense.per_machine[name] + 1e-12


def test_paged_memory_scales_with_pool_not_horizon():
    """fig8's sizing gate at unit scale: dense KV bytes grow with the
    horizon, the page pool's with live pages only."""
    d1 = PG.dense_kv_bytes(_cfg(), 4, 256)
    d2 = PG.dense_kv_bytes(_cfg(), 4, 512)
    assert d2 == 2 * d1
    p1 = PG.paged_kv_bytes(_cfg(), 32, 8)
    assert PG.paged_kv_bytes(_cfg(), 32, 8) == p1   # horizon-free
    assert PG.paged_kv_bytes(_cfg(), 64, 8) == 2 * p1
    # pool sized for the live tokens of 4 quarter-full slots beats the
    # dense allocation by ~4x
    live_pages = 4 * (64 // 8)
    assert d1 / PG.paged_kv_bytes(_cfg(), live_pages, 8) > 3.9
