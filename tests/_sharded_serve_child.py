"""Child process for the 2-device sharded token-identity tests.

Must run under ``XLA_FLAGS=--xla_force_host_platform_device_count=2``
(jax pins the device count at first init, so the parent test cannot
flip it in-process). Serves the same request list twice — unsharded,
then TP-sharded over a (1, 2) mesh — and prints a JSON verdict the
parent asserts on.

Usage: python tests/_sharded_serve_child.py {dense|paged}
"""

import json
import sys

import jax
import numpy as np


def main() -> None:
    layout = sys.argv[1]
    assert jax.device_count() == 2, \
        f"need 2 forced host devices, have {jax.device_count()}"
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serve import PagedServeEngine, Request, ServeEngine

    cfg = get_smoke_config("yi-9b")     # GQA: 4 q heads over 2 kv heads
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"r{i}",
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, 5 + i)),
                    max_new_tokens=4) for i in range(3)]
    cls = ServeEngine if layout == "dense" else PagedServeEngine
    kw = {} if layout == "dense" else {"page_size": 4}
    mesh = jax.make_mesh((1, 2), ("data", "model"))

    base = cls(cfg, params, max_slots=2, max_len=24, chunk=2,
               **kw).run(list(reqs))
    eng = cls(cfg, params, max_slots=2, max_len=24, chunk=2, mesh=mesh,
              **kw)
    sharded = eng.run(list(reqs))
    print(json.dumps({
        "layout": layout,
        "tp": eng.tp,
        "match": all(np.array_equal(base[r.rid], sharded[r.rid])
                     for r in reqs),
        "tokens": {r.rid: sharded[r.rid].tolist() for r in reqs},
    }))


if __name__ == "__main__":
    main()
