"""Mesh-sharded serving: planner sharding keys, per-shard pricing,
mesh=None bit-identity, spec properties, and 2-device token identity.

The 2-device tests run the engines in a subprocess because jax pins
the host device count at first init — the suite process has already
initialized jax on one device by the time these tests run.
"""

import json
import os
import subprocess
import sys
import types

import jax
import numpy as np
import pytest
from hypothesis import given, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M
from repro.serve import (PagedServeEngine, Request, ServeEngine,
                         collective_traffic, kv_read_seconds,
                         plan_chunk_size)
from repro.serve import planner as planner_lib
from repro.utils.sharding import (SERVE_ENGINE_RULES, rules_fingerprint,
                                  spec_for, tp_degree)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_mesh(data=1, model=2):
    """Mesh stand-in for planner tests: only axis names/sizes are read
    (the planner never places arrays), so no real devices are needed."""
    return types.SimpleNamespace(
        axis_names=("data", "model"),
        devices=types.SimpleNamespace(shape=(data, model)))


@pytest.fixture()
def cfg():
    return get_smoke_config("yi-9b")     # 4 q heads / 2 kv heads: TP=2 ok


# -- planner ---------------------------------------------------------------
def test_plan_cache_keys_on_sharding(cfg):
    """Regression: the memo key must fold mesh sizes/rules/TP — a
    sharded plan must never serve an unsharded admission (and vice
    versa), which is exactly what happened when the key ignored
    sharding."""
    planner_lib.clear_plan_cache()
    p0 = plan_chunk_size(cfg, 2, 32)
    ps = plan_chunk_size(cfg, 2, 32, mesh=_fake_mesh())
    assert p0.tp == 1 and ps.tp == 2
    assert ps is not p0
    # both entries memo-hit their own key
    assert plan_chunk_size(cfg, 2, 32) is p0
    assert plan_chunk_size(cfg, 2, 32, mesh=_fake_mesh()) is ps
    # and a different TP degree is a third entry
    p4 = plan_chunk_size(cfg, 2, 32, mesh=_fake_mesh(model=4))
    assert p4.tp == 4 and p4 is not ps


def test_unsharded_plan_is_bit_identical_to_pre_mesh_planner(cfg):
    """mesh=None pins the single-device pricing exactly: no TP, no
    collective, no dense-adjustment pass."""
    planner_lib.clear_plan_cache()
    p = plan_chunk_size(cfg, 2, 32)
    assert p.tp == 1
    assert p.per_machine_collective is None
    assert p.per_machine_dense is None          # no occupancy, no adjust
    # explicit rules without a mesh are equally inert
    planner_lib.clear_plan_cache()
    q = plan_chunk_size(cfg, 2, 32)
    assert q.per_machine == p.per_machine
    assert q.chunk == p.chunk


def test_sharded_plan_prices_shard_stream_and_collective(cfg):
    planner_lib.clear_plan_cache()
    p0 = plan_chunk_size(cfg, 2, 32)
    ps = plan_chunk_size(cfg, 2, 32, mesh=_fake_mesh())
    assert ps.per_machine_collective
    assert set(ps.per_machine_collective) == set(ps.per_machine)
    for name in ps.per_machine:
        # per-shard KV stream can only shrink the step; the collective
        # adds back a (much smaller, at these shapes) reduce term
        assert ps.per_machine[name] <= p0.per_machine[name] + \
            ps.per_machine_collective[name] + 1e-18


def test_kv_read_seconds_scales_per_shard(cfg):
    for m in ("neoverse_v2", "golden_cove", "zen4"):
        t1 = kv_read_seconds(cfg, 2, 32, m, max_len=32)
        t1_explicit = kv_read_seconds(cfg, 2, 32, m, max_len=32, tp=1)
        t2 = kv_read_seconds(cfg, 2, 32, m, max_len=32, tp=2)
        assert t1 == t1_explicit
        assert t2 < t1


# -- collective pricing ----------------------------------------------------
def test_collective_traffic_machine_ordering(cfg):
    """WA residues on the ring's store legs keep the paper ordering
    Grace <= SPR <= Zen 4 per shard."""
    rows = {r["machine"]: r for r in collective_traffic(cfg, 4, 2)}
    grace = rows["neoverse_v2"]["coll_bytes"]
    spr = rows["golden_cove"]["coll_bytes"]
    zen4 = rows["zen4"]["coll_bytes"]
    assert grace <= spr <= zen4
    assert grace < zen4                  # WA evasion is a strict win


def test_collective_traffic_tp1_is_free(cfg):
    for r in collective_traffic(cfg, 4, 1):
        assert r["ring_bytes"] == 0.0
        assert r["coll_seconds"] == 0.0


def test_tp_degree_reads_rules():
    assert tp_degree({"data": 4, "model": 2}) == 2
    assert tp_degree({"data": 4}) == 1
    assert tp_degree({}) == 1
    assert tp_degree({"model": 8}, dict(SERVE_ENGINE_RULES,
                                        kvheads=())) == 1
    assert rules_fingerprint(None) == ()
    assert rules_fingerprint(SERVE_ENGINE_RULES) == \
        rules_fingerprint(dict(SERVE_ENGINE_RULES))


# -- engine mesh plumbing --------------------------------------------------
def test_engine_mesh_none_is_the_untouched_path(cfg):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=16, chunk=2)
    assert eng.mesh is None and eng.rules is None and eng.tp == 1
    assert eng.params is params          # no device_put detour


def test_engine_one_device_mesh_token_identity(cfg):
    """A (1, 1) mesh goes through every sharded hook (device_put,
    rule-scoped tracing, sc constraints) and must not move a token."""
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"r{i}",
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, 5)),
                    max_new_tokens=3) for i in range(3)]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    base = ServeEngine(cfg, params, max_slots=2, max_len=16,
                       chunk=2).run(list(reqs))
    for cls, kw in ((ServeEngine, {}),
                    (PagedServeEngine, {"page_size": 4})):
        eng = cls(cfg, params, max_slots=2, max_len=16, chunk=2,
                  mesh=mesh, **kw)
        out = eng.run(list(reqs))
        for r in reqs:
            np.testing.assert_array_equal(out[r.rid], base[r.rid])


def test_engine_rejects_indivisible_heads(cfg):
    # yi-9b smoke has 2 kv heads: TP=3 cannot split them
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="KV heads"):
        ServeEngine(cfg, params, max_slots=2, max_len=16, chunk=2,
                    mesh=_fake_mesh(model=3))


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_two_device_sharded_token_identity(layout):
    """Acceptance pin: dense and paged engines sharded over a (1, 2)
    host mesh serve token-identical streams to the unsharded engine."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests",
                                      "_sharded_serve_child.py"), layout],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["tp"] == 2
    assert rec["match"], f"sharded tokens diverged: {rec['tokens']}"


# -- spec properties -------------------------------------------------------
@given(st.sampled_from(sorted(ARCH_IDS)),
       st.sampled_from([1, 2, 4, 8, 16]),
       st.sampled_from([1, 2, 4, 16]))
def test_param_tree_specs_never_reuse_a_mesh_axis(arch, dp, tp):
    """Across a full param tree (and the serve cache tree), no leaf
    spec may assign the same mesh axis to two dims — jax would reject
    the sharding at placement; the greedy builder must never emit it."""
    cfg = get_config(arch)
    sizes = {"data": dp, "model": tp}
    trees = [M.param_pspecs(cfg, SERVE_ENGINE_RULES, sizes),
             M.cache_pspecs(cfg, SERVE_ENGINE_RULES, sizes, 4, 64)]
    leaves = [lf for t in trees
              for lf in jax.tree.leaves(t,
                                        is_leaf=lambda x:
                                        isinstance(x, P))]
    assert leaves
    for spec in leaves:
        used = [a for part in spec if part is not None
                for a in ((part,) if isinstance(part, str) else part)]
        assert len(used) == len(set(used)), (arch, sizes, spec)


def test_serve_engine_rules_pin_kvheads_to_model_axis():
    """The serve-engine layout: kv_seq never takes the model axis (the
    kernels tile the sequence), kvheads does."""
    sizes = {"data": 1, "model": 2}
    spec = spec_for((4, 64, 2, 32),
                    ("batch", "kv_seq", "kvheads", None),
                    SERVE_ENGINE_RULES, sizes)
    assert spec[1] is None
    assert spec[2] == "model"
