"""Health state machine, deadlines, priced degradation, and the
fault-tolerant router's rescue guarantees.

Mechanics (state transitions, deadline shed/cancel, retry/backoff
bounds) run against a no-jax virtual engine whose token stream is a
pure function of position — so a rescued replay provably continues the
stream. The rescue-identity integration test and the property-based
chaos test then drive *real* engines (dense and paged) through seeded
fault schedules and pin completed streams against a fault-free
baseline — byte for byte on the scan engine, where decode bit-exactly
continues the prefill recurrence; length plus pre-interruption prefix
on the paged attention engine, whose prefill/decode reduction orders
can resolve a greedy near-tie differently after a replay boundary
(see ``_check_streams``) — with conservation checked per example.

Runs under real hypothesis or the deterministic stub
(tests/_hypothesis_stub.py); conftest tags each test with the engine
that drove it.
"""

import functools

import jax
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import (FaultSpec, FaultTolerantRouter, FaultyEngine,
                         HealthConfig, NoHealthyReplica, PagedServeEngine,
                         ReplicaHealth, ReplicaRouter, Request, ServeEngine,
                         chaos_schedule, deadline_for, priced_degradation)
from repro.serve.planner import ChunkPlan

SLOTS, MAX_LEN, CHUNK = 2, 48, 2
BUDGET = 1e-3


class VirtualEngine:
    """No-jax slot engine whose k-th emitted token *is* its position.

    ``token = len(prompt) + k`` makes the stream a pure function of
    (prompt length, index) — replaying prompt+prefix continues it
    exactly, which is the property request rescue relies on.
    """

    paged = False

    def __init__(self, n_slots=SLOTS, budget_s=BUDGET):
        self.slots = [None] * n_slots
        self.max_slots = n_slots
        self.budget_s = budget_s
        self.last_step_seconds = budget_s
        self.chunk = 1
        # rescue pricing reads the model geometry and horizon
        self.cfg = get_smoke_config("xlstm-125m")
        self.max_len = 64

    def free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self, req, slot=None):
        slot = self.free_slots()[0] if slot is None else slot

        class _S:
            pass

        s = _S()
        s.rid, s.remaining, s.out = req.rid, req.max_new_tokens, []
        s.pos = len(req.prompt)
        self.slots[slot] = s
        return slot

    def step(self):
        retired = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.out.append(s.pos)
            s.pos += 1
            s.remaining -= 1
            if s.remaining <= 0:
                retired.append((s.rid, np.asarray(s.out, np.int32)))
                self.slots[i] = None
        return retired

    def cancel(self, rid):
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None
                return np.asarray(s.out, np.int32)
        return None


def _req(rid, budget=4, plen=3, deadline_s=None):
    return Request(rid, tuple(range(1, 1 + plen)), budget,
                   deadline_s=deadline_s)


def _plan(chunk=4, t=1e-3):
    return ChunkPlan(chunk=chunk, machine="neoverse_v2",
                     t_step_seconds=t, per_machine={"neoverse_v2": t})


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------

def test_health_state_machine_walk():
    h = ReplicaHealth(HealthConfig(fail_threshold=2, eject_threshold=3,
                                   cooldown_rounds=2, probe_successes=2))
    assert h.state == "healthy" and h.admissible()
    h.strike(1)
    assert h.state == "healthy"          # one strike: still healthy
    h.success(2)
    assert h.strikes == 0                # consecutive scoring resets
    h.strike(3)
    assert not h.strike(4)               # second consecutive: quarantine
    assert h.state == "quarantined" and not h.admissible()
    assert h.steppable()                 # draining, not dead
    assert h.strike(5)                   # third: eject (caller rescues)
    assert h.state == "ejected" and not h.steppable()
    h.tick(6)
    assert h.state == "ejected"          # cooldown not yet elapsed
    h.tick(7)
    assert h.state == "probing" and h.admissible()
    h.success(8)
    h.success(9)
    assert h.state == "healthy"
    # probing failure re-ejects immediately
    h2 = ReplicaHealth(HealthConfig(cooldown_rounds=1))
    h2.state = "probing"
    assert h2.strike(1) and h2.state == "ejected"


def test_quarantine_readmits_on_success():
    h = ReplicaHealth(HealthConfig(fail_threshold=1, eject_threshold=9))
    h.strike(1)
    assert h.state == "quarantined"
    h.success(2)
    assert h.state == "healthy" and h.strikes == 0


# ---------------------------------------------------------------------------
# deadlines and priced degradation
# ---------------------------------------------------------------------------

def test_deadline_for_scales_with_budget_and_chunk():
    plan = _plan(chunk=4, t=1e-3)
    d1 = deadline_for(plan, 8, slack=2.0)          # 2 rounds
    d2 = deadline_for(plan, 16, slack=2.0)         # 4 rounds
    assert d2 == pytest.approx(2 * d1)
    assert deadline_for(plan, 8, chunk=2, slack=2.0) != d1


def test_priced_degradation_choices():
    plan = _plan(chunk=4, t=1e-3)
    # no deadline: keep wins (fewer dispatch overheads per token)
    d = priced_degradation(plan, 4, SLOTS, 1, 16)
    assert d["choice"] == "keep"
    assert set(d["options"]) == {"keep", "replan"}
    assert all(o["drain_s"] >= 0 for o in d["options"].values())
    # per-round deadline rules keep out, half-chunk still fits: replan
    d = priced_degradation(plan, 4, SLOTS, 1, 16, deadline_s=3e-3)
    assert d["choice"] == "replan" and d["chunk"] == 2
    # nothing fits: shed
    d = priced_degradation(plan, 4, SLOTS, 1, 16, deadline_s=1e-4)
    assert d["choice"] == "shed"
    # chunk=1 cannot halve: single candidate
    d = priced_degradation(plan, 1, SLOTS, 1, 16)
    assert list(d["options"]) == ["keep"]


# ---------------------------------------------------------------------------
# fault-tolerant router on the virtual engine (no jax)
# ---------------------------------------------------------------------------

def _vrouter(n=2, **kw):
    return FaultTolerantRouter([VirtualEngine() for _ in range(n)], **kw)


def test_deadline_shed_and_cancel_on_virtual_clock():
    rt = _vrouter(n=1, max_queue=4)
    rt.submit(_req("slow", budget=10, deadline_s=3.5 * BUDGET))
    rt.submit(_req("slow2", budget=10, deadline_s=3.5 * BUDGET))
    rt.submit(_req("late", budget=2, deadline_s=0.5 * BUDGET))
    done = {}
    for _ in range(12):
        done.update(dict(rt.step()))
    # 'late' never reached a slot before its 0.5-round budget passed
    assert rt.deadline_shed == 1
    # the active 10-token streams blew their 3.5-round budgets mid-decode
    assert rt.deadline_cancelled == 2
    assert not done
    kinds = [e["kind"] for e in rt.drain_events()]
    assert kinds.count("deadline_shed") == 1
    assert kinds.count("deadline_cancel") == 2


def test_no_admissible_replica_raises_queue_full_subclass():
    rt = _vrouter(n=2)
    for h in rt.health:
        h.state = "ejected"
    with pytest.raises(NoHealthyReplica):
        rt.submit(_req("a"))


def test_eject_rescues_and_stream_continues_exactly():
    cfg = HealthConfig(fail_threshold=2, eject_threshold=3,
                       latency_factor=10.0, cooldown_rounds=50)
    e0 = FaultyEngine(VirtualEngine(),
                      [FaultSpec("stuck", frozenset(range(1, 60)))],
                      budget_s=BUDGET)
    e1 = FaultyEngine(VirtualEngine(), [], budget_s=BUDGET)
    rt = FaultTolerantRouter([e0, e1], policy="round_robin",
                             max_queue=8, health=cfg)
    rt.submit(_req("a", budget=6, plen=3))    # round_robin -> replica 0
    rt.submit(_req("b", budget=6, plen=5))    # -> replica 1
    done = {}
    for _ in range(40):
        done.update(dict(rt.step()))
        if len(done) == 2:
            break
    assert rt.health[0].state == "ejected"
    assert rt.rescued == 1
    # both streams are exactly the position sequence — the rescued one
    # included, despite moving replicas mid-flight
    np.testing.assert_array_equal(done["a"], np.arange(3, 9))
    np.testing.assert_array_equal(done["b"], np.arange(5, 11))
    assert {e["kind"] for e in rt.drain_events()} >= {
        "rescue", "rescued_complete"}
    states = [s["health"] for s in rt.stats()]
    assert states == ["ejected", "healthy"]
    assert rt.rescue_log and rt.rescue_log[0]["rid"] == "a"
    rows = rt.rescue_log[0]["rows"]
    assert rows and all(r["replay_tokens"] == 4 for r in rows)
    # recurrent xlstm has no per-token KV rows: priced, and priced zero
    assert all(r["rescue_bytes"] >= 0 for r in rows)


def test_run_bounded_retries_shed_and_stall_guard():
    # every queue wedged forever: run() must shed (bounded retries) and
    # then stop loudly instead of spinning
    class Wedged(VirtualEngine):
        def step(self):
            return []                    # admits, never progresses

    rt = ReplicaRouter([Wedged(n_slots=1)], max_queue=1)
    reqs = [_req(f"r{i}", budget=2) for i in range(4)]
    with pytest.raises(RuntimeError, match="no progress"):
        rt.run(reqs, max_retries=2, stall_rounds=16)
    st = rt.stats()
    assert sum(s["shed"] for s in st) == len(rt.shed_rids) >= 1
    assert sum(s["retries"] for s in st) >= 1
    assert set(rt.shed_rids).isdisjoint({"r0"})  # r0 was admitted


def test_cancel_then_resubmit_queued_and_active():
    # regression: cancel must release the rid for resubmission
    rt = ReplicaRouter([VirtualEngine(n_slots=1)], max_queue=4)
    rt.submit(_req("live", budget=5))
    rt.submit(_req("waiting", budget=5))
    rt.step()                            # live active, waiting queued
    assert rt.cancel("waiting") is not None     # queued: empty tokens
    assert rt.submit(_req("waiting", budget=5)) == 0   # rid reusable
    assert rt.cancel("live") is not None        # active: tokens so far
    assert rt.submit(_req("live", budget=5)) == 0
    results = rt.run([])                 # drains the resubmissions
    assert set(results) == {"live", "waiting"}
    assert all(len(t) == 5 for t in results.values())
    assert not rt.busy()


# ---------------------------------------------------------------------------
# real engines: rescue identity + property-based chaos schedules
# ---------------------------------------------------------------------------

# plain cached helpers instead of pytest fixtures: @given-wrapped tests
# (stub or real) cannot take fixture parameters through the wrapper
@functools.lru_cache(maxsize=None)
def _cfg(arch):
    return get_smoke_config(arch)


@functools.lru_cache(maxsize=None)
def _params(arch):
    return M.init_params(_cfg(arch), jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _fleet(layout):
    """Two long-lived inner engines per layout (compile once).

    Chaos examples wrap them in fresh FaultyEngine/router shells;
    every example drains completely, so reuse only carries the paged
    pool's prefix index across examples (bit-exact by design).
    """
    if layout == "dense":
        def mk():
            return ServeEngine(_cfg("xlstm-125m"), _params("xlstm-125m"),
                               max_slots=SLOTS, max_len=MAX_LEN,
                               chunk=CHUNK, seed=0)
    else:
        def mk():                            # attention: real paged KV
            return PagedServeEngine(_cfg("yi-9b"), _params("yi-9b"),
                                    max_slots=SLOTS, max_len=MAX_LEN,
                                    chunk=CHUNK, seed=0, page_size=4)
    return mk(), mk()


_REQS = [Request(f"c{i}", tuple(range(2 + i, 8 + i)), 3 + (i % 4))
         for i in range(6)]


def _first_rescue_prefix(rt):
    """rid -> prefix length at its *first* rescue (pre-fault tokens)."""
    first = {}
    for r in rt.rescue_log:
        first.setdefault(r["rid"], r["prefix"])
    return first


def _check_streams(layout, rt, results, base):
    """Stream identity vs. the fault-free baseline, per cache layout.

    The scan engine's decode *is* its prefill recurrence continued, so
    a rescue replay is bit-identical end to end — assert full byte
    equality. Attention prefill and single-token decode reduce in
    different orders, so a greedy near-tie can resolve differently
    after a replay boundary (both argmaxes are legitimate); there the
    exact guarantees are length and the pre-interruption prefix, plus
    full identity for streams that were never interrupted.
    """
    first = _first_rescue_prefix(rt)
    for rid, toks in results.items():
        assert len(toks) == len(base[rid])
        k = first.get(rid)
        if layout == "dense" or k is None:
            np.testing.assert_array_equal(toks, base[rid])
        else:
            np.testing.assert_array_equal(toks[:k], base[rid][:k])


@functools.lru_cache(maxsize=None)
def _baseline(layout):
    """Fault-free streams for _REQS on the shared fleet."""
    rt = FaultTolerantRouter(
        [FaultyEngine(e, [], budget_s=BUDGET) for e in _fleet(layout)],
        policy="least_loaded", max_queue=8)
    out = rt.run(list(_REQS))
    assert len(out) == len(_REQS)
    return out


def _run_chaos(layout, schedule0, schedule1):
    inner = _fleet(layout)
    rt = FaultTolerantRouter(
        [FaultyEngine(inner[0], schedule0, budget_s=BUDGET),
         FaultyEngine(inner[1], schedule1, budget_s=BUDGET)],
        policy="least_loaded", max_queue=8,
        health=HealthConfig(fail_threshold=2, eject_threshold=3,
                            cooldown_rounds=2))
    results = rt.run(list(_REQS))
    return rt, results


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_rescue_identity_on_real_engines(layout):
    base = _baseline(layout)
    rt, results = _run_chaos(
        layout,
        [FaultSpec("stuck", frozenset(range(1, 8)))],
        [FaultSpec("nonfinite", frozenset({2}), slot=0)])
    assert rt.rescued >= 1
    assert set(results) == set(base)     # nothing lost, nothing shed
    _check_streams(layout, rt, results, base)


_RATES = {"step_error": 0.06, "stuck": 0.08, "slow": 0.05,
          "nonfinite": 0.05, "admit_error": 0.08, "pool_exhausted": 0.04}


# the stub's @given wrapper hides named args from pytest, so the
# dense/paged split is two thin test functions instead of parametrize
@given(st.integers(0, 10 ** 6))
def test_chaos_property_dense(seed):
    """Property: chaos conservation + identity on the dense engine."""
    _chaos_property("dense", seed)


@given(st.integers(0, 10 ** 6))
def test_chaos_property_paged(seed):
    """Property: chaos conservation + identity on the paged engine."""
    _chaos_property("paged", seed)


def _chaos_property(layout, seed):
    """Random seeded chaos schedules: every request is accounted for
    and every completed stream equals its fault-free baseline."""
    base = _baseline(layout)
    rt, results = _run_chaos(
        layout,
        chaos_schedule(seed, 20, _RATES, slots=SLOTS),
        chaos_schedule(seed + 1, 20, _RATES, slots=SLOTS))
    completed = set(results)
    shed = set(rt.shed_rids)
    assert completed.isdisjoint(shed)
    assert completed | shed == {r.rid for r in _REQS}, \
        "request silently lost under chaos"
    assert not rt.quarantined            # rescued, never parked
    _check_streams(layout, rt, results, base)
    for eng in rt.replicas:              # examples must drain fully
        assert all(s is None for s in eng.slots)
        if getattr(eng, "paged", False):
            eng.inner.check_pool()
