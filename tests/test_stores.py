"""Store-flavor selector, NT kernel parity, and plan-record threading.

Pins the paper's Fig. 4 store-path decisions per machine (zen4 -> nt,
grace -> standard, SPR gated on modeled saturation), checks the NT
stream/KV-writer kernels agree with the standard path numerically in
interpret mode, and checks the chosen flavor is recorded end to end
through tile plans, chunk plans, and KV traffic rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import wa
from repro.kernels import stores, tuning
from repro.kernels.stream import kernels as K
from repro.kernels.stream import ops
from repro.kernels.stream import ref as R

BIG = 1 << 30        # clearly DRAM-resident working set


# --- selector pins (paper Fig. 4) ------------------------------------------

def test_zen4_selects_nt():
    assert stores.select_store_flavor("zen4", ws_bytes=BIG) == "nt"
    plan = stores.plan_stores("zen4", ws_bytes=BIG)
    assert plan.flavor == "nt"
    assert plan.ratio_nt == pytest.approx(1.0)
    assert plan.ratio_standard == pytest.approx(2.0)


def test_grace_and_tpu_select_standard():
    for name in ("neoverse_v2", "tpu_v5e"):
        plan = stores.plan_stores(name, ws_bytes=BIG)
        assert plan.flavor == "standard", name
        # auto-claim already evades: NT buys nothing
        assert plan.ratio_nt == pytest.approx(plan.ratio_standard)
        assert plan.ratio == pytest.approx(1.0)


def test_spr_gated_on_modeled_saturation():
    # full socket: SpecI2M engages, NT is redundant (tie -> standard)
    full = stores.plan_stores("golden_cove", ws_bytes=BIG)
    assert full.saturation == pytest.approx(1.0)
    assert full.flavor == "standard"
    assert full.ratio == pytest.approx(1.1)
    # single core: interface unsaturated, the gate is open -> NT wins
    one = stores.plan_stores("golden_cove", ws_bytes=BIG, cores_active=1)
    assert one.saturation < 0.5
    assert one.flavor == "nt"
    assert one.ratio_nt < one.ratio_standard


def test_cache_resident_ws_stays_standard():
    # a 64 KiB working set lives in cache on zen4: private-tier stores
    # never reach the allocate machinery, NT buys nothing
    assert stores.select_store_flavor("zen4", ws_bytes=64e3) == "standard"


def test_resolve_and_executed_flavor():
    assert stores.resolve_flavor("nt") == "nt"
    assert stores.resolve_flavor("standard", "zen4") == "standard"
    assert stores.resolve_flavor("auto", "zen4", ws_bytes=BIG) == "nt"
    with pytest.raises(ValueError):
        stores.resolve_flavor("fast")
    # explicit nt always executes; auto degrades to standard off-TPU
    assert stores.executed_flavor("nt", "zen4") == "nt"
    from repro.kernels import on_tpu
    if not on_tpu():
        assert stores.executed_flavor("auto", "zen4",
                                      ws_bytes=BIG) == "standard"


def test_selector_shares_ladder_pricing_with_wa():
    # the plan's ratios ARE wa.ladder_traffic_ratio — never a fork
    for name in ("zen4", "neoverse_v2", "golden_cove"):
        plan = stores.plan_stores(name, ws_bytes=BIG)
        assert plan.ratio_standard == pytest.approx(
            wa.ladder_traffic_ratio(name, ws_bytes=BIG))
        assert plan.ratio_nt == pytest.approx(
            wa.ladder_traffic_ratio(name, nt_stores=True, ws_bytes=BIG))


def test_priced_store_traffic_flavor_path():
    prof = wa.store_profile((256, 512), "f32")
    payload = 256 * 512 * 4.0
    nt = wa.priced_store_traffic(prof, "zen4", ws_bytes=BIG, flavor="nt")
    std = wa.priced_store_traffic(prof, "zen4", ws_bytes=BIG,
                                  flavor="standard")
    assert nt == pytest.approx(payload)
    assert std == pytest.approx(2.0 * payload)
    auto = wa.priced_store_traffic(prof, "zen4", ws_bytes=BIG,
                                   flavor="auto")
    assert auto == pytest.approx(nt)


# --- NT vs standard interpret parity ---------------------------------------

@pytest.mark.parametrize("shape", [(16, 256), (20, 300), (7, 100)])
def test_stream_nt_parity(shape):
    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, shape, jnp.float32)
    b = jax.random.normal(kb, shape, jnp.float32)
    np.testing.assert_allclose(K.copy_nt(a, interpret=True), R.copy(a),
                               rtol=1e-6)
    np.testing.assert_allclose(K.update_nt(a, interpret=True),
                               R.update(a), rtol=1e-6)
    np.testing.assert_allclose(K.stream_triad_nt(a, b, interpret=True),
                               R.stream_triad(a, b), rtol=1e-6)
    np.testing.assert_allclose(K.init_nt(shape, interpret=True),
                               R.init(shape), rtol=1e-6)


def test_ops_flavor_routing():
    a = jnp.ones((16, 256), jnp.float32)
    # forced nt runs the NT kernel (interpret off-TPU), same numbers
    np.testing.assert_allclose(ops.copy(a, flavor="nt"),
                               ops.copy(a), rtol=1e-6)
    np.testing.assert_allclose(ops.update(a, flavor="nt"),
                               ops.update(a), rtol=1e-6)
    np.testing.assert_allclose(ops.stream_triad(a, a, flavor="nt"),
                               ops.stream_triad(a, a), rtol=1e-6)
    np.testing.assert_allclose(ops.init((16, 256), flavor="nt"),
                               ops.init((16, 256)), rtol=1e-6)
    # auto off-TPU stays on the standard execution path
    np.testing.assert_allclose(ops.copy(a, flavor="auto"), a, rtol=1e-6)


@pytest.mark.parametrize("sq", [1, 3])
def test_kv_row_update_parity(sq):
    key = jax.random.PRNGKey(1)
    kc, ku = jax.random.split(key)
    cache = jax.random.normal(kc, (2, 16, 4, 8), jnp.float32)
    upd = jax.random.normal(ku, (2, sq, 4, 8), jnp.float32)
    pos = jnp.array([3, 9], jnp.int32)
    std = stores.kv_row_update(cache, upd, pos, flavor="standard")
    nt = stores.kv_row_update(cache, upd, pos, flavor="nt")
    np.testing.assert_allclose(np.asarray(std), np.asarray(nt), rtol=1e-6)
    # rows outside the written window are untouched
    np.testing.assert_array_equal(np.asarray(nt[0, :3]),
                                  np.asarray(cache[0, :3]))
    np.testing.assert_array_equal(np.asarray(nt[1, 9 + sq:]),
                                  np.asarray(cache[1, 9 + sq:]))


def test_kv_row_update_scalar_pos_parity():
    cache = jnp.zeros((2, 8, 2, 4), jnp.float32)
    upd = jnp.ones((2, 1, 2, 4), jnp.float32)
    std = stores.kv_row_update(cache, upd, jnp.int32(5), flavor="standard")
    nt = stores.kv_row_update(cache, upd, jnp.int32(5), flavor="nt")
    np.testing.assert_array_equal(np.asarray(std), np.asarray(nt))
    assert float(np.asarray(std)[0, 5].sum()) == 8.0


def test_pad_to_horizon_parity():
    x = jnp.full((2, 3, 2, 4), 7.0, jnp.bfloat16)
    std = stores.pad_to_horizon(x, 10, flavor="standard")
    nt = stores.pad_to_horizon(x, 10, flavor="nt")
    assert std.shape == nt.shape == (2, 10, 2, 4)
    np.testing.assert_array_equal(np.asarray(std, np.float32),
                                  np.asarray(nt, np.float32))
    # no-op when already at the horizon
    assert stores.pad_to_horizon(x, 3, flavor="nt") is x


# --- plan records carry the flavor -----------------------------------------

def test_tile_plans_record_flavor():
    tuning.clear_cache()
    # a long DRAM-resident KV stream on zen4 selects nt...
    big = tuning.flash_tiles("zen4", s=1 << 16, dh=128, h=32, hkv=8)
    assert big.store_flavor == "nt"
    # ...while grace keeps standard at any size
    g = tuning.flash_tiles("neoverse_v2", s=1 << 16, dh=128, h=32, hkv=8)
    assert g.store_flavor == "standard"
    d = tuning.decode_tiles("zen4", skv=1 << 16, dh=128, h=32, hkv=8,
                            batch=8)
    assert d.store_flavor in ("standard", "nt")


def test_chunk_plan_records_flavor():
    from repro.serve.planner import clear_plan_cache, plan_chunk_size
    clear_plan_cache()
    cfg = get_smoke_config("yi-9b")
    plan = plan_chunk_size(cfg, 2, 64, store_flavor="auto")
    assert plan.store_flavor in ("standard", "nt")
    assert plan.per_machine_flavor is not None
    assert set(plan.per_machine_flavor) == set(plan.per_machine)
    for flavor in plan.per_machine_flavor.values():
        assert flavor in ("standard", "nt")
    # an explicit flavor is honoured verbatim
    forced = plan_chunk_size(cfg, 2, 64, store_flavor="nt")
    assert forced.store_flavor == "nt"
    assert all(f == "nt" for f in forced.per_machine_flavor.values())


def test_kv_update_traffic_records_flavor():
    from repro.serve.kv_traffic import kv_update_traffic
    cfg = get_smoke_config("yi-9b")
    # shapes big enough that the slot cache is DRAM-resident on zen4
    rows = kv_update_traffic(cfg, 64, 1 << 15, flavor="auto",
                             machines=("zen4", "neoverse_v2",
                                       "golden_cove"))
    by = {r["machine"]: r for r in rows}
    assert by["zen4"]["store_flavor"] == "nt"
    assert by["neoverse_v2"]["store_flavor"] == "standard"
    assert by["golden_cove"]["store_flavor"] in ("standard", "nt")
    # flavored pricing can only reduce zen4's donated traffic
    legacy = {r["machine"]: r for r in kv_update_traffic(
        cfg, 64, 1 << 15, machines=("zen4",))}
    assert by["zen4"]["donated_bytes"] \
        <= legacy["zen4"]["donated_bytes"] + 1e-9
    assert legacy["zen4"]["store_flavor"] == "standard"
    # a cache-resident working set correctly stays standard everywhere
    small = kv_update_traffic(cfg, 1, 64, flavor="auto",
                              machines=("zen4",))
    assert small[0]["store_flavor"] == "standard"


# --- forward-path threading -------------------------------------------------

def test_forward_decode_flavor_token_identity():
    cfg = get_smoke_config("yi-9b")
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 2, 16)
    tok = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.array([0, 0], jnp.int32)
    lg_std, _, c_std = M.forward(cfg, params, {"tokens": tok},
                                 mode="decode", cache=cache, pos=pos,
                                 store_flavor="standard")
    lg_nt, _, c_nt = M.forward(cfg, params, {"tokens": tok},
                               mode="decode", cache=cache, pos=pos,
                               store_flavor="nt")
    np.testing.assert_allclose(np.asarray(lg_std), np.asarray(lg_nt),
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(c_std), jax.tree.leaves(c_nt)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-5, atol=2e-5)
