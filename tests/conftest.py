import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# exactly 1 device; only repro.launch.dryrun (a separate process) sets the
# 512-device placeholder flag.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Degrade gracefully when `hypothesis` is not installed (it is a dev
# extra: `pip install -e .[dev]`): install the deterministic mini-stub
# from tests/_hypothesis_stub.py into sys.modules BEFORE any test module
# imports it, so collection never errors. With real hypothesis present,
# register the repro profile as before.
try:
    from hypothesis import HealthCheck, settings  # noqa: E402
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import _build_modules  # noqa: E402

    sys.modules.update(_build_modules())
    from hypothesis import HealthCheck, settings  # noqa: E402

settings.register_profile(
    "repro",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")
