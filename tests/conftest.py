import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# exactly 1 device; only repro.launch.dryrun (a separate process) sets the
# 512-device placeholder flag.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Degrade gracefully when `hypothesis` is not installed (it is a dev
# extra: `pip install -e .[dev]`): install the deterministic mini-stub
# from tests/_hypothesis_stub.py into sys.modules BEFORE any test module
# imports it, so collection never errors. With real hypothesis present,
# register the repro profile as before.
try:
    from hypothesis import HealthCheck, settings  # noqa: E402
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_stub import _build_modules  # noqa: E402

    sys.modules.update(_build_modules())
    from hypothesis import HealthCheck, settings  # noqa: E402

settings.register_profile(
    "repro",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


def _property_engine() -> str:
    """Which engine @given-decorated tests actually ran on."""
    import hypothesis
    if getattr(hypothesis, "__is_repro_stub__", False):
        return "stub"
    return "hypothesis"


def pytest_collection_modifyitems(config, items):
    """Tag property-based tests with the engine that drives them.

    The stub fallback must never be silent: every ``@given`` test gets
    a ``hypothesis_stub`` or ``hypothesis_real`` marker (selectable
    with ``-m``), and the counts feed the terminal summary line below
    so a CI log always states which engine exercised the properties.
    """
    import pytest

    n_stub = n_real = 0
    for item in items:
        fn = getattr(item, "function", None)
        if fn is None:
            continue
        if getattr(fn, "hypothesis_stub", False):
            item.add_marker(pytest.mark.hypothesis_stub)
            n_stub += 1
        elif hasattr(fn, "hypothesis"):     # real hypothesis wraps here
            item.add_marker(pytest.mark.hypothesis_real)
            n_real += 1
    config._property_test_counts = (n_stub, n_real)


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """One unmissable line: stubbed vs exhaustive property coverage."""
    n_stub, n_real = getattr(config, "_property_test_counts", (0, 0))
    if n_stub == 0 and n_real == 0:
        return
    if _property_engine() == "stub":
        msg = (f"[property-tests] {n_stub} hypothesis-driven tests; "
               "engine: DETERMINISTIC STUB (boundary + 12 seeded examples "
               "each — install the [dev] extra for exhaustive coverage)")
    else:
        msg = (f"[property-tests] {n_real} hypothesis-driven tests; "
               "engine: hypothesis (repro profile, 20 examples each)")
    terminalreporter.write_line(msg)
