import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# exactly 1 device; only repro.launch.dryrun (a separate process) sets the
# 512-device placeholder flag.

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings, HealthCheck  # noqa: E402

settings.register_profile(
    "repro",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")
