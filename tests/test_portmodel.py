"""Port-model engine invariants: flop exactness on dots, loop-trip
multiplication, unit routing, lower-bound structure, and hypothesis
property tests on the spec/shape machinery."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.core import baseline, hloparse, isa, portmodel
from repro.core.machine import MACHINES, TPU_V5E


def _compile_text(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, d) for s, d in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    txt = _compile_text(lambda a, b: a @ b,
                        ((256, 512), jnp.bfloat16),
                        ((512, 1024), jnp.bfloat16))
    rep = portmodel.analyze(txt, TPU_V5E)
    want = 2 * 256 * 512 * 1024
    assert abs(rep.flops - want) / want < 0.05
    assert rep.unknown_ops == 0


def test_scan_trip_multiplication():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c.T) @ c * 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=37)
        return y
    txt = _compile_text(f, ((128, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    want = 37 * 2 * (2 * 128 ** 3)
    assert abs(rep.flops - want) / want < 0.1
    assert 37 in rep.trips_seen.values()


def test_transcendental_routing():
    txt = _compile_text(lambda x: jnp.exp(x) + jnp.sin(x),
                        ((8192, 512), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    vpu = sum(c for p, c in rep.port_occupation.items()
              if p.startswith("VPU"))
    mxu = sum(c for p, c in rep.port_occupation.items()
              if p.startswith("MXU"))
    assert vpu > 0 and mxu == 0


def test_incore_excludes_memory_ports():
    txt = _compile_text(lambda a, b: a + b,
                        ((1 << 20,), jnp.float32), ((1 << 20,), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.tp_incore_cycles <= rep.tp_cycles
    assert rep.bytes_hbm >= 3 * 4 * (1 << 20) * 0.9   # 2 reads + 1 write


def test_serial_floor_on_sequential_scan():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 0.9 + 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=512)
        return y
    txt = _compile_text(f, ((8, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.serial_cycles > 0
    # tiny per-step work: the LCD floor must dominate raw port occupation
    assert rep.serial_cycles >= rep.tp_incore_cycles * 0.5


def test_collective_accounting():
    import numpy as np
    mesh = jax.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    # single-device: no collectives expected; exercise the parser path
    txt = _compile_text(lambda a: a.sum(), ((128, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.coll_bytes == {}


def test_baseline_predict_monotone():
    m = MACHINES["tpu_v5e"]
    r1 = baseline.predict({"flops": 1e12, "bytes accessed": 1e9}, m)
    r2 = baseline.predict({"flops": 2e12, "bytes accessed": 1e9}, m)
    assert r2.seconds >= r1.seconds
    assert r1.bottleneck() in ("compute", "memory")


# ---- hypothesis property tests --------------------------------------------

@given(st.lists(st.integers(1, 512), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "pred"]))
def test_parse_shapes_roundtrip(dims, dtype):
    s = f"{dtype}[{','.join(map(str, dims))}]"
    shapes = hloparse.parse_shapes(s)
    assert shapes[0].dtype == dtype
    assert shapes[0].dims == tuple(dims)
    import math
    assert shapes[0].elems == math.prod(dims) if dims else 1


@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
def test_mxu_pass_count_lower_bound(m, n, k):
    """ceil-div tiling: passes x 128^3 >= m*n*k (padding never loses work)."""
    import math
    passes = math.ceil(m / 128) * math.ceil(n / 128) * math.ceil(k / 128)
    assert passes * 128 ** 3 >= m * n * k


@given(st.integers(1, 10_000_000))
def test_vpu_blocks_cover_elements(e):
    blocks = isa._vpu_blocks(e)
    assert blocks * isa.VPU_BLOCK >= e
    assert (blocks - 1) * isa.VPU_BLOCK < e


def test_report_bound_is_max_of_terms():
    txt = _compile_text(lambda a, b: jax.nn.relu(a @ b),
                        ((512, 512), jnp.bfloat16),
                        ((512, 512), jnp.bfloat16))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.bound_cycles >= rep.tp_cycles
    assert rep.bound_cycles >= rep.serial_cycles
    assert rep.bound_incore_cycles <= rep.bound_cycles


# ---- compare() process-pool fan-out + degradation paths --------------------

def _chain_text():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c.T) @ c * 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y
    return _compile_text(f, ((64, 64), jnp.float32))


def test_compare_pool_matches_serial():
    txt = _chain_text()
    serial = portmodel.compare(txt, parallel="serial")
    pooled = portmodel.compare(txt, parallel="process")
    assert list(serial) == list(pooled)
    for name in serial:
        s, p = serial[name], pooled[name]
        assert s.tp_cycles == p.tp_cycles
        assert s.serial_cycles == p.serial_cycles
        assert s.bytes_hbm == p.bytes_hbm
        assert s.t_mem_tier == p.t_mem_tier
        assert s.bottleneck_tier == p.bottleneck_tier


def test_compare_unpicklable_model_falls_back_serial():
    import dataclasses
    txt = _chain_text()
    adhoc = dataclasses.replace(TPU_V5E, name="adhoc_unpicklable")
    object.__setattr__(adhoc, "chip", lambda: None)   # lambdas don't pickle
    import pickle
    with pytest.raises(Exception):
        pickle.dumps(adhoc)
    reports = portmodel.compare(txt, machines=[adhoc, TPU_V5E],
                                parallel="process")
    assert set(reports) == {"adhoc_unpicklable", "tpu_v5e"}
    ref = portmodel.compare(txt, machines=[TPU_V5E], parallel="serial")
    assert reports["tpu_v5e"].tp_cycles == ref["tpu_v5e"].tp_cycles


# ---- missing-µ-op-class degradation (Analyzer._occupy) ---------------------

def test_missing_vpu_class_degrades_with_counted_warning():
    """A machine injected straight into the MACHINES dict (bypassing
    validate_model) without a `vpu` entry used to KeyError; it now
    degrades to the cheapest available class, warns, and counts."""
    import dataclasses
    import warnings as _warnings
    table = {k: v for k, v in TPU_V5E.table.items() if k != "vpu"}
    novpu = dataclasses.replace(TPU_V5E, name="novpu_test", table=table)
    MACHINES["novpu_test"] = novpu
    try:
        txt = _compile_text(lambda x: jnp.exp(x) + x,
                            ((512, 512), jnp.float32))
        with _warnings.catch_warnings(record=True) as got:
            _warnings.simplefilter("always")
            rep = portmodel.analyze(txt, "novpu_test")
        assert rep.fallback_uops > 0
        assert any("novpu_test" in str(w.message) and
                   isinstance(w.message, RuntimeWarning) for w in got)
        # degradation is usable: a bound still comes out
        assert rep.tp_cycles > 0
    finally:
        del MACHINES["novpu_test"]


def test_full_machines_never_fall_back():
    txt = _chain_text()
    for name, rep in portmodel.compare(txt, parallel="serial").items():
        assert rep.fallback_uops == 0, name
