"""Port-model engine invariants: flop exactness on dots, loop-trip
multiplication, unit routing, lower-bound structure, and hypothesis
property tests on the spec/shape machinery."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.core import baseline, hloparse, isa, portmodel
from repro.core.machine import MACHINES, TPU_V5E


def _compile_text(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, d) for s, d in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    txt = _compile_text(lambda a, b: a @ b,
                        ((256, 512), jnp.bfloat16),
                        ((512, 1024), jnp.bfloat16))
    rep = portmodel.analyze(txt, TPU_V5E)
    want = 2 * 256 * 512 * 1024
    assert abs(rep.flops - want) / want < 0.05
    assert rep.unknown_ops == 0


def test_scan_trip_multiplication():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c.T) @ c * 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=37)
        return y
    txt = _compile_text(f, ((128, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    want = 37 * 2 * (2 * 128 ** 3)
    assert abs(rep.flops - want) / want < 0.1
    assert 37 in rep.trips_seen.values()


def test_transcendental_routing():
    txt = _compile_text(lambda x: jnp.exp(x) + jnp.sin(x),
                        ((8192, 512), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    vpu = sum(c for p, c in rep.port_occupation.items()
              if p.startswith("VPU"))
    mxu = sum(c for p, c in rep.port_occupation.items()
              if p.startswith("MXU"))
    assert vpu > 0 and mxu == 0


def test_incore_excludes_memory_ports():
    txt = _compile_text(lambda a, b: a + b,
                        ((1 << 20,), jnp.float32), ((1 << 20,), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.tp_incore_cycles <= rep.tp_cycles
    assert rep.bytes_hbm >= 3 * 4 * (1 << 20) * 0.9   # 2 reads + 1 write


def test_serial_floor_on_sequential_scan():
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 0.9 + 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=512)
        return y
    txt = _compile_text(f, ((8, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.serial_cycles > 0
    # tiny per-step work: the LCD floor must dominate raw port occupation
    assert rep.serial_cycles >= rep.tp_incore_cycles * 0.5


def test_collective_accounting():
    import numpy as np
    mesh = jax.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    # single-device: no collectives expected; exercise the parser path
    txt = _compile_text(lambda a: a.sum(), ((128, 128), jnp.float32))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.coll_bytes == {}


def test_baseline_predict_monotone():
    m = MACHINES["tpu_v5e"]
    r1 = baseline.predict({"flops": 1e12, "bytes accessed": 1e9}, m)
    r2 = baseline.predict({"flops": 2e12, "bytes accessed": 1e9}, m)
    assert r2.seconds >= r1.seconds
    assert r1.bottleneck() in ("compute", "memory")


# ---- hypothesis property tests --------------------------------------------

@given(st.lists(st.integers(1, 512), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "pred"]))
def test_parse_shapes_roundtrip(dims, dtype):
    s = f"{dtype}[{','.join(map(str, dims))}]"
    shapes = hloparse.parse_shapes(s)
    assert shapes[0].dtype == dtype
    assert shapes[0].dims == tuple(dims)
    import math
    assert shapes[0].elems == math.prod(dims) if dims else 1


@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
def test_mxu_pass_count_lower_bound(m, n, k):
    """ceil-div tiling: passes x 128^3 >= m*n*k (padding never loses work)."""
    import math
    passes = math.ceil(m / 128) * math.ceil(n / 128) * math.ceil(k / 128)
    assert passes * 128 ** 3 >= m * n * k


@given(st.integers(1, 10_000_000))
def test_vpu_blocks_cover_elements(e):
    blocks = isa._vpu_blocks(e)
    assert blocks * isa.VPU_BLOCK >= e
    assert (blocks - 1) * isa.VPU_BLOCK < e


def test_report_bound_is_max_of_terms():
    txt = _compile_text(lambda a, b: jax.nn.relu(a @ b),
                        ((512, 512), jnp.bfloat16),
                        ((512, 512), jnp.bfloat16))
    rep = portmodel.analyze(txt, TPU_V5E)
    assert rep.bound_cycles >= rep.tp_cycles
    assert rep.bound_cycles >= rep.serial_cycles
    assert rep.bound_incore_cycles <= rep.bound_cycles
