"""Chunkwise-parallel recurrence implementations vs sequential oracles
(Mamba selective scan, mLSTM) — the TPU-native adaptations of DESIGN.md."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm, xlstm


def _mk_mlstm_params(key, di, h):
    ks = jax.random.split(key, 6)
    return {
        "wq": jax.random.normal(ks[0], (di, di)) * 0.1,
        "wk": jax.random.normal(ks[1], (di, di)) * 0.1,
        "wv": jax.random.normal(ks[2], (di, di)) * 0.1,
        "w_if": jax.random.normal(ks[3], (di, 2, h)) * 0.5,
        "b_if": jnp.zeros((2, h)),
        "out": jnp.eye(di),
    }


@pytest.mark.parametrize("t,chunk", [(64, 16), (100, 16), (37, 8)])
def test_mlstm_chunkwise_matches_sequential(t, chunk):
    di, h = 64, 4
    p = _mk_mlstm_params(jax.random.PRNGKey(0), di, h)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, t, di))
    y_seq, st_seq = xlstm.mlstm_sequential(p, x, n_heads=h, want_state=True)
    y_chk, st_chk = xlstm.mlstm_chunkwise(p, x, n_heads=h, chunk=chunk,
                                          want_state=True)
    np.testing.assert_allclose(y_chk, y_seq, rtol=2e-3, atol=2e-3)
    for a, b in zip(st_chk, st_seq):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_mlstm_grad_finite():
    di, h = 32, 2
    p = _mk_mlstm_params(jax.random.PRNGKey(2), di, h)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 48, di))
    g = jax.grad(lambda xx: xlstm.mlstm_chunkwise(
        p, xx, n_heads=h, chunk=16)[0].sum())(x)
    assert bool(jnp.isfinite(g).all())


@pytest.mark.parametrize("chunk", [8, 33, 100])
def test_slstm_chunk_invariance(chunk):
    d, h = 64, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    p = {"w": jax.random.normal(ks[0], (d, 4, d)) * 0.2,
         "b": jnp.zeros((4, d)),
         "r": jax.random.normal(ks[1], (h, d // h, 4, d // h)) * 0.2,
         "out": jnp.eye(d)}
    x = jax.random.normal(ks[2], (2, 100, d))
    y1, s1 = xlstm.slstm_mixer(p, x, n_heads=h, chunk=chunk, want_state=True)
    y2, s2 = xlstm.slstm_mixer(p, x, n_heads=h, chunk=100, want_state=True)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    for k in s1:
        np.testing.assert_allclose(s1[k], s2[k], rtol=1e-5, atol=1e-5)


def _ssm_sequential_oracle(a_in, u_b, c_mat, h0):
    b, t, d, n = a_in.shape
    h = h0
    ys = []
    for i in range(t):
        h = a_in[:, i] * h + u_b[:, i]
        ys.append(jnp.einsum("bdn,bn->bd", h, c_mat[:, i]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("t,chunk", [(32, 8), (50, 16), (64, 64)])
def test_ssm_chunked_scan_matches_oracle(t, chunk):
    b, d, n = 2, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    a_in = jax.nn.sigmoid(jax.random.normal(ks[0], (b, t, d, n))) * 0.9 + 0.05
    u_b = jax.random.normal(ks[1], (b, t, d, n)) * 0.1
    c_mat = jax.random.normal(ks[2], (b, t, n))
    h0 = jax.random.normal(ks[3], (b, d, n)) * 0.1
    y, hT = ssm._ssm_scan_chunked(a_in, u_b, c_mat, h0, chunk)
    y_ref, h_ref = _ssm_sequential_oracle(a_in, u_b, c_mat, h0)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hT, h_ref, rtol=1e-4, atol=1e-5)


def test_mamba_decode_matches_prefill_tail():
    """One-token decode from the prefill state == full forward last step."""
    d, di_exp, n, k = 32, 2, 8, 4
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                      ssm_d_state=n, ssm_conv_dim=k, ssm_expand=di_exp,
                      ssm_dt_rank=4, ssm_chunk=8)
    from repro.models.model import _mamba_defs, _tree_init
    defs = _mamba_defs(cfg)
    p = _tree_init(jax.random.PRNGKey(6), defs, jnp.float32, None)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 33, d)) * 0.5
    y_full, _ = ssm.mamba_mixer(p, x, d_state=n, conv_dim=k, chunk=8)
    _, st = ssm.mamba_mixer(p, x[:, :32], d_state=n, conv_dim=k, chunk=8,
                            want_state=True)
    y_dec, _ = ssm.mamba_mixer(p, x[:, 32:33], d_state=n, conv_dim=k,
                               state=st, want_state=True)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 32],
                               rtol=5e-3, atol=5e-3)
