"""Cross-vendor machine registry: µ-op table completeness, registration
validation, calibration round-trips, compare() fan-out, and the paper's
qualitative write-allocate ordering (Fig. 4)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import isa, portmodel, wa
from repro.core.machine import (MACHINES, MachineModel, MachineValidationError,
                                OpEntry, get_machine, host_cpu_model,
                                register, registered_models,
                                registered_names, validate_model)

CPU_NAMES = ("zen4", "golden_cove", "neoverse_v2")


def _compile_text(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, d) for s, d in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


# ---- completeness of every registered machine -----------------------------

def test_all_machines_have_complete_uop_tables():
    assert registered_models(), "registry must not be empty"
    for m in registered_models():
        for cls in isa.UOP_CLASSES:
            e = m.table.get(cls)
            assert e is not None, f"{m.name} missing {cls}"
            assert e.cycles_per_unit > 0, f"{m.name}/{cls}"
            assert e.latency >= 0, f"{m.name}/{cls}"
            assert e.ports, f"{m.name}/{cls} has no ports"
            assert set(e.ports) <= set(m.ports)


def test_paper_cpus_registered_with_expected_topology():
    for name in CPU_NAMES:
        assert name in registered_names()
    zen4 = get_machine("zen4")
    glc = get_machine("golden_cove")
    v2 = get_machine("neoverse_v2")
    # Table II: FMA pipe pair on x86, all four pipes on V2
    assert len(zen4.entry("mxu").ports) == 2
    assert len(glc.entry("mxu").ports) == 2
    assert len(v2.entry("mxu").ports) == 4
    # divider pinned to a single pipe everywhere (asymmetric port set)
    for m in (zen4, glc, v2):
        assert len(m.entry("vdiv").ports) == 1
    # SIMD width: 2x256b double-pump < 512b; V2 has 4x128b
    assert zen4.simd_width_bytes == 32
    assert glc.simd_width_bytes == 64
    assert v2.simd_width_bytes == 16
    # WA-mode tags drive core/wa.py mode selection
    assert zen4.wa_mode == "explicit_only"
    assert glc.wa_mode == "saturation_gated"
    assert v2.wa_mode == "auto_claim"


# ---- registration validation ----------------------------------------------

def _tiny_model(name="tiny", **overrides) -> MachineModel:
    ports = ("P0", "MEM", "ICI")
    table = {cls: OpEntry(("MEM",) if cls in ("dma", "ici") else ("P0",),
                          1.0, 1.0)
             for cls in isa.UOP_CLASSES}
    table.update(overrides.pop("table_overrides", {}))
    kw = dict(name=name, clock_hz=1e9, ports=ports, table=table)
    kw.update(overrides)
    return MachineModel(**kw)


def test_register_rejects_incomplete_table():
    m = _tiny_model()
    t = dict(m.table)
    del t["vdiv"]
    bad = MachineModel(name="bad", clock_hz=1e9, ports=m.ports, table=t)
    with pytest.raises(MachineValidationError):
        register(bad)
    assert "bad" not in MACHINES


def test_register_rejects_bad_entries():
    with pytest.raises(MachineValidationError):
        validate_model(_tiny_model(
            table_overrides={"vpu": OpEntry(("P0",), 0.0, 1.0)}))
    with pytest.raises(MachineValidationError):
        validate_model(_tiny_model(
            table_overrides={"vpu": OpEntry(("P0",), 1.0, -1.0)}))
    with pytest.raises(MachineValidationError):
        validate_model(_tiny_model(
            table_overrides={"vpu": OpEntry(("NOPE",), 1.0, 1.0)}))
    with pytest.raises(MachineValidationError):
        validate_model(_tiny_model(wa_mode="sometimes"))
    with pytest.raises(MachineValidationError):
        validate_model(_tiny_model(
            table_overrides={"vpu": OpEntry(("P0",), 1.0, 1.0,
                                            port_weights=(1.0, 2.0))}))


def test_register_requires_replace_to_overwrite():
    m = _tiny_model(name="dup_test")
    try:
        register(m)
        with pytest.raises(ValueError):
            register(m)
        m2 = register(_tiny_model(name="dup_test", clock_hz=2e9),
                      replace=True)
        assert get_machine("dup_test") is m2
    finally:
        MACHINES.pop("dup_test", None)


def test_get_machine_resolves_names_and_models():
    m = get_machine("tpu_v5e")
    assert get_machine(m) is m
    with pytest.raises(KeyError):
        get_machine("not_a_machine")


# ---- host calibration round-trip ------------------------------------------

def test_host_cpu_model_calibration_roundtrip():
    calib = {"vpu": 2.5e9, "mxu": 4.0e7, "dma": 3.3e10}
    m = host_cpu_model(calib)
    validate_model(m)
    for cls, rate in calib.items():
        # cycles_per_unit at the nominal 1 GHz clock == 1e9 / rate
        assert m.entry(cls).cycles_per_unit == pytest.approx(1e9 / rate)
    # unlisted classes keep defaults but stay valid/positive
    assert m.entry("vdiv").cycles_per_unit > 0


def test_calibrated_model_registers_as_host_cpu():
    before = MACHINES.pop("host_cpu", None)
    try:
        register(host_cpu_model({"vpu": 1e9}), replace=True)
        assert "host_cpu" in registered_names()
        assert get_machine("host_cpu").entry("vpu").cycles_per_unit \
            == pytest.approx(1.0)
    finally:
        MACHINES.pop("host_cpu", None)
        if before is not None:
            MACHINES["host_cpu"] = before


# ---- analysis across the registry -----------------------------------------

def test_analyzer_accepts_machine_names():
    txt = _compile_text(lambda a, b: a @ b,
                        ((128, 128), jnp.float32), ((128, 128), jnp.float32))
    by_name = portmodel.analyze(txt, "zen4")
    by_model = portmodel.analyze(txt, get_machine("zen4"))
    assert by_name.tp_cycles == pytest.approx(by_model.tp_cycles)
    assert by_name.flops == pytest.approx(2 * 128 ** 3, rel=0.05)


def test_compare_returns_one_report_per_machine():
    txt = _compile_text(lambda a, b: jnp.tanh(a @ b),
                        ((128, 128), jnp.float32), ((128, 128), jnp.float32))
    names = ("zen4", "golden_cove", "neoverse_v2", "tpu_v5p")
    reps = portmodel.compare(txt, machines=names)
    assert tuple(reps) == names
    for name, rep in reps.items():
        assert isinstance(rep, portmodel.Report)
        assert rep.bound_cycles > 0
        assert rep.bottleneck() != "none"
    # same module, same flops on every machine — only cycles differ
    flops = {round(r.flops) for r in reps.values()}
    assert len(flops) == 1
    # fan-out matches sequential analysis exactly
    solo = portmodel.analyze(txt, "zen4")
    assert reps["zen4"].tp_cycles == pytest.approx(solo.tp_cycles)
    assert reps["zen4"].port_occupation == solo.port_occupation


def test_compare_defaults_to_whole_registry():
    txt = _compile_text(lambda a: a + 1.0, ((1024,), jnp.float32))
    reps = portmodel.compare(txt)
    assert set(reps) == set(registered_names())


def test_vdiv_routes_to_single_divider_port():
    txt = _compile_text(lambda a, b: a / b,
                        ((8192,), jnp.float32), ((8192,), jnp.float32))
    rep = portmodel.analyze(txt, "zen4")
    m = get_machine("zen4")
    div_port = m.entry("vdiv").ports[0]
    others = [p for p in m.entry("vpu").ports if p != div_port]
    assert rep.port_occupation.get(div_port, 0.0) > 0
    # divide work must not smear over the non-divider SIMD pipes
    assert rep.port_occupation.get(div_port, 0.0) > \
        max(rep.port_occupation.get(p, 0.0) for p in others)


def test_vlsu_port_weights_split_load_store():
    m = get_machine("neoverse_v2")
    e = m.entry("vlsu")
    assert e.port_weights is not None
    txt = _compile_text(lambda a: jnp.roll(a, 1), ((1 << 16,), jnp.float32))
    rep = portmodel.analyze(txt, m)
    ld = rep.port_occupation.get("LD0", 0.0)
    st = rep.port_occupation.get("ST0", 0.0)
    assert ld > 0 and st > 0
    # store pipes carry the smaller weighted share
    assert st < ld


# ---- the paper's WA ordering ----------------------------------------------

def test_wa_modes_follow_machine_tags():
    assert wa.wa_mode_of("zen4") == "explicit_only"
    assert wa.wa_mode_of(get_machine("tpu_v5e")) == "auto_claim"
    # Fig. 4, no NT stores: Grace <= SPR <= Zen 4
    grace = wa.traffic_ratio_for("neoverse_v2")
    spr = wa.traffic_ratio_for("golden_cove")
    zen = wa.traffic_ratio_for("zen4")
    assert grace <= spr <= zen
    assert grace == pytest.approx(1.0)
    assert zen == pytest.approx(2.0)
    # with NT stores Zen 4 evades fully, SPR keeps ~10% residue
    assert wa.traffic_ratio_for("zen4", nt_stores=True) == pytest.approx(1.0)
    assert wa.traffic_ratio_for("golden_cove", nt_stores=True) \
        == pytest.approx(1.1)


def test_apply_wa_mode_counts_rmw_consistently():
    # all-partial store scan: RMW reads equal the payload
    scan = {"stored_bytes": 100.0, "rmw_read_bytes": 100.0,
            "copy_bytes": 0.0, "wa_ratio": 2.0}
    grace = wa.apply_wa_mode(scan, "neoverse_v2")
    # auto_claim traffic must equal the scan's own stored+rmw bytes
    assert grace["traffic_bytes"] == pytest.approx(200.0)
    zen = wa.apply_wa_mode(scan, "zen4")
    # explicit_only: full write-allocate on top of the tiling reads
    assert zen["traffic_bytes"] == pytest.approx(300.0)


def test_machine_store_traffic_ordering_on_real_module():
    def f(x, cache):
        y = jnp.tanh(x) * 2.0
        return jax.lax.dynamic_update_slice(cache, y[None], (0, 0, 0))
    txt = _compile_text(f, ((64, 128), jnp.float32),
                        ((4, 64, 128), jnp.float32))
    t = {n: wa.machine_store_traffic(txt, n)["traffic_bytes"]
         for n in CPU_NAMES}
    assert t["neoverse_v2"] <= t["golden_cove"] <= t["zen4"]
    w = wa.machine_store_traffic(txt, "zen4")
    assert w["traffic_bytes"] >= w["stored_bytes"] > 0
    assert w["wa_mode"] == "explicit_only"
