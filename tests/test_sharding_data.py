"""Sharding-rule engine + data-pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.data.pipeline import SyntheticLM, make_iterator
from repro.models import model as M
from repro.utils.sharding import (SERVE_RULES, TRAIN_RULES, spec_for)

MESH_SIZES = {"pod": 2, "data": 16, "model": 16}
MESH_SIZES_SP = {"data": 16, "model": 16}


@given(st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16, 32, 64, 128, 256,
                                 688, 1536, 4096]),
                min_size=1, max_size=4),
       st.lists(st.sampled_from(["embed", "mlp", "qheads", "kvheads",
                                 "vocab", "expert", None]),
                min_size=1, max_size=4))
def test_spec_for_divisibility_and_uniqueness(shape, axes):
    axes = (axes + [None] * 4)[:len(shape)]
    spec = spec_for(tuple(shape), tuple(axes), TRAIN_RULES, MESH_SIZES)
    used = []
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        prod = 1
        for p in parts:
            assert p not in used, "mesh axis used twice"
            used.append(p)
            prod *= MESH_SIZES[p]
        assert dim % prod == 0, "non-divisible sharding"


def test_grok_experts_fall_back_to_ffn_sharding():
    cfg = get_config("grok-1-314b")
    specs = M.param_pspecs(cfg, TRAIN_RULES, MESH_SIZES_SP)
    moe = specs["scan"]["0"]["ffn"]["w_up"]   # (stack, E=8, d, ffe)
    # 8 experts don't divide model=16 -> expert dim unsharded,
    # ffe picks up the model axis instead
    assert moe[1] is None
    assert moe[3] == "model"


def test_qwen3_experts_sharded():
    cfg = get_config("qwen3-moe-235b-a22b")
    specs = M.param_pspecs(cfg, TRAIN_RULES, MESH_SIZES_SP)
    moe = specs["scan"]["0"]["ffn"]["w_up"]   # (stack, E=128, d, ffe)
    assert moe[1] == "model"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_pspec_tree_matches_shape_tree(arch):
    cfg = get_config(arch)
    shapes = M.param_shapes(cfg)
    specs = M.param_pspecs(cfg, TRAIN_RULES, MESH_SIZES)
    s_tree = jax.tree.structure(shapes)
    p_tree = jax.tree.structure(specs, is_leaf=lambda x: x is None or
                                hasattr(x, "index"))
    assert s_tree == p_tree
    # every spec is consistent with its shape
    for sh, sp in zip(jax.tree.leaves(shapes),
                      jax.tree.leaves(specs, is_leaf=lambda x: x is None or
                                      hasattr(x, "index"))):
        assert len(sp) <= len(sh.shape)


@pytest.mark.parametrize("arch", ["yi-9b", "jamba-v0.1-52b"])
def test_cache_pspecs_shard_kv_seq(arch):
    cfg = get_config(arch)
    specs = M.cache_pspecs(cfg, SERVE_RULES, MESH_SIZES_SP,
                           batch=128, seq=32768)
    # attention KV cache: batch over data, seq over model
    flat = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: x is None or hasattr(x, "index"))
    kv = [s for p, s in flat if "k" == p[-1].key or "v" == p[-1].key]
    assert kv, "no attention caches found"
    for s in kv:
        flat_axes = [a for part in s if part is not None
                     for a in (part if isinstance(part, tuple) else (part,))]
        assert "data" in flat_axes     # batch sharded
        assert "model" in flat_axes    # seq (or heads) sharded over TP


def test_synthetic_data_deterministic():
    src = SyntheticLM(1000, 64, seed=1)
    b1 = src.batch(5, 4)
    b2 = src.batch(5, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch(6, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_iterator_mrope_and_embeds():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2-vl-7b")
    from repro.configs.base import ShapeSpec
    it = make_iterator(cfg, ShapeSpec("t", 32, 4, "train"))
    b = next(it)
    assert b["positions"].shape == (3, 4, 32)
    cfg2 = get_smoke_config("musicgen-large")
    it2 = make_iterator(cfg2, ShapeSpec("t", 32, 4, "train"))
    b2 = next(it2)
    assert "embeds" in b2 and b2["embeds"].shape == (4, 32, cfg2.d_model)
