"""Assigned-architecture configs must match the published values exactly."""

import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, shapes_for

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
    "grok-1-314b": (64, 6144, 48, 8, 0, 131072),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
}

MOE = {
    "qwen3-moe-235b-a22b": (128, 8, 1536),
    "grok-1-314b": (8, 2, 32768),
    "jamba-v0.1-52b": (16, 2, 14336),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config(arch):
    cfg = get_config(arch)
    exp = EXPECTED[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == exp


@pytest.mark.parametrize("arch", sorted(MOE))
def test_moe_config(arch):
    cfg = get_config(arch)
    assert (cfg.n_experts, cfg.experts_per_token, cfg.d_ff_expert) == MOE[arch]


def test_layer_plan_jamba():
    cfg = get_config("jamba-v0.1-52b")
    plan = cfg.layer_plan()
    assert len(plan) == 32
    # HF config: attention at period 8 offset 4, MoE period 2 offset 1
    for i, blk in enumerate(plan):
        mixer, ffn = blk.split(":")
        assert mixer == ("attn" if i % 8 == 4 else "mamba")
        assert ffn == ("moe" if i % 2 == 1 else "dense")


def test_layer_plan_gemma3():
    plan = get_config("gemma3-4b").layer_plan()
    assert len(plan) == 34
    for i, blk in enumerate(plan):
        mixer = blk.split(":")[0]
        assert mixer == ("attn" if i % 6 == 5 else "attn_local")


def test_shapes_for_long_context():
    long_ok = {a for a in ARCH_IDS
               if any(s.name == "long_500k" for s in shapes_for(get_config(a)))}
    assert long_ok == {"gemma3-4b", "xlstm-125m", "jamba-v0.1-52b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model == 128 and cfg.vocab_size == 512
    assert cfg.n_layers <= 8
    full = get_config(arch)
    # same family/pattern structure
    assert cfg.family == full.family
    assert len(cfg.block_pattern) == len(full.block_pattern)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected_scale = {
        "yi-9b": 8.8e9, "gemma3-4b": 4.0e9, "minitron-8b": 8.3e9,
        "qwen1.5-110b": 111e9, "qwen2-vl-7b": 7.4e9,
        "qwen3-moe-235b-a22b": 235e9, "grok-1-314b": 314e9,
        "musicgen-large": 1.5e9, "xlstm-125m": 0.125e9,
        "jamba-v0.1-52b": 52e9,
    }[arch]
    assert 0.55 * expected_scale < n < 1.7 * expected_scale, \
        f"{arch}: {n/1e9:.2f}B params vs expected ~{expected_scale/1e9:.0f}B"
    if cfg.n_experts:
        assert cfg.active_param_count() < n
