"""End-to-end behaviour tests: per-architecture smoke (reduced config, one
forward + one train step on CPU, asserting output shapes + no NaNs) and
prefill->decode consistency against the full forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.optim.adamw import OptConfig
from repro.train import step as step_lib

B, S = 2, 32


def _batch(cfg, key, s=S, with_targets=True):
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    else:
        out["embeds"] = jax.random.normal(key, (B, s, cfg.d_model),
                                          jnp.bfloat16)
    if with_targets:
        out["targets"] = jax.random.randint(key, (B, s), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    logits, aux = M.forward(cfg, params, _batch(cfg, key, with_targets=False),
                            mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    state = step_lib.init_train_state(cfg, key)
    step = jax.jit(step_lib.make_train_step(cfg, OptConfig(lr=1e-3,
                                                           warmup_steps=1,
                                                           total_steps=10)))
    state2, metrics = step(state, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2["step"]) == 1
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    s = S
    if cfg.embed_inputs:
        toks = jax.random.randint(key, (B, s + 1), 0, cfg.vocab_size)
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :s]}
        dec = {"tokens": toks[:, s:s + 1]}
    else:
        emb = jax.random.normal(key, (B, s + 1, cfg.d_model), jnp.bfloat16)
        full = {"embeds": emb}
        pre = {"embeds": emb[:, :s]}
        dec = {"embeds": emb[:, s:s + 1]}
    logits_full, _ = M.forward(cfg, params, full, mode="train")
    _, _, cache = M.forward(cfg, params, pre, mode="prefill")

    def pad(x):
        if x.ndim == 4 and x.shape[1] == s:
            return jnp.pad(x, [(0, 0), (0, 1), (0, 0), (0, 0)])
        if x.ndim == 5 and x.shape[2] == s:
            return jnp.pad(x, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
        return x

    cache = jax.tree.map(pad, cache)
    logits_dec, _, _ = M.forward(cfg, params, dec, mode="decode",
                                 cache=cache, pos=jnp.int32(s))
    a = np.asarray(logits_full[:, -1].astype(jnp.float32))
    b = np.asarray(logits_dec[:, 0].astype(jnp.float32))
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 5e-2, f"{arch}: decode-vs-full rel err {err}"


def test_prefill_returns_last_token_logits_only():
    cfg = get_smoke_config("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits, _, cache = M.forward(
        cfg, params, _batch(cfg, jax.random.PRNGKey(0), with_targets=False),
        mode="prefill")
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert "scan" in cache or "tail" in cache
