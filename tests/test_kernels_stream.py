"""Pallas stream kernels vs pure-jnp oracles: shape/dtype sweeps in
interpret mode (the required per-kernel allclose harness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.stream import kernels as K
from repro.kernels.stream import ref as R

SHAPES_2D = [(256, 512), (512, 1024), (64, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_copy_add_triads(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a, b, c = (_rand(k, shape, dtype) for k in ks)
    np.testing.assert_allclose(K.copy(a, interpret=True), R.copy(a),
                               **_tol(dtype))
    np.testing.assert_allclose(K.add(a, b, interpret=True), R.add(a, b),
                               **_tol(dtype))
    np.testing.assert_allclose(K.stream_triad(a, b, 2.5, interpret=True),
                               R.stream_triad(a, b, 2.5), **_tol(dtype))
    np.testing.assert_allclose(
        K.schoenauer_triad(a, b, c, interpret=True),
        R.schoenauer_triad(a, b, c), **_tol(dtype))
    np.testing.assert_allclose(K.update(a, 1.5, interpret=True),
                               R.update(a, 1.5), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES_2D)
def test_init_full_and_partial(shape):
    out = K.init_store(shape, 3.5, interpret=True)
    np.testing.assert_array_equal(out, R.init(shape, 3.5))
    m, n = shape[0] - 3, shape[1] - 28
    out2 = K.init_partial((m, n), 2.5, interpret=True)
    np.testing.assert_array_equal(out2, R.init((m, n), 2.5))


@pytest.mark.parametrize("shape", SHAPES_2D)
def test_sum_reduction(shape):
    a = _rand(jax.random.PRNGKey(1), shape, jnp.float32)
    got = K.sum_reduction(a, interpret=True)
    np.testing.assert_allclose(got, R.sum_reduction(a), rtol=1e-4)


@pytest.mark.parametrize("n", [65536, 262144])
def test_pi(n):
    np.testing.assert_allclose(K.pi_integration(n, interpret=True),
                               np.pi, rtol=1e-4)


@pytest.mark.parametrize("shape", [(130, 256), (66, 384), (258, 128)])
def test_jacobi_2d(shape):
    u = _rand(jax.random.PRNGKey(2), shape, jnp.float32)
    np.testing.assert_allclose(K.jacobi_2d5pt(u, interpret=True),
                               R.jacobi_2d5pt(u), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(18, 32, 128), (10, 16, 256)])
def test_jacobi_3d(shape):
    u = _rand(jax.random.PRNGKey(3), shape, jnp.float32)
    np.testing.assert_allclose(K.jacobi_3d7pt(u, interpret=True),
                               R.jacobi_3d7pt(u), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,sweeps", [((20, 128), 1), ((34, 128), 2)])
def test_gauss_seidel(shape, sweeps):
    u = _rand(jax.random.PRNGKey(4), shape, jnp.float32)
    np.testing.assert_allclose(
        K.gauss_seidel_2d5pt(u, sweeps, interpret=True),
        R.gauss_seidel_2d5pt(u, sweeps), rtol=1e-5, atol=1e-5)


def test_ref_jacobi_variants_consistent():
    """3d11pt/3d27pt oracles: spot checks on constant fields."""
    u = jnp.ones((12, 12, 12))
    np.testing.assert_allclose(R.jacobi_3d11pt(u), jnp.ones((8, 8, 8)),
                               rtol=1e-6)
    np.testing.assert_allclose(R.jacobi_3d27pt(u), jnp.ones((10, 10, 10)),
                               rtol=1e-6)
