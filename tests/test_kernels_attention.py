"""Flash-attention Pallas kernel vs exact oracle: GQA/window/dtype sweep
in interpret mode + the model-stack chunked implementation vs the same
oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import flash as F
from repro.kernels.attention import ref as R
from repro.models import attention as A

CASES = [
    # (h, hkv, s, dh, window, bq, bk)
    (4, 2, 256, 64, None, 64, 64),
    (8, 8, 128, 32, None, 32, 64),
    (4, 1, 256, 64, 96, 64, 32),
    (2, 2, 192, 128, None, 64, 64),
    (8, 4, 128, 64, 64, 32, 32),
]


@pytest.mark.parametrize("h,hkv,s,dh,window,bq,bk", CASES)
def test_flash_vs_ref(h, hkv, s, dh, window, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, h, s, dh), jnp.float32)
    k = jax.random.normal(ks[1], (2, hkv, s, dh), jnp.float32)
    v = jax.random.normal(ks[2], (2, hkv, s, dh), jnp.float32)
    out = F.flash_attention(q, k, v, bq=bq, bk=bk, window=window,
                            interpret=True)
    ref = R.attention(q, k, v, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out = F.flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    ref = R.attention(q, k, v)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("window", [None, 48])
def test_model_chunked_attention_vs_oracle(window):
    """The model stack's chunked-causal path against the dense oracle."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, hkv, dh = 2, 160, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32)
    got = A.chunked_causal_attention(q, k, v, q_chunk=32, kv_chunk=64,
                                     window=window)
    # oracle in BHSD layout
    ref = R.attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), window=window)
    np.testing.assert_allclose(got, jnp.swapaxes(ref, 1, 2),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_vs_oracle():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, hkv, dh = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, h, dh), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32)
    pos = 40
    got = A.decode_attention(q, kc, vc, jnp.int32(pos))
    # oracle: dense attention with q at position `pos`
    ref = A.dense_causal_attention(q, kc[:, :pos + 1], vc[:, :pos + 1],
                                   q_offset=pos)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
