"""Serve-engine behaviour: chunked decode matches the seed per-token
greedy loop token-for-token, admit/evict keeps per-slot streams
independent, donation keeps the decode cache update in place, and the
old `grow`-helper shape collision is pinned as a regression."""

import math
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import Request, ServeEngine, make_chunked_decode_step
from repro.serve.kv_traffic import kv_update_traffic
from repro.train import serve as serve_lib


def _params(cfg, seed=0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def _prompts(cfg, b, s, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size))


def _seed_greedy_loop(cfg, params, prompts, gen):
    """The seed serve loop: batched prefill + one decode step per token
    (cache preallocated at the horizon — the fixed version of the old
    jnp.pad regrow)."""
    b, s = prompts.shape
    prefill = jax.jit(serve_lib.make_prefill_step(cfg, cache_len=s + gen))
    decode = jax.jit(serve_lib.make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(gen - 1):
        lg, cache = decode(params, cache, {"tokens": tok[:, None]},
                           jnp.int32(s + i))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out, axis=1)


def _run_engine(cfg, params, prompts, gen, **kw):
    b = prompts.shape[0]
    eng = ServeEngine(cfg, params, max_slots=b,
                      max_len=prompts.shape[1] + gen, **kw)
    res = eng.run([Request(rid=str(i), prompt=tuple(int(t) for t in prompts[i]),
                           max_new_tokens=gen) for i in range(b)])
    return np.stack([res[str(i)] for i in range(b)]), eng


@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-4b", "xlstm-125m"])
def test_engine_matches_seed_greedy_loop(arch):
    cfg = get_smoke_config(arch)
    params = _params(cfg)
    prompts = _prompts(cfg, 4, 16)
    gen, chunk = 12, 4
    ref = _seed_greedy_loop(cfg, params, prompts, gen)
    got, eng = _run_engine(cfg, params, prompts, gen, chunk=chunk)
    np.testing.assert_array_equal(got, ref)
    # chunked dispatch budget: ceil(gen/chunk) instead of gen-1
    assert eng.decode_dispatches <= math.ceil(gen / chunk)
    assert eng.prefill_dispatches == 1          # batched admit fast path


def test_admit_evict_keeps_streams_independent():
    cfg = get_smoke_config("yi-9b")
    params = _params(cfg)
    rng = np.random.default_rng(0)
    # 3 requests on 2 slots with mixed prompt lengths and budgets:
    # c is admitted mid-flight (per-slot positions) after a retires
    reqs = [Request("a", tuple(rng.integers(0, cfg.vocab_size, 8)), 6),
            Request("b", tuple(rng.integers(0, cfg.vocab_size, 10)), 12),
            Request("c", tuple(rng.integers(0, cfg.vocab_size, 8)), 6)]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=24, chunk=3)
    res = eng.run(list(reqs))
    assert set(res) == {"a", "b", "c"}
    for r in reqs:
        solo = ServeEngine(cfg, params, max_slots=2, max_len=24, chunk=3)
        sres = solo.run([r])
        np.testing.assert_array_equal(
            res[r.rid], sres[r.rid],
            err_msg=f"stream {r.rid} disturbed by batch-mates")


def test_cancel_returns_partial_stream_and_frees_slot():
    """cancel() mid-flight hands back the tokens decoded so far (a
    prefix of the uncancelled stream), frees the slot, and the next
    request served from that slot is undisturbed."""
    cfg = get_smoke_config("yi-9b")
    params = _params(cfg)
    rng = np.random.default_rng(3)
    pa = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 8))
    pb = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 8))
    eng = ServeEngine(cfg, params, max_slots=1, max_len=24, chunk=3)
    assert eng.step() == []                     # idle engine: no-op
    eng.admit(Request("a", pa, 12))
    eng.step()                                  # a few tokens in flight
    assert eng.cancel("zzz") is None            # unknown rid
    part = eng.cancel("a")
    assert eng.free_slots() == [0]
    full = ServeEngine(cfg, params, max_slots=1, max_len=24,
                       chunk=3).run([Request("a", pa, 12)])["a"]
    assert 1 <= len(part) < len(full)
    np.testing.assert_array_equal(part, full[:len(part)])
    res = eng.run([Request("b", pb, 6)])
    solo = ServeEngine(cfg, params, max_slots=1, max_len=24,
                       chunk=3).run([Request("b", pb, 6)])
    np.testing.assert_array_equal(res["b"], solo["b"])


def test_decode_cache_update_stays_in_place():
    """Donation: no full-cache-leaf copy of the cache *arguments* in the
    lowered HLO (without donation XLA copies every KV buffer per chunk)."""
    cfg = get_smoke_config("yi-9b")
    b, horizon = 2, 24
    step = make_chunked_decode_step(cfg, 3)
    args = (M.param_shapes(cfg), M.cache_shapes(cfg, b, horizon),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    kv_leaf = jax.tree.leaves(M.cache_shapes(cfg, b, horizon))[0]
    sig = "bf16[" + ",".join(str(d) for d in kv_leaf.shape) + "]"

    def arg_copies(txt):
        return [ln for ln in txt.splitlines()
                if re.search(r"= " + re.escape(sig) + r"\S* copy\(", ln)
                and "%Arg_" in ln]

    donated = jax.jit(step, donate_argnums=(1,)).lower(
        *args).compile().as_text()
    plain = jax.jit(step).lower(*args).compile().as_text()
    assert "input_output_alias" in donated
    assert len(arg_copies(plain)) >= 2      # detector sanity: K and V copied
    assert len(arg_copies(donated)) == 0    # in-place with donation


def test_grow_shape_collision_regression():
    """The old launch/serve.py `grow` matched cache leaves by
    `x.shape[1] == s` / `x.shape[2] == s`: with prompt_len == n_heads the
    mLSTM state (B, H, Dh, Dh) / (R, B, H, Dh, Dh) collides and the heads
    axis got padded. Slot preallocation replaces shape-guessing entirely."""
    cfg = get_smoke_config("xlstm-125m")
    s = cfg.n_heads                            # the colliding prompt length
    gen = 6
    prompts = _prompts(cfg, 2, s)
    params = _params(cfg)
    _, cache = jax.jit(serve_lib.make_prefill_step(cfg))(
        params, {"tokens": jnp.asarray(prompts)})

    def old_grow(x):                           # verbatim old helper
        if x.ndim == 4 and x.shape[1] == s:
            return jnp.pad(x, [(0, 0), (0, gen), (0, 0), (0, 0)])
        if x.ndim == 5 and x.shape[2] == s:
            return jnp.pad(x, [(0, 0), (0, 0), (0, gen), (0, 0), (0, 0)])
        return x
    grown = jax.tree.map(old_grow, cache)
    want = M.cache_shapes(cfg, 2, s + gen)
    mismatched = [g.shape for g, w in zip(jax.tree.leaves(grown),
                                          jax.tree.leaves(want))
                  if g.shape != w.shape]
    assert mismatched, "old grow no longer misfires — update this pin"

    # the engine serves the same shape correctly
    ref = _seed_greedy_loop(cfg, params, prompts, gen)
    got, _ = _run_engine(cfg, params, prompts, gen, chunk=2)
    np.testing.assert_array_equal(got, ref)


def test_recurrent_state_dtype_stable_in_chunk():
    """Mamba conv state comes back in compute dtype; the chunk scan must
    pin the carry to the cache contract (f32) instead of type-erroring."""
    cfg = get_smoke_config("jamba-v0.1-52b")
    params = _params(cfg)
    prompts = _prompts(cfg, 2, 8)
    got, _ = _run_engine(cfg, params, prompts, 6, chunk=3)
    assert got.shape == (2, 6)


def test_temperature_sampling_in_graph():
    cfg = get_smoke_config("yi-9b")
    params = _params(cfg)
    prompts = _prompts(cfg, 2, 8)
    got, eng = _run_engine(cfg, params, prompts, 8, chunk=4,
                           temperature=0.8, seed=3)
    assert got.shape == (2, 8)
    assert eng.decode_dispatches <= math.ceil(8 / 4)
    got2, _ = _run_engine(cfg, params, prompts, 8, chunk=4,
                          temperature=0.8, seed=3)
    np.testing.assert_array_equal(got, got2)   # seeded: reproducible


def test_kv_traffic_donation_delta_positive():
    cfg = get_smoke_config("gemma3-4b")
    rows = kv_update_traffic(cfg, 4, 48)
    assert {r["machine"] for r in rows} >= {"zen4", "golden_cove",
                                            "neoverse_v2"}
    by = {r["machine"]: r for r in rows}
    for r in rows:
        assert r["delta_bytes"] > 0, r         # donation always cheaper
        assert r["copied_bytes"] > r["donated_bytes"]
    # paper ordering on the in-place path: Grace <= SPR <= Zen 4
    assert (by["neoverse_v2"]["donated_bytes"]
            <= by["golden_cove"]["donated_bytes"]
            <= by["zen4"]["donated_bytes"])


def test_zero_and_one_token_budgets():
    cfg = get_smoke_config("yi-9b")
    params = _params(cfg)
    prompts = _prompts(cfg, 2, 8)
    ref = _seed_greedy_loop(cfg, params, prompts, 1)
    got, eng = _run_engine(cfg, params, prompts, 1, chunk=2)
    np.testing.assert_array_equal(got, ref)
    assert eng.decode_dispatches == 0          # prefill already yields tok0
    # zero/negative budgets and over-horizon prompts are rejected clearly
    eng2 = ServeEngine(cfg, params, max_slots=1, max_len=16, chunk=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng2.admit(Request("z", tuple(prompts[0]), 0))
    with pytest.raises(ValueError, match="horizon"):
        eng2.admit(Request("h", tuple(range(12)), 8))
    # out-of-vocab ids are rejected at admission: the jitted embedding
    # gather NaN-fills OOB rows, silently poisoning the whole stream
    with pytest.raises(ValueError, match="prompt ids"):
        eng2.admit(Request("v", (1, cfg.vocab_size, 2), 2))
    with pytest.raises(ValueError, match="prompt ids"):
        eng2.admit(Request("n", (-1, 2, 3), 2))
    assert eng2.free_slots() == [0]            # nothing half-admitted
