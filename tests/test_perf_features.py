"""Tests for the §Perf optimization features: int8-moment AdamW, fused
mamba scan, multi-token decode loop, serve-rule variants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models import ssm
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state
from repro.train import step as step_lib
from repro.train.serve import make_decode_loop_step, make_prefill_step
from repro.utils.sharding import (SERVE_FSDP_GATHER_RULES, SERVE_FSDP_RULES,
                                  spec_for)


def test_int8_moments_converge_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=1, total_steps=300,
                   weight_decay=0.0, clip_norm=100.0, moments_dtype="int8")
    params = {"w": jnp.array([[5.0, -3.0, 2.0]])}
    opt = init_opt_state(params, "int8")
    step = jnp.zeros((), jnp.int32)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(oc, params, grads, opt, step)
        step = step + 1
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


def test_int8_state_shapes_and_specs():
    cfg = get_smoke_config("yi-9b")
    oc = OptConfig(moments_dtype="int8")
    shapes = step_lib.train_state_shapes(cfg, oc)
    m = shapes["opt"]["m"]
    leaf = jax.tree.leaves(m, is_leaf=lambda x: isinstance(x, dict)
                           and set(x) == {"q", "s"})[0]
    assert leaf["q"].dtype == jnp.int8
    assert leaf["s"].dtype == jnp.float32
    specs = step_lib.train_state_pspecs(
        cfg, {"embed": ("data",), "mlp": ("model",), "qheads": ("model",),
              "kvheads": ("model",), "vocab": ("model",), "stack": (),
              None: ()}, {"data": 2, "model": 2}, oc)
    s_tree = jax.tree.structure(shapes)
    from jax.sharding import PartitionSpec as P
    p_tree = jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))
    assert s_tree == p_tree


def test_fused_mamba_scan_matches_unfused():
    b, t, d, n = 2, 40, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, t, d)))
    bm = jax.random.normal(ks[1], (b, t, n))
    cm = jax.random.normal(ks[2], (b, t, n))
    x = jax.random.normal(ks[3], (b, t, d))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)))
    h0 = jnp.zeros((b, d, n))
    a_bar = jnp.exp(dt[..., None] * a)
    u = (dt * x)[..., None] * bm[..., None, :]
    y1, h1 = ssm._ssm_scan_chunked(a_bar, u, cm, h0, 16)
    y2, h2 = ssm._ssm_scan_chunked_fused(dt, bm, cm, x, a, h0, 16)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)


def test_jamba_fused_flag_equivalence():
    cfg = get_smoke_config("jamba-v0.1-52b")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    l1, _ = M.forward(cfg, params, {"tokens": toks})
    cfg2 = dataclasses.replace(cfg, ssm_fuse=False)
    l2, _ = M.forward(cfg2, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_loop_matches_stepwise():
    cfg = get_smoke_config("yi-9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    logits, cache = jax.jit(make_prefill_step(cfg))(params, {"tokens": toks})
    grow = lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 3) +
                             [(0, 6), (0, 0), (0, 0)]) \
        if x.ndim in (4, 5) and x.shape[-3] == 16 else x
    cache = jax.tree.map(grow, cache)
    tok0 = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    loop = jax.jit(make_decode_loop_step(cfg, 6))
    toks_loop, _ = loop(params, cache, {"tokens": tok0}, jnp.int32(16))

    # stepwise greedy with the plain decode step
    from repro.train.serve import make_decode_step
    dec = jax.jit(make_decode_step(cfg))
    cur = tok0
    got = []
    c = cache
    for i in range(6):
        lg, c = dec(params, c, {"tokens": cur}, jnp.int32(16 + i))
        cur = jnp.argmax(lg, -1).astype(jnp.int32)[:, None]
        got.append(cur[:, 0])
    # loop emits the INPUT token of each step's successor; align: the loop
    # returns tokens generated after consuming tok0 sequentially
    np.testing.assert_array_equal(np.asarray(toks_loop),
                                  np.stack(got, axis=1))


def test_serve_rule_variants_differ():
    sizes = {"data": 16, "model": 16}
    w = (8192, 64, 128)   # wq
    gather = spec_for(w, ("embed", "qheads", None),
                      SERVE_FSDP_GATHER_RULES, sizes)
    res2d = spec_for(w, ("embed", "qheads", None), SERVE_FSDP_RULES, sizes)
    assert gather == res2d            # weights sharded identically
    act = (128, 1, 8192)
    a_g = spec_for(act, ("act_batch", None, "act_embed"),
                   SERVE_FSDP_GATHER_RULES, sizes)
    a_r = spec_for(act, ("act_batch", None, "act_embed"),
                   SERVE_FSDP_RULES, sizes)
    assert a_g[0] is not None and a_g[2] is None    # batch-sharded acts
    assert a_r[0] is None and a_r[2] is not None    # d-sharded acts