"""Split-KV flash-decode kernel, MemTier tile autotuner, and the serve
wiring around them: interpret-mode parity against the dense decode
oracle, cross-machine tile divergence (the tuner must actually read the
ladders), planner memoization, and in-place cache updates with the
kernel routed into the serve decode step."""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import portmodel
from repro.kernels import tuning, use_pallas
from repro.kernels.attention import decode as D
from repro.kernels.attention import ops as kops
from repro.models import attention as A
from repro.models import model as M
from repro.serve import decode_read_traffic, plan_chunk_size
from repro.serve import planner as planner_lib
from repro.serve.decode import make_chunked_decode_step

PAPER_CPUS = ("zen4", "golden_cove", "neoverse_v2")


# --- kernel parity (interpret mode on CPU) ---------------------------------

def _rand_case(b, skv, h, hkv, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), jnp.float32)
    return q, k, v


CASES = [
    # (b, skv, h, hkv, dh, window, bk, n_splits, pos)
    (2, 64, 4, 2, 32, None, 32, 1, 40),            # GQA g=2
    (2, 64, 8, 2, 32, None, 16, 2, 63),            # g=4, splits
    (3, 80, 4, 1, 32, None, 32, 2, [3, 40, 79]),   # MQA, Skv % bk != 0
    (2, 96, 4, 4, 64, 24, 32, 3, [10, 90]),        # window, per-slot pos
    (2, 50, 4, 2, 32, 16, 16, 1, 49),              # window, ragged Skv
]


@pytest.mark.parametrize("b,skv,h,hkv,dh,window,bk,ns,pos", CASES)
def test_flash_decode_vs_decode_attention(b, skv, h, hkv, dh, window,
                                          bk, ns, pos):
    q, k, v = _rand_case(b, skv, h, hkv, dh)
    pos = jnp.asarray(pos, jnp.int32)
    got = D.flash_decode(q, k, v, pos, window=window, bk=bk, n_splits=ns,
                         interpret=True)
    ref = A.decode_attention(q, k, v, pos, window=window)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_decode_multi_token():
    """Sq>1: query tokens at pos..pos+Sq-1, causal among themselves."""
    b, skv, h, hkv, dh, sq, pos0 = 2, 64, 4, 2, 32, 3, 17
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), jnp.float32)
    got = D.flash_decode(q, k, v, jnp.int32(pos0), bk=16, n_splits=2,
                         interpret=True)
    ref = A.dense_causal_attention(q, k[:, :pos0 + sq], v[:, :pos0 + sq],
                                   q_offset=pos0)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_ref_decode_bounded_matches_dense():
    """The occupancy-bounded oracle == the dense path whenever the bound
    covers every slot's position (the router's contract)."""
    q, k, v = _rand_case(2, 64, 4, 2, 32, seed=2)
    pos = jnp.asarray([5, 30], jnp.int32)
    ref = A.decode_attention(q, k, v, pos)
    for kv_len in (31, 48, 64):
        got = D.ref_decode(q, k, v, pos, kv_len=kv_len)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_ops_routing_and_bounds():
    q, k, v = _rand_case(2, 64, 4, 2, 32, seed=3)
    pos = jnp.asarray([9, 21], jnp.int32)
    ref = A.decode_attention(q, k, v, pos)
    # every impl, with and without an occupancy bound, same numerics
    for impl in ("ref", "auto", "pallas"):
        for kv_len in (None, 22, 40):
            got = kops.flash_decode(q, k, v, pos, impl=impl, kv_len=kv_len)
            np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{impl}/{kv_len}")
    with pytest.raises(ValueError, match="unknown impl"):
        use_pallas("cuda")


def test_decode_attention_impl_routes_through_ops():
    q, k, v = _rand_case(2, 48, 4, 2, 32, seed=4)
    pos = jnp.int32(30)
    ref = A.decode_attention(q, k, v, pos)
    got = A.decode_attention(q, k, v, pos, impl="pallas")
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
    got = A.decode_attention(q, k, v, pos, impl="auto", kv_len=31)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


# --- paged kernel parity (interpret mode on CPU) ---------------------------

def _paginate(k, v, ps, seed=7, n_extra=3):
    """Scatter a dense (B, Skv, Hkv, Dh) cache into a shared page pool.

    Physical pages are assigned through a *permuted* (out-of-order)
    block table, extra unmapped pages and the partial-last-page tail are
    filled with garbage, so parity only holds if the kernel really
    gathers through the table and masks by logical position.
    """
    b, skv, hkv, dh = k.shape
    nb = -(-skv // ps)
    rng = np.random.default_rng(seed)
    n_pages = b * nb + n_extra
    perm = rng.permutation(n_pages)[:b * nb].reshape(b, nb)
    kp = rng.standard_normal((n_pages, ps, hkv, dh)).astype(np.float32)
    vp = rng.standard_normal((n_pages, ps, hkv, dh)).astype(np.float32)
    kd, vd = np.asarray(k), np.asarray(v)
    for i in range(b):
        for j in range(nb):
            rows = min(ps, skv - j * ps)        # partial last page: the
            kp[perm[i, j], :rows] = kd[i, j * ps:j * ps + rows]
            vp[perm[i, j], :rows] = vd[i, j * ps:j * ps + rows]
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(perm, jnp.int32)


PAGED_CASES = [
    # (b, skv, h, hkv, dh, window, ps, n_splits, pos)
    (2, 64, 4, 2, 32, None, 16, 1, 40),            # GQA g=2
    (2, 64, 8, 2, 32, None, 8, 2, 63),             # g=4, splits
    (3, 80, 4, 1, 32, None, 16, 2, [3, 40, 79]),   # MQA, ragged pos
    (2, 96, 4, 4, 64, 24, 16, 3, [10, 90]),        # sliding window
    (2, 50, 4, 2, 32, 16, 16, 1, 49),              # partial last page
]


@pytest.mark.parametrize("b,skv,h,hkv,dh,window,ps,ns,pos", PAGED_CASES)
def test_flash_decode_paged_vs_dense(b, skv, h, hkv, dh, window, ps, ns,
                                     pos):
    q, k, v = _rand_case(b, skv, h, hkv, dh)
    pos = jnp.asarray(pos, jnp.int32)
    kp, vp, bt = _paginate(k, v, ps)
    got = D.flash_decode_paged(q, kp, vp, bt, pos, window=window,
                               n_splits=ns, interpret=True)
    ref = A.decode_attention(q, k, v, pos, window=window)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_flash_decode_paged_multi_token():
    """Sq>1 against the page pool: causal among the query tokens."""
    b, skv, h, hkv, dh, sq, pos0 = 2, 64, 4, 2, 32, 3, 17
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, dh), jnp.float32)
    kp, vp, bt = _paginate(k, v, 8)
    got = D.flash_decode_paged(q, kp, vp, bt, jnp.int32(pos0),
                               n_splits=2, interpret=True)
    ref = A.dense_causal_attention(q, k[:, :pos0 + sq], v[:, :pos0 + sq],
                                   q_offset=pos0)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_ref_decode_paged_matches_dense():
    """The pure-JAX paged oracle (the serve path off-TPU) is exact."""
    q, k, v = _rand_case(2, 64, 4, 2, 32, seed=2)
    pos = jnp.asarray([5, 30], jnp.int32)
    kp, vp, bt = _paginate(k, v, 8)
    got = D.ref_decode_paged(q, kp, vp, bt, pos)
    ref = A.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_paged_ops_routing_and_page_bound():
    """ops.flash_decode_paged: every impl, with and without a kv_len
    occupancy bound (sliced at page granularity), same numerics."""
    q, k, v = _rand_case(2, 64, 4, 2, 32, seed=3)
    pos = jnp.asarray([9, 21], jnp.int32)
    kp, vp, bt = _paginate(k, v, 8)
    ref = A.decode_attention(q, k, v, pos)
    for impl in ("ref", "auto", "pallas"):
        for kv_len in (None, 22, 40):
            got = kops.flash_decode_paged(q, kp, vp, bt, pos, impl=impl,
                                          kv_len=kv_len)
            np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{impl}/{kv_len}")


def test_paged_dead_table_columns_are_masked():
    """Columns past a slot's live pages may hold *any* valid page id
    (the engine maps them to the scratch page; recycled tables may
    alias other slots' pages) — logical-position masking must zero
    them regardless of what they point at."""
    q, k, v = _rand_case(2, 64, 4, 2, 32, seed=5)
    pos = jnp.asarray([9, 21], jnp.int32)       # live pages: 2 and 3
    kp, vp, bt = _paginate(k, v, 8)
    rng = np.random.default_rng(11)
    bad = np.asarray(bt).copy()
    for i, live in enumerate([2, 3]):
        bad[i, live:] = rng.integers(0, kp.shape[0], bad.shape[1] - live)
    ref = A.decode_attention(q, k, v, pos)
    for ns in (1, 2):
        got = D.flash_decode_paged(q, kp, vp, jnp.asarray(bad, jnp.int32),
                                   pos, n_splits=ns, interpret=True)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


# --- MemTier-driven autotuner ----------------------------------------------

def test_autotuned_tiles_differ_across_machines():
    """The acceptance pin: tiling must be derived from the ladders, so at
    least two registered machines must disagree — for both kernels."""
    shape = dict(s=4096, dh=64, h=8, hkv=8)
    flash = {name: tuning.flash_tiles(name, **shape)
             for name in ("tpu_v5e", *PAPER_CPUS)}
    assert len({(p.bq, p.bk) for p in flash.values()}) >= 2, flash
    dshape = dict(skv=4096, dh=64, h=8, hkv=2, batch=4)
    dec = {name: tuning.decode_tiles(name, **dshape)
           for name in ("tpu_v5e", *PAPER_CPUS)}
    assert len({(p.bk, p.n_splits) for p in dec.values()}) >= 2, dec


def test_autotuner_reads_the_ladder_not_constants():
    """A 128 MB-VMEM TPU keeps its score tile on-chip; the paper CPUs
    spill it to a cache level — and the many-core sockets shard the KV
    stream over splits while single-core machines must not."""
    tpu = tuning.flash_tiles("tpu_v5e", s=4096, dh=64, h=8, hkv=8)
    z4 = tuning.flash_tiles("zen4", s=4096, dh=64, h=8, hkv=8)
    assert tpu.home_tier == "VMEM"
    assert z4.home_tier in ("L1", "L2")
    assert z4.ws_bytes < tpu.ws_bytes      # pushed to a smaller tile
    tpu_d = tuning.decode_tiles("tpu_v5e", skv=4096, dh=64, h=8, hkv=2,
                                batch=4)
    z4_d = tuning.decode_tiles("zen4", skv=4096, dh=64, h=8, hkv=2,
                               batch=4)
    assert tpu_d.n_splits == 1             # one core drives the grid
    assert z4_d.n_splits > 1               # 96-core socket shards KV


def test_autotuned_defaults_replace_hardcoded_512s():
    """ops.flash_attention with no explicit tiles must run the autotuned
    plan (pinned by numerics parity at a shape where 512 won't divide)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 4, 160, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 160, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 160, 32), jnp.float32)
    out = kops.flash_attention(q, k, v, impl="pallas")
    ref = kops.flash_attention(q, k, v, impl="ref")
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_fit_block_snaps_to_largest_divisor():
    """Autotuned tiles must snap to *large* divisors of s, and the raw
    kernel must accept its own defaults at lengths the 512s divided."""
    assert tuning.fit_block(1024, 1536) == 768
    assert tuning.fit_block(256, 1000) == 250      # gcd would give 8
    assert tuning.fit_block(512, 512) == 512
    assert tuning.fit_block(64, 7) == 7
    from repro.kernels.attention import flash as F
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 1, 1536, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1, 1536, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1, 1536, 16), jnp.float32)
    out = F.flash_attention(q, k, v, interpret=True)   # default tiles
    from repro.kernels.attention import ref as R
    np.testing.assert_allclose(out, R.attention(q, k, v),
                               rtol=2e-5, atol=2e-5)


def test_reported_plan_matches_executed_plan():
    """decode_read_traffic / planner must price the tiling the kernel
    path actually runs: tuned at the occupancy bound, not the horizon."""
    from repro.serve.kv_traffic import bounded_decode_plan
    cfg = get_smoke_config("yi-9b")
    batch, max_len, occ = 4, 2048, 65
    plan, bound = bounded_decode_plan(cfg, batch, max_len, occ, "zen4")
    executed = tuning.decode_tiles(
        "zen4", skv=occ, dh=cfg.head_dim_eff, h=cfg.n_heads,
        hkv=cfg.n_kv_heads, batch=batch, dtype=cfg.param_dtype)
    assert (plan.bk, plan.n_splits) == (executed.bk, executed.n_splits)
    assert bound == min(-(-occ // executed.bk) * executed.bk, max_len)
    row = decode_read_traffic(cfg, batch, max_len, occ,
                              machines=("zen4",))[0]
    assert row["bk"] == executed.bk
    assert row["split_read_bytes"] == pytest.approx(
        bound / max_len * row["dense_read_bytes"])


# --- planner memoization + kernel pricing ----------------------------------

def test_plan_chunk_size_memoized(monkeypatch):
    cfg = get_smoke_config("yi-9b")
    planner_lib.clear_plan_cache()
    calls = {"n": 0}
    real = portmodel.compare

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(planner_lib.portmodel, "compare", counting)
    p1 = plan_chunk_size(cfg, 2, 32)
    assert calls["n"] == 1
    p2 = plan_chunk_size(cfg, 2, 32)
    assert calls["n"] == 1                  # repeat admission: O(1) hit
    assert p2 is p1
    # a different shape is a different key, not a stale hit
    plan_chunk_size(cfg, 2, 48)
    assert calls["n"] == 2


def test_plan_kernel_pricing_occupancy_bounded():
    """With an occupancy bound the planner re-prices the KV stream: the
    kernel-path step can only get cheaper, and the dense table rides
    along for reporting."""
    cfg = get_smoke_config("yi-9b")
    planner_lib.clear_plan_cache()
    dense = plan_chunk_size(cfg, 4, 256)
    kern = plan_chunk_size(cfg, 4, 256, occupancy=32)
    assert kern.occupancy == 32 and dense.occupancy is None
    assert kern.per_machine_dense is not None
    for name, t in kern.per_machine.items():
        assert t <= kern.per_machine_dense[name] + 1e-15, name
    assert kern.t_step_seconds <= dense.t_step_seconds + 1e-15


def test_decode_read_traffic_ratio_gt1_on_paper_cpus():
    """Acceptance: dense/split KV-read ratio > 1 on all three paper CPUs
    (and exactly 1 only when the cache is full)."""
    cfg = get_smoke_config("yi-9b")
    rows = {r["machine"]: r
            for r in decode_read_traffic(cfg, 4, 512, 64)}
    for name in PAPER_CPUS:
        assert rows[name]["read_ratio"] > 1, rows[name]
        assert rows[name]["split_read_bytes"] < rows[name]["dense_read_bytes"]
    full = decode_read_traffic(cfg, 4, 512, 512)
    assert all(r["read_ratio"] == 1.0 for r in full)


# --- serve decode step with the kernel routed in ---------------------------

def test_serve_chunked_decode_in_place_with_kernel():
    """HLO check: routing the split-KV kernel into the serve chunked
    decode step must not break cache donation — the per-token KV
    dynamic-update-slice still happens in place."""
    cfg = get_smoke_config("yi-9b")
    b, horizon = 2, 24
    step = make_chunked_decode_step(cfg, 2, attn_impl="pallas",
                                    kv_len=horizon)
    args = (M.param_shapes(cfg), M.cache_shapes(cfg, b, horizon),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.eval_shape(lambda: jax.random.PRNGKey(0)))
    kv_leaf = jax.tree.leaves(M.cache_shapes(cfg, b, horizon))[0]
    sig = "bf16[" + ",".join(str(d) for d in kv_leaf.shape) + "]"

    def arg_copies(txt):
        return [ln for ln in txt.splitlines()
                if re.search(r"= " + re.escape(sig) + r"\S* copy\(", ln)
                and "%Arg_" in ln]

    donated = jax.jit(step, donate_argnums=(1,)).lower(
        *args).compile().as_text()
    assert "input_output_alias" in donated
    assert len(arg_copies(donated)) == 0    # in-place with donation
    assert "dynamic-update-slice" in donated


def test_chunked_decode_kernel_path_token_parity():
    """The kernel-routed chunked decode emits the same tokens as the
    dense path (greedy, per-slot positions)."""
    cfg = get_smoke_config("yi-9b")
    b, horizon, n = 2, 24, 3
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    cache = M.init_cache(cfg, b, horizon)
    tok = jnp.asarray([[3], [7]], jnp.int32)
    pos = jnp.asarray([0, 4], jnp.int32)
    dense = make_chunked_decode_step(cfg, n)
    routed = make_chunked_decode_step(cfg, n, attn_impl="auto",
                                      kv_len=pos.max().item() + n)
    t0, _, _ = jax.jit(dense)(params, cache, tok, pos, key)
    t1, _, _ = jax.jit(routed)(params, M.init_cache(cfg, b, horizon),
                               tok, pos, key)
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))
