"""Fault injector: schedules, engine-surface conformance, and the
in-graph non-finite guard's quarantine path on both cache layouts.

The injector is the test harness for the whole fault-tolerance layer,
so its own determinism is load-bearing: identical seeds must yield
identical schedules, injected step faults must fire exactly at their
indices, and NaN poison must be caught by the engines' guard (and
scrubbed afterwards so recycled pages can't re-poison later streams).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import (FaultSpec, FaultyEngine, PagedServeEngine,
                         PoolExhausted, Request, ServeEngine,
                         TransientFault, chaos_schedule)
from repro.serve.faults import poison_slot, scrub_nonfinite

SLOTS, MAX_LEN, CHUNK = 2, 32, 2


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("xlstm-125m")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _req(rid, budget=6, base=1):
    return Request(rid, tuple(range(base, base + 4)), budget)


def _dense(cfg, params, **kw):
    return ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                       chunk=CHUNK, **kw)


def _paged(cfg, params, **kw):
    return PagedServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                            chunk=CHUNK, page_size=4, **kw)


def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultSpec("meteor_strike", frozenset({1}))


def test_chaos_schedule_is_seed_deterministic():
    rates = {"stuck": 0.3, "nonfinite": 0.2, "admit_error": 0.25}
    a = chaos_schedule(3, 40, rates, slots=SLOTS)
    b = chaos_schedule(3, 40, rates, slots=SLOTS)
    assert a == b
    assert a != chaos_schedule(4, 40, rates, slots=SLOTS)
    kinds = {f.kind for f in a}
    assert kinds <= {"stuck", "nonfinite", "admit_error"}
    # nonfinite targets round-robin over slots
    slots = [f.slot for f in a if f.kind == "nonfinite"]
    assert all(0 <= s < SLOTS for s in slots)


def test_step_error_and_stuck_and_slow(cfg, params):
    eng = FaultyEngine(
        _dense(cfg, params),
        [FaultSpec("step_error", frozenset({0})),
         FaultSpec("stuck", frozenset({1})),
         FaultSpec("slow", frozenset({2}), factor=7.0)],
        budget_s=1e-3)
    eng.admit(_req("a"))
    with pytest.raises(TransientFault):
        eng.step()
    before = list(eng.slots[0].out)
    assert eng.step() == []                       # stuck: no progress
    assert eng.slots[0].out == before
    assert eng.last_step_seconds == pytest.approx(50e-3)
    eng.step()                                    # slow: progresses
    assert len(eng.slots[0].out) > len(before)
    assert eng.last_step_seconds == pytest.approx(7e-3)
    eng.step()                                    # healthy again
    assert eng.last_step_seconds == pytest.approx(1e-3)
    assert eng.injected == {"step_error": 1, "stuck": 1, "slow": 1}


def test_admission_faults(cfg, params):
    eng = FaultyEngine(
        _dense(cfg, params),
        [FaultSpec("admit_error", frozenset({0})),
         FaultSpec("pool_exhausted", frozenset({1}))],
        budget_s=1e-3)
    with pytest.raises(TransientFault):
        eng.admit(_req("a"))
    with pytest.raises(PoolExhausted):
        eng.admit(_req("a"))
    assert eng.admit(_req("a")) == 0              # third attempt lands


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_nonfinite_poison_quarantines_only_victim(cfg, params, layout):
    mk = _dense if layout == "dense" else _paged
    eng = mk(cfg, params)
    eng.admit(_req("victim", base=1))
    eng.admit(_req("bystander", base=2))
    poison_slot(eng, 0)
    retired = eng.step()
    assert retired == []
    q = eng.drain_quarantined()
    assert [rid for rid, _ in q] == ["victim"]
    assert eng.slots[0] is None                   # slot freed
    assert eng.slots[1] is not None               # batchmate unharmed
    scrub_nonfinite(eng)
    # bystander must finish with a fully finite stream
    out = {}
    for _ in range(8):
        out.update({r: t for r, t in eng.step()})
        if all(s is None for s in eng.slots):
            break
    assert "bystander" in out


def test_scrub_keeps_healthy_rows_bit_exact(cfg, params):
    eng = _dense(cfg, params)
    eng.admit(_req("a", base=1))
    eng.admit(_req("b", base=2))
    healthy = [np.asarray(leaf).copy()
               for leaf in jax.tree.leaves(eng.cache)]
    poison_slot(eng, 0)
    scrub_nonfinite(eng)
    for before, after in zip(healthy, jax.tree.leaves(eng.cache)):
        a = np.asarray(after)
        assert np.isfinite(a[np.isfinite(a)]).all()
        if a.ndim >= 2 and a.shape[1] == SLOTS:   # slot-batched leaf
            np.testing.assert_array_equal(before[:, 1], a[:, 1])


def test_faulty_engine_delegates_surface(cfg, params):
    inner = _dense(cfg, params)
    eng = FaultyEngine(inner, [], budget_s=1e-3)
    assert eng.max_slots == SLOTS and eng.chunk == CHUNK
    eng.admit(_req("a"))
    assert eng.free_slots() == [1]
    assert eng.cancel("a") is not None
    assert eng.free_slots() == [0, 1]
    eng.set_chunk(3)                              # delegated mutator
    assert inner.chunk == 3


def test_faultless_wrapper_streams_identical(cfg, params):
    reqs = [_req(f"r{i}", base=i + 1) for i in range(3)]
    plain = _dense(cfg, params).run(list(reqs))
    wrapped = FaultyEngine(_dense(cfg, params), [], budget_s=1e-3)
    got = {}
    for r in reqs[:SLOTS]:
        wrapped.admit(r)
    pending = list(reqs[SLOTS:])
    for _ in range(32):
        for rid, toks in wrapped.step():
            got[rid] = toks
        while pending and wrapped.free_slots():
            wrapped.admit(pending.pop(0))
        if not pending and all(s is None for s in wrapped.slots):
            break
    for r in reqs:
        np.testing.assert_array_equal(got[r.rid], plain[r.rid])
