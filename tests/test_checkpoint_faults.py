"""Checkpoint/restart + fault-tolerance machinery."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.faults import (HeartbeatRegistry, RestartManager,
                                 StragglerDetector, elastic_mesh_shape)


def _tree(k=0):
    return {"a": jnp.arange(12.0).reshape(3, 4) + k,
            "b": {"c": jnp.ones((5,), jnp.int32) * k}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(3)
    ck.save(7, t, block=True)
    assert ck.all_steps() == [7]
    step, got = ck.restore_latest(_tree(0))
    assert step == 7
    np.testing.assert_array_equal(got["a"], t["a"])
    np.testing.assert_array_equal(got["b"]["c"], t["b"]["c"])


def test_checkpoint_gc_keeps_last(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), block=True)
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))       # async
    ck.wait()
    assert ck.latest_step() == 1


def test_restart_manager_recovers(tmp_path):
    ck = Checkpointer(str(tmp_path))
    rm = RestartManager(ck, ckpt_every=2)

    def step_fn(state, step):
        return {"x": state["x"] + 1}

    state, end = rm.run({"x": jnp.zeros(())}, step_fn, 10,
                        inject_failure_at=5)
    assert rm.restarts == 1
    assert end == 10
    assert float(state["x"]) == 10.0   # recomputed steps after restore


def test_straggler_detector_flags_outlier():
    d = StragglerDetector(warmup=5, z_thresh=3.0)
    flagged = []
    for i in range(30):
        dt = 0.1 + 0.001 * (i % 3)
        flagged.append(d.observe(dt))
    assert not any(flagged)
    assert d.observe(1.5) is True      # 15x step time


def test_heartbeats():
    h = HeartbeatRegistry(4, miss_budget=2)
    for host in range(4):
        h.beat(host, t=100.0)
    h.beat(0, t=200.0)
    assert h.sweep(timeout=50.0, now=210.0) == []     # first miss
    dead = h.sweep(timeout=50.0, now=211.0)
    assert set(dead) == {1, 2, 3}


@given(st.integers(1, 4096))
def test_elastic_mesh_shape_properties(n):
    shape = elastic_mesh_shape(n)
    total = 1
    for d in shape:
        assert d >= 1
        total *= d
    assert total <= n
    # model axis is a power-of-two divisor of the per-pod chips
    assert shape[-1] & (shape[-1] - 1) == 0


def test_elastic_prefers_model_width():
    assert elastic_mesh_shape(256)[-1] == 16
    assert elastic_mesh_shape(512) == (2, 16, 16)
    # degraded pod: model axis preserved when divisible
    assert elastic_mesh_shape(240)[-1] == 16
