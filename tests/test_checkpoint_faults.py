"""Checkpoint/restart + fault-tolerance machinery."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.faults import (HeartbeatRegistry, RestartManager,
                                 StragglerDetector, elastic_mesh_shape)


def _tree(k=0):
    return {"a": jnp.arange(12.0).reshape(3, 4) + k,
            "b": {"c": jnp.ones((5,), jnp.int32) * k}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(3)
    ck.save(7, t, block=True)
    assert ck.all_steps() == [7]
    step, got = ck.restore_latest(_tree(0))
    assert step == 7
    np.testing.assert_array_equal(got["a"], t["a"])
    np.testing.assert_array_equal(got["b"]["c"], t["b"]["c"])


def test_checkpoint_gc_keeps_last(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), block=True)
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))       # async
    ck.wait()
    assert ck.latest_step() == 1


def _crashing_put(fail_at):
    """A ``_put`` that dies on its ``fail_at``-th file write."""
    calls = {"n": 0}
    orig = Checkpointer._put

    def put(path, writer):
        if calls["n"] == fail_at:
            raise RuntimeError("simulated disk death")
        calls["n"] += 1
        orig(path, writer)
    return put


@pytest.mark.parametrize("fail_at", [0, 1, 2, 3])
def test_mid_write_crash_never_tears_snapshot(tmp_path, fail_at):
    # a step writes 2 leaves + manifest + COMMIT = 4 files; failing at
    # each index simulates dying during leaves, manifest, or COMMIT
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), block=True)
    ck._put = _crashing_put(fail_at)
    ck.save(2, _tree(2))
    with pytest.raises(RuntimeError, match="background checkpoint"):
        ck.wait()
    assert ck.all_steps() == [1]          # torn write invisible
    step, got = ck.restore_latest(_tree(0))
    assert step == 1
    np.testing.assert_array_equal(got["a"], _tree(1)["a"])
    del ck._put                           # disk "recovers"
    ck.save(2, _tree(2), block=True)      # clobbers the leftover .tmp
    assert ck.all_steps() == [1, 2]


def test_crash_between_commit_and_rename(tmp_path, monkeypatch):
    import repro.checkpoint.checkpointer as C
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), block=True)
    orig = C.os.replace

    def replace(src, dst):
        if src.endswith(".tmp"):          # the final directory rename
            raise RuntimeError("killed before rename")
        orig(src, dst)
    monkeypatch.setattr(C.os, "replace", replace)
    ck.save(2, _tree(2))
    with pytest.raises(RuntimeError, match="background checkpoint"):
        ck.wait()
    # the .tmp dir carries COMMIT, yet discovery must not trust it
    assert os.path.exists(
        os.path.join(str(tmp_path), "step_00000002.tmp", "COMMIT"))
    assert ck.all_steps() == [1]
    monkeypatch.undo()
    ck.save(2, _tree(2), block=True)
    assert ck.all_steps() == [1, 2]


def test_wait_reraises_and_clears_background_failure(tmp_path):
    ck = Checkpointer(str(tmp_path))

    def boom(step, leaves, treedef_str):
        raise ValueError("flaky filesystem")
    ck._write = boom
    ck.save(1, _tree(1))
    with pytest.raises(RuntimeError, match="background checkpoint") as ei:
        ck.wait()
    assert isinstance(ei.value.__cause__, ValueError)
    ck.wait()                             # error consumed, not sticky


def test_discovery_ignores_non_snapshot_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(3), block=True)
    for name in ("step_abc", "step_00000004.tmp", "stepX"):
        os.makedirs(os.path.join(str(tmp_path), name))
        with open(os.path.join(str(tmp_path), name, "COMMIT"), "wb") as f:
            f.write(b"ok")
    os.makedirs(os.path.join(str(tmp_path), "step_00000005"))  # no COMMIT
    assert ck.all_steps() == [3]


def test_restart_manager_recovers(tmp_path):
    ck = Checkpointer(str(tmp_path))
    rm = RestartManager(ck, ckpt_every=2)

    def step_fn(state, step):
        return {"x": state["x"] + 1}

    state, end = rm.run({"x": jnp.zeros(())}, step_fn, 10,
                        inject_failure_at=5)
    assert rm.restarts == 1
    assert end == 10
    assert float(state["x"]) == 10.0   # recomputed steps after restore


def test_straggler_detector_flags_outlier():
    d = StragglerDetector(warmup=5, z_thresh=3.0)
    flagged = []
    for i in range(30):
        dt = 0.1 + 0.001 * (i % 3)
        flagged.append(d.observe(dt))
    assert not any(flagged)
    assert d.observe(1.5) is True      # 15x step time


def test_heartbeats():
    h = HeartbeatRegistry(4, miss_budget=2)
    for host in range(4):
        h.beat(host, t=100.0)
    h.beat(0, t=200.0)
    assert h.sweep(timeout=50.0, now=210.0) == []     # first miss
    dead = h.sweep(timeout=50.0, now=211.0)
    assert set(dead) == {1, 2, 3}


@given(st.integers(1, 4096))
def test_elastic_mesh_shape_properties(n):
    shape = elastic_mesh_shape(n)
    total = 1
    for d in shape:
        assert d >= 1
        total *= d
    assert total <= n
    # model axis is a power-of-two divisor of the per-pod chips
    assert shape[-1] & (shape[-1] - 1) == 0


def test_elastic_prefers_model_width():
    assert elastic_mesh_shape(256)[-1] == 16
    assert elastic_mesh_shape(512) == (2, 16, 16)
    # degraded pod: model axis preserved when divisible
    assert elastic_mesh_shape(240)[-1] == 16
