"""Optimizer + gradient-compression tests (incl. hypothesis properties)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st
from hypothesis.extra import numpy as hnp

from repro.optim import compression as C
from repro.optim.adamw import (OptConfig, adamw_update, global_norm,
                               init_opt_state, lr_schedule)


def test_adamw_converges_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                   weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = init_opt_state(params)
    step = jnp.zeros((), jnp.int32)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}        # d/dw of w^2
        params, opt, _ = adamw_update(oc, params, grads, opt, step)
        step = step + 1
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_lr_schedule_shape():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(oc, jnp.float32(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                      # warmup rising
    assert max(lrs) == pytest.approx(1e-3, rel=0.15)
    assert lrs[-1] < lrs[50]                    # cosine decay
    assert lrs[-1] >= oc.lr * oc.min_lr_frac * 0.9


def test_grad_clipping_applied():
    oc = OptConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    big = {"w": jnp.full(4, 1e6)}
    p2, _, m = adamw_update(oc, params, big, opt, jnp.zeros((), jnp.int32))
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.0   # update bounded by lr


@given(hnp.arrays(np.float32, st.integers(1, 2000),
                  elements=st.floats(-1e3, 1e3, width=32)))
def test_quantize_roundtrip_bounded(x):
    xj = jnp.asarray(x)
    q, s, n = C.quantize_int8(xj)
    back = C.dequantize_int8(q, s, n, xj.shape)
    # blockwise max-scaled int8: error <= scale/2 per element
    scales = np.repeat(np.asarray(s).ravel(), C.BLOCK)[:x.size]
    err = np.abs(np.asarray(back) - x)
    assert np.all(err <= scales / 2 + 1e-6)


def test_error_feedback_reinjects():
    g = {"w": jnp.array([0.3, -0.2, 0.7, 0.01])}
    d1, r1 = C.compress_tree(g, None)
    # residual equals quantization error
    np.testing.assert_allclose(np.asarray(r1["w"]),
                               np.asarray(g["w"]) - np.asarray(d1["w"]),
                               rtol=1e-6, atol=1e-6)
    # two steps with error feedback deliver ~2g in total
    d2, r2 = C.compress_tree(g, r1)
    total = np.asarray(d1["w"]) + np.asarray(d2["w"]) + np.asarray(r2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]),
                               rtol=1e-5, atol=1e-6)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
