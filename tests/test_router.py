"""Replica-router behaviour: admission policies, backpressure,
cancel/fork forwarding, and end-to-end identity with a single engine.

Policy/queueing mechanics run against a deterministic fake engine (no
jax, no compiles — the router only touches the engine's slot surface);
one integration test drives real engines through ``run`` and pins the
tokens against a single-engine serve of the same requests.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import Request, ServeEngine
from repro.serve.router import QueueFull, ReplicaRouter


class _FakeSlot:
    def __init__(self, rid, budget):
        self.rid, self.remaining, self.out = rid, budget, []


class FakeEngine:
    """Slot-surface stub: each step every active slot emits one token
    equal to its slot index (deterministic, engine-identifiable)."""

    paged = True          # so fork() is allowed on the stub

    def __init__(self, n_slots=2):
        self.slots = [None] * n_slots

    def free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def admit(self, req, slot=None):
        slot = self.free_slots()[0] if slot is None else slot
        self.slots[slot] = _FakeSlot(req.rid, req.max_new_tokens)
        return slot

    def step(self):
        retired = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.out.append(i)
            s.remaining -= 1
            if s.remaining <= 0:
                retired.append((s.rid, np.asarray(s.out, np.int32)))
                self.slots[i] = None
        return retired

    def cancel(self, rid):
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None
                return np.asarray(s.out, np.int32)
        return None

    def fork(self, rid, new_rid, max_new_tokens=None):
        src = next(s for s in self.slots if s is not None and s.rid == rid)
        slot = self.free_slots()[0]
        self.slots[slot] = _FakeSlot(
            new_rid, src.remaining if max_new_tokens is None
            else max_new_tokens)
        return slot


def _req(rid, budget=3):
    return Request(rid=rid, prompt=(1, 2, 3), max_new_tokens=budget)


def test_round_robin_rotates():
    r = ReplicaRouter([FakeEngine(), FakeEngine(), FakeEngine()])
    placed = [r.submit(_req(f"r{i}")) for i in range(6)]
    assert placed == [0, 1, 2, 0, 1, 2]


def test_least_loaded_prefers_idle_replica():
    r = ReplicaRouter([FakeEngine(), FakeEngine()], policy="least_loaded")
    r.submit(_req("big", budget=50))     # lands on 0, 50 owed tokens
    assert [r.submit(_req(f"s{i}")) for i in range(3)] == [1, 1, 1]


def test_backpressure_raises_queue_full():
    r = ReplicaRouter([FakeEngine(n_slots=1)], max_queue=2)
    r.submit(_req("a"))
    r.submit(_req("b"))
    with pytest.raises(QueueFull):
        r.submit(_req("c"))
    r.step()                             # admits "a" into the slot
    r.submit(_req("c"))                  # queue drained by one


def test_duplicate_rid_rejected():
    r = ReplicaRouter([FakeEngine()])
    r.submit(_req("a"))
    with pytest.raises(ValueError):
        r.submit(_req("a"))


def test_cancel_queued_and_active():
    r = ReplicaRouter([FakeEngine(n_slots=1)], max_queue=4)
    r.submit(_req("live", budget=5))
    r.submit(_req("waiting", budget=5))
    r.step()                             # "live" active, "waiting" queued
    out_q = r.cancel("waiting")
    assert out_q is not None and out_q.size == 0   # never decoded
    out_a = r.cancel("live")
    assert out_a is not None and out_a.size >= 1   # tokens so far
    assert r.cancel("ghost") is None
    assert not r.busy()


def test_fork_lands_on_owning_replica():
    r = ReplicaRouter([FakeEngine(), FakeEngine()])
    r.submit(_req("parent", budget=4))   # round-robin -> replica 0
    r.step()
    assert r.fork("parent", "child") == 0
    results = {}
    while r.busy():
        results.update(dict(r.step()))
    assert set(results) == {"parent", "child"}
    with pytest.raises(KeyError):
        r.fork("ghost", "x")


def test_run_drains_everything_under_backpressure():
    r = ReplicaRouter([FakeEngine(n_slots=1), FakeEngine(n_slots=1)],
                      policy="least_loaded", max_queue=1)
    reqs = [_req(f"r{i}", budget=1 + i % 3) for i in range(9)]
    results = r.run(reqs)
    assert set(results) == {q.rid for q in reqs}
    assert all(len(results[q.rid]) == q.max_new_tokens for q in reqs)
    st = r.stats()
    assert sum(s["completed"] for s in st) == len(reqs)
    assert all(s["queued"] == 0 and s["active"] == 0 for s in st)


def test_router_matches_single_engine_tokens():
    cfg = get_smoke_config("xlstm-125m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"r{i}",
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size, 6)),
                    max_new_tokens=3) for i in range(4)]

    def mk():
        return ServeEngine(cfg, params, max_slots=2, max_len=16, chunk=2)

    solo = mk().run(list(reqs))
    routed = ReplicaRouter([mk(), mk()], max_queue=4).run(list(reqs))
    for r in reqs:
        np.testing.assert_array_equal(routed[r.rid], solo[r.rid])
