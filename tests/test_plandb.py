"""Plan database: DB hits are bit-identical to online planning with
zero online work (pinned by the planner/tuner stats counters), misses
fall back without any behavior change, and content fingerprints make
staleness impossible — re-registering a machine or changing the config
changes the key, never serves an old plan."""

import dataclasses

import pytest

from repro.configs import get_smoke_config
from repro.core.machine import get_machine, register, registered_names
from repro.kernels import tuning
from repro.serve import plandb
from repro.serve.planner import (clear_plan_cache, plan_chunk_size,
                                 plan_stats, reset_plan_stats)

BATCH, MAX_LEN = 4, 96


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("yi-9b")


@pytest.fixture(scope="module")
def db(cfg):
    return plandb.sweep(cfg, batches=(BATCH,), max_lens=(MAX_LEN,),
                        tps=(1,))


@pytest.fixture(autouse=True)
def _clean_install():
    prev = plandb.installed()
    yield
    plandb.install(prev)


def _plan_all(cfg, **kw):
    return {m: plan_chunk_size(cfg, BATCH, MAX_LEN, machine=m, **kw)
            for m in registered_names()}


def test_db_hit_bit_identical_zero_online(cfg, db):
    """Every registered machine: the DB plan equals the online plan as
    a dataclass (bit-identical floats through JSON) and the hit path
    performs zero online planning."""
    plandb.install(None)
    ref = _plan_all(cfg)
    plandb.install(db)
    reset_plan_stats()
    hits = _plan_all(cfg)
    stats = plan_stats()
    assert stats["online_plans"] == 0
    assert stats["db_hits"] == len(registered_names())
    for m, p in hits.items():
        assert p == ref[m], f"{m}: DB plan differs from online"


def test_db_miss_falls_back_identically(cfg, db):
    """A key outside the sweep (different batch) misses the DB and is
    planned online — same plan as with no DB installed at all."""
    plandb.install(None)
    ref = plan_chunk_size(cfg, BATCH + 1, MAX_LEN, machine="zen4")
    plandb.install(db)
    reset_plan_stats()
    got = plan_chunk_size(cfg, BATCH + 1, MAX_LEN, machine="zen4")
    stats = plan_stats()
    assert stats["online_plans"] == 1 and stats["db_hits"] == 0
    assert got == ref


def test_memo_and_db_share_one_invalidation(cfg, db):
    """clear_plan_cache() empties the plan memo AND the tile memo, so a
    freshly installed DB (install() calls it) is actually consulted."""
    plandb.install(db)
    reset_plan_stats()
    plan_chunk_size(cfg, BATCH, MAX_LEN, machine="zen4")
    plan_chunk_size(cfg, BATCH, MAX_LEN, machine="zen4")
    stats = plan_stats()
    assert stats["db_hits"] == 1 and stats["memo_hits"] == 1
    clear_plan_cache()
    reset_plan_stats()
    plan_chunk_size(cfg, BATCH, MAX_LEN, machine="zen4")
    assert plan_stats()["db_hits"] == 1    # re-resolved from DB, not memo


def test_machine_refingerprint_invalidates(cfg, db):
    """register(replace=True) with changed machine parameters changes
    the registry fingerprint: the old DB key misses and the plan is
    recomputed online against the new machine."""
    orig = get_machine("zen4")
    plandb.install(db)
    reset_plan_stats()
    plan_chunk_size(cfg, BATCH, MAX_LEN, machine="zen4")
    assert plan_stats()["db_hits"] == 1
    try:
        register(dataclasses.replace(orig, clock_hz=orig.clock_hz * 2),
                 replace=True)
        clear_plan_cache()
        reset_plan_stats()
        plan_chunk_size(cfg, BATCH, MAX_LEN, machine="zen4")
        stats = plan_stats()
        assert stats["db_hits"] == 0 and stats["online_plans"] == 1
    finally:
        register(orig, replace=True)
        clear_plan_cache()


def test_config_fingerprint_invalidates(cfg, db):
    """A config change (vocab size) misses every chunk key."""
    plandb.install(db)
    reset_plan_stats()
    other = dataclasses.replace(cfg, vocab_size=cfg.vocab_size + 1)
    plan_chunk_size(other, BATCH, MAX_LEN, machine="zen4")
    stats = plan_stats()
    assert stats["db_hits"] == 0 and stats["online_plans"] == 1


def test_save_load_roundtrip_and_version_gate(cfg, db, tmp_path):
    path = tmp_path / "plans.json"
    db.save(path)
    back = plandb.PlanDB.load(path)
    assert len(back) == len(db)
    plandb.install(None)
    ref = _plan_all(cfg)
    plandb.install(back)
    reset_plan_stats()
    assert _plan_all(cfg) == ref
    assert plan_stats()["online_plans"] == 0
    # version gate: a future format must be a hard error
    import json
    doc = json.loads(path.read_text())
    doc["version"] = plandb.PLANDB_VERSION + 1
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="version"):
        plandb.PlanDB.load(path)
    doc["format"] = "something-else"
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="not a repro plan database"):
        plandb.PlanDB.load(path)


def test_tile_db_hits(cfg, db):
    """flash/decode tile lookups resolve from the DB with zero online
    autotunes, bit-identical to the online tuner."""
    kw = dict(dh=cfg.head_dim_eff, h=cfg.n_heads, hkv=cfg.n_kv_heads,
              backend="tp_bound")
    plandb.install(None)
    tuning.clear_cache()
    ref_f = tuning.flash_tiles("zen4", s=MAX_LEN, **kw)
    ref_d = tuning.decode_tiles("zen4", skv=MAX_LEN, **kw)
    plandb.install(db)
    tuning.reset_tile_stats()
    got_f = tuning.flash_tiles("zen4", s=MAX_LEN, **kw)
    got_d = tuning.decode_tiles("zen4", skv=MAX_LEN, **kw)
    stats = tuning.tile_stats()
    assert stats["online"] == 0
    assert stats["db_hits"] == 2
    assert got_f == ref_f and got_d == ref_d


def test_backend_disagreement_report(db):
    """The report is well-formed; each row names a swept point where
    tp_bound and mca_sched picked different winners."""
    rows = plandb.backend_disagreements(db)
    assert isinstance(rows, list)
    for r in rows:
        assert r["kind"] in ("chunk", "tiles")


def test_sweep_never_copies_itself(cfg, db):
    """Sweeping with a DB installed temporarily uninstalls it: the new
    sweep's plans are online answers, then the installation returns."""
    plandb.install(db)
    again = plandb.sweep(cfg, batches=(BATCH,), max_lens=(MAX_LEN,),
                         tps=(1,))
    assert plandb.installed() is db
    assert len(again) == len(db)
