"""fig3 RPE cache hygiene: strict-JSON persistence (NaN <-> null),
failure sentinels are retried instead of pinned, and the summarize
consumers degrade gracefully when no finite records exist."""

import json
import math
import sys

import pytest

from repro.core import rpe

sys.path.insert(0, ".")
from benchmarks import fig3_rpe  # noqa: E402


def _rec(kernel="copy", variant="jnp", size="S", t=1e-4):
    return rpe.RpeRecord(kernel, variant, size, t, t * 2, t * 3,
                         t * 2.5)


def _nan_rec(kernel="copy", variant="jnp", size="S"):
    nan = float("nan")
    return rpe.RpeRecord(kernel, variant, size, nan, nan, nan, nan)


def test_save_records_emits_strict_json(tmp_path):
    path = str(tmp_path / "cache.json")
    rpe.save_records([_rec(), _nan_rec("add")], path)
    raw = open(path).read()
    assert "NaN" not in raw
    data = json.loads(raw)          # would reject bare NaN tokens
    assert data[1]["t_meas"] is None


def test_load_records_maps_null_back_to_nan(tmp_path):
    path = str(tmp_path / "cache.json")
    rpe.save_records([_rec(), _nan_rec("add")], path)
    recs = rpe.load_records(path)
    assert recs[0].t_meas == pytest.approx(1e-4)
    assert math.isnan(recs[1].t_meas)


def test_load_records_tolerates_corrupt_cache(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text('[{"kernel": "copy", "varia')   # truncated write
    assert rpe.load_records(str(path)) == []
    path.write_text('[{"kernel": null, "variant": "jnp", "size": "S", '
                    '"t_meas": null, "t_port": null, "t_naive": null}]')
    assert rpe.load_records(str(path)) == []        # null string field


def test_save_records_is_atomic(tmp_path):
    path = str(tmp_path / "cache.json")
    rpe.save_records([_rec()], path)
    assert not (tmp_path / "cache.json.tmp").exists()
    assert len(rpe.load_records(path)) == 1


def test_run_retries_cached_failure_records(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    rpe.save_records([_nan_rec(k, v, s)
                      for k in ("copy", "add")
                      for v in ("jnp", "fori")
                      for s in ("S", "L")], path)
    calls = []

    def fake_run_block(k, v, s):
        calls.append((k, v, s))
        return _rec(k, v, s)

    monkeypatch.setattr(rpe, "run_block", fake_run_block)
    monkeypatch.setattr("repro.kernels.stream.ref.KERNELS_13",
                        ("copy", "add"))
    records = fig3_rpe.run(full=False, cache=path)
    assert len(calls) == 8          # every NaN sentinel was retried
    assert all(math.isfinite(r.t_meas) for r in records)
    # and the refreshed cache now counts them as done
    calls.clear()
    fig3_rpe.run(full=False, cache=path)
    assert calls == []


def test_run_does_not_persist_failures(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")

    def failing_run_block(k, v, s):
        if k == "add":
            raise RuntimeError("boom")
        return _rec(k, v, s)

    monkeypatch.setattr(rpe, "run_block", failing_run_block)
    monkeypatch.setattr("repro.kernels.stream.ref.KERNELS_13",
                        ("copy", "add"))
    records = fig3_rpe.run(full=False, cache=path)
    assert sum(1 for r in records if math.isnan(r.t_meas)) == 4
    cached = rpe.load_records(path)
    assert all(math.isfinite(r.t_meas) for r in cached)
    assert {r.kernel for r in cached} == {"copy"}


def test_legacy_record_without_t_mca_is_rerun(tmp_path, monkeypatch):
    # pre-backend-split cache entry: finite t_meas, no t_mca key at all
    path = tmp_path / "cache.json"
    path.write_text('[{"kernel": "copy", "variant": "jnp", "size": "S", '
                    '"t_meas": 1e-4, "t_port": 2e-4, "t_naive": 3e-4}]')
    legacy = rpe.load_records(str(path))
    assert math.isnan(legacy[0].t_mca)      # loads, but incomplete
    calls = []

    def fake_run_block(k, v, s):
        calls.append((k, v, s))
        return _rec(k, v, s)

    monkeypatch.setattr(rpe, "run_block", fake_run_block)
    monkeypatch.setattr("repro.kernels.stream.ref.KERNELS_13", ("copy",))
    fig3_rpe.run(full=False, cache=str(path))
    assert ("copy", "jnp", "S") in calls    # backfilled, not pinned
    refreshed = rpe.load_records(str(path))
    assert all(math.isfinite(r.t_mca) for r in refreshed)


def test_failed_backfill_keeps_legacy_measurement(tmp_path, monkeypatch):
    # a legacy record whose backfill re-run CRASHES must survive in the
    # cache file (its finite measurement is still valid data)
    path = tmp_path / "cache.json"
    legacy = rpe.RpeRecord("copy", "jnp", "S", 1e-4, 2e-4, 3e-4)
    rpe.save_records([legacy], str(path))

    def run_block(k, v, s):
        if k == "copy":
            raise RuntimeError("backfill boom")
        return _rec(k, v, s)

    monkeypatch.setattr(rpe, "run_block", run_block)
    monkeypatch.setattr("repro.kernels.stream.ref.KERNELS_13",
                        ("copy", "add"))
    fig3_rpe.run(full=False, cache=str(path))
    cached = {(r.kernel, r.variant, r.size): r
              for r in rpe.load_records(str(path))}
    assert ("copy", "jnp", "S") in cached           # not deleted
    assert cached[("copy", "jnp", "S")].t_meas == pytest.approx(1e-4)
    assert ("add", "jnp", "S") in cached            # new blocks saved


def test_summarize_per_backend_without_nan_poisoning():
    # one fully-populated record + one legacy record (NaN t_mca only):
    # every backend's mean must come out finite — the NaN may shrink
    # the mca sample, never poison its mean
    legacy = rpe.RpeRecord("add", "jnp", "S", 1e-4, 2e-4, 3e-4)
    s = rpe.summarize([_rec(), legacy, _nan_rec("sum_reduction")])
    assert s["port_model"]["n"] == 2
    assert s["mca_sched"]["n"] == 1
    assert s["naive_baseline"]["n"] == 2
    for model in ("port_model", "mca_sched", "naive_baseline"):
        assert math.isfinite(s[model]["mean_rpe"])
        assert math.isfinite(s[model]["mean_abs_rpe"])


def test_summarize_all_overpredicted_formats_cleanly():
    # every prediction slower than measurement => no rpe >= 0;
    # mean_underpred_rpe must stay format-safe (NaN, not None)
    s = rpe.summarize([_rec(t=1e-4)])     # t_port/t_naive > t_meas
    st = s["port_model"]
    assert math.isnan(st["mean_underpred_rpe"])
    assert f"{st['mean_underpred_rpe']:.2f}" == "nan"


def test_summarize_empty_on_all_nan():
    s = rpe.summarize([_nan_rec()])
    assert s["port_model"] == {}
    assert s["naive_baseline"] == {}


def test_gen_fig3_degrades_without_finite_records(tmp_path, monkeypatch):
    from benchmarks import make_experiments
    monkeypatch.chdir(tmp_path)
    (tmp_path / "results").mkdir()
    rpe.save_records([_nan_rec()],
                     str(tmp_path / "results/rpe_records.json"))
    out = make_experiments.gen_fig3()
    assert "(no finite records)" in out


def test_baseline_predict_accepts_list_of_dicts():
    from repro.core import baseline
    from repro.core.machine import get_machine
    m = get_machine("tpu_v5e")
    ca = [{"flops": 2.0e9, "bytes accessed": 1.0e9}]
    rep = baseline.predict(ca, m, peak_flops=1e9, mem_bw=1e9)
    assert rep.flops == 2.0e9
    assert rep.seconds == pytest.approx(2.0)
    empty = baseline.predict([], m, peak_flops=1e9, mem_bw=1e9)
    assert empty.seconds == 0.0
