"""Integration: end-to-end train driver (loss decreases), serving
generation, compressed-gradient training, and a subprocess mini dry-run
(placeholder-device mesh lower+compile on a reduced config)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def test_train_loss_decreases():
    from repro.launch.train import main
    losses = main(["--arch", "xlstm-125m", "--smoke", "--steps", "30",
                   "--batch", "8", "--seq", "64", "--lr", "3e-3",
                   "--log-every", "10"])
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_train_with_compression_runs():
    from repro.launch.train import main
    losses = main(["--arch", "yi-9b", "--smoke", "--steps", "6",
                   "--batch", "4", "--seq", "32", "--compress",
                   "--log-every", "5"])
    assert np.isfinite(losses).all()


def test_checkpoint_resume(tmp_path):
    from repro.launch.train import main
    d = str(tmp_path / "ck")
    main(["--arch", "xlstm-125m", "--smoke", "--steps", "4",
          "--batch", "2", "--seq", "32", "--ckpt-dir", d,
          "--ckpt-every", "2", "--log-every", "10"])
    # resume past end: restores step 4 and exits immediately
    losses = main(["--arch", "xlstm-125m", "--smoke", "--steps", "4",
                   "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                   "--ckpt-every", "2", "--log-every", "10"])
    assert losses == [] or len(losses) <= 4


def test_serve_generate_deterministic():
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.serve import generate
    from repro.models import model as M
    cfg = get_smoke_config("gemma3-4b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    t1 = generate(cfg, params, prompts, 8)
    t2 = generate(cfg, params, prompts, 8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 8)


@pytest.mark.slow
def test_mini_dryrun_subprocess(tmp_path):
    """Lower+compile a smoke config on a 2x2 placeholder mesh in a fresh
    process (the only place device-count flags are allowed)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.optim.adamw import OptConfig
from repro.train import step as step_lib
from repro.utils.sharding import TRAIN_RULES, mesh_axis_sizes, use_mesh_rules
from repro.configs.base import ShapeSpec
import repro.models.model as M

cfg = get_smoke_config("yi-9b")
mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
sizes = mesh_axis_sizes(mesh)
shape = ShapeSpec("mini", 64, 4, "train")
fn = step_lib.make_train_step(cfg, OptConfig(), 1)
state_shapes = step_lib.train_state_shapes(cfg)
bshapes = step_lib.batch_shapes(cfg, shape)
named = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                  is_leaf=lambda x: isinstance(x, P))
state_sh = named(step_lib.train_state_pspecs(cfg, TRAIN_RULES, sizes))
batch_sh = named(step_lib.batch_pspecs(cfg, bshapes, TRAIN_RULES, sizes))
with mesh, use_mesh_rules(mesh, TRAIN_RULES):
    c = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None)).lower(
        state_shapes, bshapes).compile()
ma = c.memory_analysis()
print(json.dumps({"ok": True, "temp": int(ma.temp_size_in_bytes)}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["temp"] > 0
