"""Tentpole invariants of the trace-IR / backend split: the simulator
never beats the analytical lower bound on any registered machine,
decomposition runs once per module, the backend registry resolves
aliases, compare() fans (machine, backend) pairs, the degradation
warning fires once per fan-out (not per worker), and the planner /
autotuner default paths are backend-identical."""

import os
import warnings as _warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import backends as backends_lib
from repro.core import portmodel, trace
from repro.core.machine import MACHINES, TPU_V5E, registered_names

_DATA = os.path.join(os.path.dirname(__file__), "data")

#: every paper CPU must satisfy the acceptance invariant; TPUs ride along
PAPER_CPUS = ("zen4", "golden_cove", "neoverse_v2")


def _compile_text(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, d) for s, d in shapes]
    return jax.jit(fn).lower(*args).compile().as_text()


@pytest.fixture(scope="module")
def fixture_hlos():
    """The fixed fixture set: the committed golden module plus two
    freshly-lowered shapes (straight-line compute, scanned LCD)."""
    with open(os.path.join(_DATA, "golden.hlo")) as f:
        golden = f.read()

    def scanned(x):
        def body(c, _):
            return jnp.tanh(c @ c.T) @ c * 0.1, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    return {
        "golden": golden,
        "straight": _compile_text(
            lambda a, b: jax.nn.relu(a @ b) + jnp.exp(a @ b),
            ((256, 256), jnp.float32), ((256, 256), jnp.float32)),
        "scanned": _compile_text(scanned, ((96, 96), jnp.float32)),
    }


# ---- acceptance: simulator >= analytical bound, everywhere -----------------

def test_mca_never_beats_tp_bound_on_all_machines(fixture_hlos):
    """For every registered machine and every fixture module, the
    MCA-style simulator's cycles are >= the TP lower bound (a cycle
    simulator can never beat perfect ILP), and the simulator actually
    simulated (sim_cycles is set)."""
    for tag, hlo in fixture_hlos.items():
        nested = portmodel.compare(hlo, backends=("tp", "mca"),
                                   parallel="serial")
        assert set(nested) == set(registered_names())
        for name, per in nested.items():
            tp, mca = per["tp_bound"], per["mca_sched"]
            assert tp.backend == "tp_bound"
            assert mca.backend == "mca_sched"
            assert tp.sim_cycles is None
            assert mca.sim_cycles is not None
            assert mca.bound_incore_cycles >= tp.bound_incore_cycles, \
                (tag, name)
            assert mca.bound_cycles >= tp.bound_cycles, (tag, name)
            # the analytical fields are shared (same trace, same walk)
            assert mca.tp_cycles == tp.tp_cycles, (tag, name)
            assert mca.flops == tp.flops and \
                mca.bytes_hbm == tp.bytes_hbm, (tag, name)


def test_mca_strictly_pessimistic_somewhere(fixture_hlos):
    """Dispatch stalls / latency chains must actually cost something:
    on the straight-line module every paper CPU simulates strictly
    above the TP bound (otherwise the simulator degenerated into the
    clamp)."""
    nested = portmodel.compare(fixture_hlos["straight"],
                               machines=PAPER_CPUS,
                               backends=("tp", "mca"), parallel="serial")
    for name, per in nested.items():
        assert per["mca_sched"].bound_incore_cycles > \
            per["tp_bound"].bound_incore_cycles, name


def test_mca_seconds_ordering_survives_tier_resolution(fixture_hlos):
    """The downstream consumable (tier-resolved seconds) preserves the
    pessimistic-or-equal ordering on every registered machine."""
    from repro.core.machine import get_machine
    nested = portmodel.compare(fixture_hlos["golden"],
                               backends=("tp", "mca"), parallel="serial")
    for name, per in nested.items():
        m = get_machine(name)
        assert per["mca_sched"].tier_bound_seconds(m) >= \
            per["tp_bound"].tier_bound_seconds(m), name


# ---- trace IR: one lowering per module -------------------------------------

def test_trace_lowered_once_per_fanout(fixture_hlos):
    hlo = fixture_hlos["scanned"]
    portmodel._trace_cached.cache_clear()
    portmodel._parse_cached.cache_clear()
    portmodel.compare(hlo, backends=("tp", "mca"), parallel="serial")
    info = portmodel._trace_cached.cache_info()
    assert info.misses == 1         # one lowering ...
    portmodel.compare(hlo, backends=("tp", "mca"), parallel="serial")
    assert portmodel._trace_cached.cache_info().misses == 1
    # ... shared by analyze() on the same text too
    portmodel.analyze(hlo, "zen4", backend="mca")
    assert portmodel._trace_cached.cache_info().misses == 1


def test_trace_is_machine_independent(fixture_hlos):
    tr = trace.lower_text(fixture_hlos["scanned"])
    assert tr.n_ops() > 0
    loops = [op for op in tr.entry.ops if op.kind == "loop"]
    assert loops and loops[0].trips == 12
    assert loops[0].region is not None and loops[0].region.boundary
    # µ-op classes are machine-file keys, not ports
    classes = {c for op in tr.entry.ops for c, _ in op.uops}
    from repro.core import isa
    assert classes <= set(isa.UOP_CLASSES)


# ---- backend registry ------------------------------------------------------

def test_backend_registry_and_aliases():
    assert set(backends_lib.registered_backends()) >= \
        {"tp_bound", "mca_sched"}
    assert backends_lib.get_backend("tp").name == "tp_bound"
    assert backends_lib.get_backend("osaca").name == "tp_bound"
    assert backends_lib.get_backend("mca").name == "mca_sched"
    assert backends_lib.get_backend("llvm-mca").name == "mca_sched"
    inst = backends_lib.get_backend("tp_bound")
    assert backends_lib.get_backend(inst) is inst
    with pytest.raises(KeyError):
        backends_lib.get_backend("nonesuch")
    with pytest.raises(ValueError):
        backends_lib.register_backend(
            backends_lib.get_backend("tp_bound"))


@pytest.mark.parametrize("parallel", ["serial", "process"])
def test_compare_honours_custom_backend_instance(fixture_hlos, parallel):
    """An ad-hoc Backend instance must run AS CONFIGURED — not be
    swapped for the registry's default-configured instance by name."""
    from repro.core.backends.mca_sched import McaSchedBackend
    hlo = fixture_hlos["straight"]
    tight = McaSchedBackend(window=1, issue_width=1)
    default = portmodel.compare(hlo, machines=("zen4",),
                                backends="mca", parallel=parallel)
    custom = portmodel.compare(hlo, machines=("zen4",),
                               backends=tight, parallel=parallel)
    assert custom["zen4"].sim_cycles > default["zen4"].sim_cycles


def test_two_backend_fanout_walks_once_per_machine(fixture_hlos,
                                                   monkeypatch):
    """The stock mca report contains the tp report (same walk): a
    tp+mca fan-out must schedule only the simulator tasks and derive
    the tp_bound reports — N analytic walks, not 2N."""
    from repro.core.backends import tp_bound as tb
    hlo = fixture_hlos["straight"]
    calls = []
    orig = tb._Walk.run

    def counting(self, trace, name):
        calls.append(name)
        return orig(self, trace, name)

    monkeypatch.setattr(tb._Walk, "run", counting)
    nested = portmodel.compare(hlo, machines=("zen4", "tpu_v5e"),
                               backends=("tp", "mca"), parallel="serial")
    assert calls == ["mca_sched", "mca_sched"]
    for name in ("zen4", "tpu_v5e"):
        tp, mca = nested[name]["tp_bound"], nested[name]["mca_sched"]
        assert tp.backend == "tp_bound" and tp.sim_cycles is None
        assert list(nested[name]) == ["tp_bound", "mca_sched"]
        # the derived report equals a direct tp_bound run
        direct = portmodel.compare(hlo, machines=(name,),
                                   parallel="serial")[name]
        assert tp.port_occupation == direct.port_occupation
        assert tp.bound_cycles == direct.bound_cycles
        assert tp.t_mem_tier == direct.t_mem_tier
        # and shares no mutable state with the mca report
        assert tp.port_occupation is not mca.port_occupation


def test_compare_dedupes_alias_spellings(fixture_hlos):
    """Alias + canonical spellings are one backend: one run, one key."""
    hlo = fixture_hlos["scanned"]
    nested = portmodel.compare(hlo, machines=("zen4",),
                               backends=("tp", "osaca", "tp_bound"),
                               parallel="serial")
    assert list(nested["zen4"]) == ["tp_bound"]


def test_compare_shapes_flat_vs_nested(fixture_hlos):
    hlo = fixture_hlos["scanned"]
    flat = portmodel.compare(hlo, machines=("zen4",), parallel="serial")
    assert isinstance(flat["zen4"], portmodel.Report)
    single = portmodel.compare(hlo, machines=("zen4",),
                               backends="mca", parallel="serial")
    assert single["zen4"].backend == "mca_sched"
    nested = portmodel.compare(hlo, machines=("zen4",),
                               backends=("tp",), parallel="serial")
    assert set(nested["zen4"]) == {"tp_bound"}


def test_compare_pool_matches_serial_nested(fixture_hlos):
    hlo = fixture_hlos["scanned"]
    ser = portmodel.compare(hlo, backends=("tp", "mca"),
                            parallel="serial")
    pool = portmodel.compare(hlo, backends=("tp", "mca"),
                             parallel="process")
    assert list(ser) == list(pool)
    for name in ser:
        for b in ("tp_bound", "mca_sched"):
            assert ser[name][b].bound_cycles == \
                pool[name][b].bound_cycles, (name, b)
            assert ser[name][b].sim_cycles == \
                pool[name][b].sim_cycles, (name, b)


# ---- degradation warning: once per fan-out, counted on the report ----------

def _novpu(name):
    import dataclasses
    table = {k: v for k, v in TPU_V5E.table.items() if k != "vpu"}
    return dataclasses.replace(TPU_V5E, name=name, table=table)


@pytest.mark.parametrize("parallel", ["serial", "process"])
def test_degradation_warns_once_per_fanout(parallel):
    txt = _compile_text(lambda x: jnp.exp(x) + x,
                        ((512, 512), jnp.float32))
    MACHINES["novpu_a"] = _novpu("novpu_a")
    MACHINES["novpu_b"] = _novpu("novpu_b")
    try:
        with _warnings.catch_warnings(record=True) as got:
            _warnings.simplefilter("always")
            reports = portmodel.compare(
                txt, machines=("novpu_a", "novpu_b", "tpu_v5e"),
                backends=("tp", "mca"), parallel=parallel)
        degr = [w for w in got if issubclass(w.category, RuntimeWarning)
                and "degraded" in str(w.message)]
        assert len(degr) == 1           # parent warns ONCE, not 2x2
        msg = str(degr[0].message)
        assert "novpu_a" in msg and "novpu_b" in msg and "vpu" in msg
        for b in ("tp_bound", "mca_sched"):
            assert reports["novpu_a"][b].fallback_uops > 0
            assert "vpu" in reports["novpu_a"][b].fallback_classes
            assert reports["tpu_v5e"][b].fallback_uops == 0
    finally:
        del MACHINES["novpu_a"], MACHINES["novpu_b"]


# ---- consumers: default paths identical, opt-in pessimistic ----------------

def test_tuner_tp_backend_matches_default():
    from repro.kernels import tuning
    tuning.clear_cache()
    for machine in PAPER_CPUS + ("tpu_v5e",):
        legacy = tuning.decode_tiles(machine, skv=4096, dh=64, h=8,
                                     hkv=8, batch=4)
        via_tp = tuning.decode_tiles(machine, skv=4096, dh=64, h=8,
                                     hkv=8, batch=4, backend="tp_bound")
        assert (legacy.bq, legacy.bk, legacy.n_splits) == \
            (via_tp.bq, via_tp.bk, via_tp.n_splits), machine
        assert legacy.seconds == pytest.approx(via_tp.seconds), machine
        f_legacy = tuning.flash_tiles(machine, s=2048, dh=64, h=8, hkv=8)
        f_tp = tuning.flash_tiles(machine, s=2048, dh=64, h=8, hkv=8,
                                  backend="tp_bound")
        assert (f_legacy.bq, f_legacy.bk) == (f_tp.bq, f_tp.bk), machine
        mca = tuning.decode_tiles(machine, skv=4096, dh=64, h=8,
                                  hkv=8, batch=4, backend="mca_sched")
        assert mca.seconds >= via_tp.seconds - 1e-18, machine


def test_planner_backend_opt_in(fixture_hlos):
    from repro.configs import get_smoke_config
    from repro.serve import planner as planner_lib
    cfg = get_smoke_config("yi-9b")
    planner_lib.clear_plan_cache()
    hlo = fixture_hlos["golden"]
    default = planner_lib.plan_chunk_size(cfg, 2, 32, machine="zen4",
                                          hlo_text=hlo)
    via_tp = planner_lib.plan_chunk_size(cfg, 2, 32, machine="zen4",
                                         hlo_text=hlo,
                                         backend="tp_bound")
    assert default.backend == "tp_bound"
    assert default.chunk == via_tp.chunk
    assert default.t_step_seconds == via_tp.t_step_seconds
    mca = planner_lib.plan_chunk_size(cfg, 2, 32, machine="zen4",
                                      hlo_text=hlo, backend="mca")
    assert mca.backend == "mca_sched"
    # pessimistic-or-equal step cost => never a larger chunk
    assert mca.t_step_seconds >= via_tp.t_step_seconds
    assert mca.chunk <= via_tp.chunk


def test_uops_seconds_matches_closed_form():
    from repro.core.machine import get_machine
    for machine in PAPER_CPUS:
        m = get_machine(machine)
        e = m.table["mxu"]
        passes = 37.5
        want = m.seconds(passes * e.cycles_per_unit
                         / max(1, len(e.ports)))
        got = backends_lib.uops_seconds(m, [("mxu", passes)])
        assert got == pytest.approx(want, rel=0, abs=0), machine
        sim = backends_lib.uops_seconds(m, [("mxu", passes)], "mca")
        assert sim >= got, machine
