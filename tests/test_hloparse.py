"""hloparse edge cases: out-of-order parameter_index ordering, nested
while trip-count resolution (backend_config annotation, condition
fallback, and the vocab-constant cap), and tuple-shape byte accounting
through the parser and the trace lowering."""

import math

from repro.core import hloparse, portmodel, trace
from repro.core.machine import TPU_V5E

# parameters deliberately listed out of dataflow/index order: HLO text
# orders by dataflow, the byte accounting must map by parameter_index
_OOO_PARAMS = """\
HloModule ooo_params

fused_add (pb: f32[64,32], pa: f32[8,8]) -> f32[8,8] {
  %pb = f32[64,32] parameter(1)
  %pa = f32[8,8] parameter(0)
  %sl = f32[8,8] slice(%pb), slice={[0:8], [0:8]}
  ROOT %add = f32[8,8] add(%pa, %sl)
}

ENTRY main (a: f32[8,8], b: f32[64,32]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %b = f32[64,32] parameter(1)
  ROOT %fus = f32[8,8] fusion(%a, %b), kind=kLoop, calls=%fused_add
}
"""


def test_params_in_order_sorts_by_declared_index():
    mod = hloparse.parse_hlo(_OOO_PARAMS)
    body = mod.computations["fused_add"]
    # text order is pb (index 1) first; declared order must win
    assert [i.name for i in body.instrs if i.opcode == "parameter"] == \
        ["pb", "pa"]
    assert [p.name for p in trace.params_in_order(body)] == ["pa", "pb"]


def test_fusion_byte_accounting_uses_parameter_index():
    """Operand 1 (the 64x32 source) feeds only a slice inside the body:
    with correct index mapping the fusion reads the 8x8 slice, not the
    full 8 KiB operand. A dataflow-order mapping would pair operand 1
    with parameter 0 and charge the full read."""
    rep = portmodel.analyze(_OOO_PARAMS, TPU_V5E)
    full = 8 * 8 * 4 + 8 * 8 * 4 + 8 * 8 * 4      # out + a + slice-of-b
    assert rep.bytes_hbm == float(full)


_NESTED_WHILE = """\
HloModule nested_while

inner_cond (pi: (f32[8,128], s32[])) -> pred[] {
  %pi = (f32[8,128], s32[]) parameter(0)
  %ii = s32[] get-tuple-element(%pi), index=1
  %ci = s32[] constant(7)
  ROOT %lti = pred[] compare(%ii, %ci), direction=LT
}

inner_body (pib: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %pib = (f32[8,128], s32[]) parameter(0)
  %x = f32[8,128] get-tuple-element(%pib), index=0
  %j = s32[] get-tuple-element(%pib), index=1
  %t = f32[8,128] tanh(%x)
  %one = s32[] constant(1)
  %jn = s32[] add(%j, %one)
  ROOT %tup = (f32[8,128], s32[]) tuple(%t, %jn)
}

outer_cond (po: (f32[8,128], s32[])) -> pred[] {
  %po = (f32[8,128], s32[]) parameter(0)
  %io = s32[] get-tuple-element(%po), index=1
  %co = s32[] constant(50000)
  ROOT %lto = pred[] compare(%io, %co), direction=LT
}

outer_body (pob: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %pob = (f32[8,128], s32[]) parameter(0)
  %y = f32[8,128] get-tuple-element(%pob), index=0
  %k = s32[] get-tuple-element(%pob), index=1
  %wi = (f32[8,128], s32[]) while(%pob), condition=%inner_cond, body=%inner_body
  %yi = f32[8,128] get-tuple-element(%wi), index=0
  %onek = s32[] constant(1)
  %kn = s32[] add(%k, %onek)
  ROOT %tupo = (f32[8,128], s32[]) tuple(%yi, %kn)
}

ENTRY main (s: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %s = (f32[8,128], s32[]) parameter(0)
  ROOT %wo = (f32[8,128], s32[]) while(%s), condition=%outer_cond, body=%outer_body, backend_config={"known_trip_count":{"n":"5"}}
}
"""


def test_nested_while_trip_resolution():
    """Outer trips come from backend_config (primary source), inner from
    the condition-constant fallback; the 50000 outer-condition constant
    is ignored (vocab-sized constants must not masquerade as trips)."""
    mod = hloparse.parse_hlo(_NESTED_WHILE)
    trips = hloparse.trip_counts_from_text(_NESTED_WHILE)
    outer = next(i for i in mod.entry.instrs if i.opcode == "while")
    assert hloparse.while_trip_count(mod, outer, trips) == 5
    body = mod.computations["outer_body"]
    inner = next(i for i in body.instrs if i.opcode == "while")
    assert hloparse.while_trip_count(mod, inner, trips) == 7

    rep = portmodel.analyze(_NESTED_WHILE, TPU_V5E)
    assert rep.trips_seen["wo"] == 5
    assert rep.trips_seen["wi"] == 7
    # the trace mirrors the nesting structurally
    tr = trace.lower_text(_NESTED_WHILE)
    wo = next(op for op in tr.entry.ops if op.kind == "loop")
    assert wo.trips == 5
    wi = next(op for op in wo.region.ops if op.kind == "loop")
    assert wi.trips == 7
    # tanh runs trips_outer x trips_inner times: 8x128 = 1 vpu block
    # per call, charged on the xlu class
    xlu = sum(c for p, c in rep.port_occupation.items()
              if p.startswith("VPU"))
    assert xlu >= 5 * 7 * TPU_V5E.table["xlu"].cycles_per_unit


def test_vocab_sized_condition_constant_does_not_become_trips():
    trips = hloparse.trip_counts_from_text(_NESTED_WHILE)
    assert trips["outer_cond"] == 50000          # seen in the text ...
    mod = hloparse.parse_hlo(_NESTED_WHILE)
    wo = next(i for i in mod.entry.instrs if i.opcode == "while")
    # ... but without backend_config it would cap to the fallback of 1
    wo_stripped = hloparse.Instr(wo.name, wo.opcode, wo.shapes,
                                 wo.operands,
                                 "condition=%outer_cond, body=%outer_body")
    assert hloparse.while_trip_count(mod, wo_stripped, trips) == 1


_TUPLE_SHAPES = """\
HloModule tuple_bytes

ENTRY main (a: f32[4,8], k: s32[2]) -> (f32[4,8], bf16[16]) {
  %a = f32[4,8] parameter(0)
  %k = s32[2] parameter(1)
  ROOT %sorted = (f32[4,8], bf16[16]) sort(%a, %k), dimensions={0}
}
"""


def test_tuple_shape_byte_accounting():
    mod = hloparse.parse_hlo(_TUPLE_SHAPES)
    sorted_i = mod.entry.root
    assert sorted_i.opcode == "sort"
    assert [s.dtype for s in sorted_i.shapes] == ["f32", "bf16"]
    assert [s.bytes for s in sorted_i.shapes] == [4 * 8 * 4, 16 * 2]
    assert sorted_i.shape.dims == (4, 8)          # primary shape
    # elems sums across the flattened tuple (drives µ-op sizing)
    assert sum(s.elems for s in sorted_i.shapes) == 4 * 8 + 16
    rep = portmodel.analyze(_TUPLE_SHAPES, TPU_V5E)
    # boundary traffic: tuple result + both operands, in full
    want = (4 * 8 * 4 + 16 * 2) + 4 * 8 * 4 + 2 * 4
    assert rep.bytes_hbm == float(want)
    assert math.isfinite(rep.bound_cycles) and rep.bound_cycles > 0


def test_scalar_and_empty_dim_shapes():
    shapes = hloparse.parse_shapes("(f32[], s32[3,0,2])")
    assert shapes[0].dims == () and shapes[0].elems == 1
    assert shapes[1].elems == 0 and shapes[1].bytes == 0
