"""Overlapped serving runtime: pipelined decode dispatch is a pure
scheduling change (token streams byte-identical to serial on dense and
paged engines, through the router, at temperature 0 and >0), the
dispatch-gap stats are measured, prompt staging hits/misses/falls back
safely, and opportunistic snapshots never stall a decode round."""

import os

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import (PagedServeEngine, PromptStager, ReplicaRouter,
                         Request, ServeEngine)

SLOTS, MAX_LEN, CHUNK = 3, 40, 2


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("yi-9b")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _requests(cfg, n, seed=1, budgets=(9, 7, 11)):
    rng = np.random.default_rng(seed)
    return [Request(rid=f"r{i}",
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, cfg.vocab_size,
                                              6 + (i % 3))),
                    max_new_tokens=budgets[i % len(budgets)])
            for i in range(n)]


def _streams(results):
    return {rid: [int(t) for t in toks] for rid, toks in results.items()}


def _drain(eng):
    out = {}
    while any(s is not None for s in eng.slots):
        for rid, toks in eng.step():
            out[rid] = toks
    return out


def _engine(cfg, params, *, paged=False, pipeline=0, **kw):
    cls = PagedServeEngine if paged else ServeEngine
    if paged:
        kw.setdefault("page_size", 8)
    return cls(cfg, params, max_slots=SLOTS, max_len=MAX_LEN, chunk=CHUNK,
               pipeline=pipeline, **kw)


# -- byte identity --------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_pipelined_streams_byte_identical(cfg, params, paged, temperature):
    """Serial vs pipeline=2, more requests than slots (mid-flight
    admission while rounds are in flight): identical token streams and
    identical dispatch counts — the overlap changes scheduling only."""
    reqs = _requests(cfg, 2 * SLOTS)
    out = {}
    for pipeline in (0, 2):
        eng = _engine(cfg, params, paged=paged, pipeline=pipeline,
                      temperature=temperature, seed=3)
        out[pipeline] = (_streams(eng.run([Request(r.rid, r.prompt,
                                                   r.max_new_tokens)
                                           for r in reqs])),
                         eng.decode_dispatches, eng.prefill_dispatches)
    assert out[0][0] == out[2][0]
    assert out[0][1:] == out[2][1:]


def test_router_pipelined_identical(cfg, params):
    """The router path: pipelined replicas retire the same streams as
    serial replicas, and stats() surfaces the per-replica overlap."""
    reqs = _requests(cfg, 8, seed=5)
    out = {}
    for pipeline in (0, 2):
        engines = [_engine(cfg, params, pipeline=pipeline, seed=2)
                   for _ in range(2)]
        router = ReplicaRouter(engines, policy="round_robin",
                               max_queue=8)
        out[pipeline] = _streams(router.run(
            [Request(r.rid, r.prompt, r.max_new_tokens) for r in reqs]))
        for row in router.stats():
            assert row["pipeline"] == pipeline
            assert row["mean_dispatch_gap_s"] >= 0.0
    assert out[0] == out[2]


def test_cancel_and_fork_sync_inflight(cfg, params):
    """cancel() (and paged fork()) first drain in-flight rounds, so the
    returned tokens-so-far match what a serial engine would report."""
    reqs = _requests(cfg, SLOTS, budgets=(12, 12, 12))
    got = {}
    for pipeline in (0, 2):
        eng = _engine(cfg, params, paged=True, pipeline=pipeline)
        for r in reqs:
            eng.admit(Request(r.rid, r.prompt, r.max_new_tokens))
        eng.step()
        eng.step()
        toks = eng.cancel("r1")
        eng.fork("r0", "r0b", max_new_tokens=3)
        rest = {}
        while any(s is not None for s in eng.slots):
            for rid, t in eng.step():
                rest[rid] = [int(x) for x in t]
        got[pipeline] = ([int(x) for x in toks], rest)
    assert got[0] == got[2]


# -- dispatch-gap stats ---------------------------------------------------

def test_dispatch_gap_measured(cfg, params):
    eng = _engine(cfg, params, pipeline=2)
    stats = eng.stats()
    assert stats["gap_rounds"] == 0 and stats["mean_dispatch_gap_s"] == 0.0
    eng.run(_requests(cfg, SLOTS))
    stats = eng.stats()
    assert stats["pipeline"] == 2
    assert stats["gap_rounds"] > 0
    assert stats["mean_dispatch_gap_s"] > 0.0
    assert stats["in_flight"] == 0          # run() drains


def test_serial_keeps_donation_pipelined_does_not(cfg, params):
    """The double-buffer trade is mode-gated: serial donates the cache
    (in-place update), pipelined must not (a donated still-pending
    input blocks the next enqueue)."""
    assert _engine(cfg, params, pipeline=0)._donate() == (1,)
    assert _engine(cfg, params, pipeline=2)._donate() == ()


# -- prompt staging -------------------------------------------------------

def test_stager_hit_miss_and_fallback():
    st = PromptStager(depth=2)
    st.stage("a", (1, 2, 3))
    assert np.asarray(st.take("a", (1, 2, 3))).tolist() == [[1, 2, 3]]
    st.stage("b", (4, 5))
    # prompt mismatch: staged bytes must never win over the request
    assert np.asarray(st.take("b", (9, 9))).tolist() == [[9, 9]]
    # un-staged rid: inline fallback
    assert np.asarray(st.take("c", (7,))).tolist() == [[7]]
    s = st.stats()
    assert s["hits"] == 1 and s["misses"] == 2 and s["queued"] == 0


def test_stager_depth_eviction():
    st = PromptStager(depth=2)
    for i, rid in enumerate(("a", "b", "c")):
        st.stage(rid, (i,))
    assert st.stats()["queued"] == 2        # oldest ("a") evicted
    assert np.asarray(st.take("a", (0,))).tolist() == [[0]]
    assert st.stats()["misses"] == 1


def test_engine_staging_used_on_admit(cfg, params):
    """Staged admission is counted as a hit and decodes the same stream
    as an identical engine admitting the same request unstaged."""
    reqs = _requests(cfg, 2)
    eng = _engine(cfg, params)
    assert eng.stage(reqs[0]) is True
    eng.admit(reqs[0])
    eng.admit(reqs[1])                      # never staged -> miss
    s = eng.stats()["staging"]
    assert s["hits"] == 1 and s["misses"] == 1
    eng2 = _engine(cfg, params)
    eng2.admit(Request(reqs[0].rid, reqs[0].prompt,
                       reqs[0].max_new_tokens))
    drained = [{rid: [int(x) for x in t]
                for rid, t in _drain(e).items()} for e in (eng, eng2)]
    assert drained[0][reqs[0].rid] == drained[1][reqs[0].rid]


def test_sharded_engine_declines_staging(cfg, params):
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ServeEngine(cfg, params, max_slots=SLOTS, max_len=MAX_LEN,
                      chunk=CHUNK, mesh=mesh)
    assert eng.stage(_requests(cfg, 1)[0]) is False


def test_cancel_discards_staged_prompt(cfg, params):
    eng = _engine(cfg, params)
    req = _requests(cfg, 1)[0]
    eng.stage(req)
    assert eng.cancel(req.rid) is None      # never admitted
    assert eng.stager.stats()["queued"] == 0


# -- opportunistic snapshots ----------------------------------------------

def test_snapshot_skip_if_busy(cfg, params, tmp_path):
    ckpt = Checkpointer(str(tmp_path / "ck"), keep=2)
    eng = _engine(cfg, params, pipeline=2)
    assert eng.snapshot(ckpt, step=0) is True
    # immediately queuing another snapshot must not block the serve
    # path: while the background write is live it is skipped, and after
    # wait() the next one lands
    skipped = eng.snapshot(ckpt, step=1)
    ckpt.wait()
    assert eng.snapshot(ckpt, step=2) is True
    ckpt.wait()
    steps = ckpt.all_steps()
    assert 2 in steps
    if skipped:
        assert 1 not in steps
    assert os.path.isdir(tmp_path / "ck")
