"""Tests for the multi-tier memory-hierarchy model (core/memtier.py).

Covers tier resolution at capacity boundaries, zero-capacity (disabled)
tiers, per-mode WA residue across every registered machine, ladder
validation, and the fig5 cache-ladder regression (Grace <= SPR <= Zen 4
WA-adjusted store traffic at every tier).
"""

import math
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import memtier, wa
from repro.core.machine import (MACHINES, MachineValidationError,
                                MachineModel, OpEntry, get_machine,
                                validate_model)
from repro.utils.hw import CPU_CHIPS, MemTier

PAPER_CPUS = ("zen4", "golden_cove", "neoverse_v2")


def _ladder(*rows):
    return tuple(MemTier(*r) for r in rows)


SIMPLE = _ladder(
    ("L1", 32e3, 100e9, 50e9, 0.0, 1.0),
    ("L2", 1e6, 50e9, 25e9, 0.0, 1.0),
    ("DRAM", math.inf, 20e9, 10e9, 200e9, 0.5),
)


# --- resolution ------------------------------------------------------------

def test_boundary_working_sets_resolve_inclusive():
    # exactly at capacity -> still the inner tier; one byte over -> next
    assert memtier.resolve_home(SIMPLE, 32e3).name == "L1"
    assert memtier.resolve_home(SIMPLE, 32e3 + 1).name == "L2"
    assert memtier.resolve_home(SIMPLE, 1e6).name == "L2"
    assert memtier.resolve_home(SIMPLE, 1e6 + 1).name == "DRAM"
    assert memtier.resolve_home(SIMPLE, 1e15).name == "DRAM"


def test_ladder_includes_all_legs_down_to_home():
    assert [t.name for t in memtier.ladder(SIMPLE, 1e3)] == ["L1"]
    assert [t.name for t in memtier.ladder(SIMPLE, 5e5)] == ["L1", "L2"]
    assert [t.name for t in memtier.ladder(SIMPLE, 1e9)] == \
        ["L1", "L2", "DRAM"]


def test_zero_capacity_tiers_are_skipped():
    tiers = _ladder(
        ("L1", 32e3, 100e9, 50e9, 0.0, 1.0),
        ("L2", 0.0, 50e9, 25e9, 0.0, 1.0),          # disabled level
        ("DRAM", math.inf, 20e9, 10e9, 200e9, 0.5),
    )
    assert memtier.resolve_home(tiers, 64e3).name == "DRAM"
    assert [t.name for t in memtier.ladder(tiers, 64e3)] == ["L1", "DRAM"]


def test_all_zero_tiers_raise():
    tiers = _ladder(("L1", 0.0, 1e9, 1e9, 0.0, 1.0))
    with pytest.raises(ValueError):
        memtier.resolve_home(tiers, 1.0)


def test_every_registered_machine_has_a_resolvable_ladder():
    for name, m in MACHINES.items():
        tiers = memtier.tiers_of(m)
        assert tiers, name
        assert tiers[-1].capacity_bytes == math.inf, name
        res = memtier.transfer_time(m, ws_bytes=1e9, load_bytes=1e9,
                                    store_bytes=1e9)
        assert res.seconds > 0, name
        assert res.home == tiers[-1].name, name


def test_machines_without_tiers_get_flat_dram_fallback():
    bare = MachineModel(
        name="bare", clock_hz=1e9, ports=("P0", "MEM"),
        table={cls: OpEntry(("MEM",) if cls in ("dma", "ici") else ("P0",),
                            1.0, 1.0)
               for cls in ("mxu", "vpu", "xlu", "vdiv", "vlsu", "gather4",
                           "sc", "dma", "ici")})
    tiers = memtier.tiers_of(bare)
    assert len(tiers) == 1 and tiers[0].name == "DRAM"
    # dma is 1 cycle/byte at 1 GHz -> 1 GB/s flat
    res = memtier.transfer_time(bare, ws_bytes=1e6, load_bytes=1e9)
    assert res.seconds == pytest.approx(1.0)


# --- ECM composition -------------------------------------------------------

def test_full_overlap_is_max_none_is_sum():
    kw = dict(ws_bytes=1e9, load_bytes=1e9, store_bytes=0.0)
    full = memtier.transfer_time("zen4", overlap="full", **kw)
    none = memtier.transfer_time("zen4", overlap="none", **kw)
    assert full.seconds == pytest.approx(
        max(leg.seconds for leg in full.legs))
    assert none.seconds == pytest.approx(
        sum(leg.seconds for leg in none.legs))
    assert none.seconds > full.seconds
    with pytest.raises(ValueError):
        memtier.transfer_time("zen4", overlap="half", **kw)


def test_tpu_dram_resident_degrades_to_flat_hbm_roofline():
    m = get_machine("tpu_v5e")
    traffic = 8e9                     # >> VMEM -> home tier is HBM
    res = memtier.memory_seconds(m, traffic)
    assert res.home == "HBM"
    assert res.seconds == pytest.approx(traffic / m.chip.hbm_bw)


def test_private_tiers_scale_with_cores_shared_tiers_cap():
    t_priv = MemTier("L1", 1e5, 10e9, 10e9, shared_bw=0.0)
    t_shared = MemTier("DRAM", math.inf, 10e9, 10e9, shared_bw=40e9)
    assert memtier.effective_bw(t_priv, 8) == (80e9, 80e9)
    assert memtier.effective_bw(t_shared, 8) == (40e9, 40e9)


# --- modeled saturation (the SpecI2M gate) ---------------------------------

def test_saturation_zero_on_private_tiers_and_full_at_dram():
    for name in PAPER_CPUS:
        m = get_machine(name)
        assert memtier.modeled_saturation(m, 16e3) == 0.0       # L1
        assert memtier.modeled_saturation(m, 1e9, m.cores) == 1.0
        assert memtier.modeled_saturation(m, 1e9, 1) < 1.0      # one core


def test_traffic_ratio_for_uses_ladder_gate():
    # SpecI2M dormant for an L1-resident set, engaged for a DRAM set
    r_cache = wa.traffic_ratio_for("golden_cove", ws_bytes=16e3)
    r_dram = wa.traffic_ratio_for("golden_cove", ws_bytes=1e9)
    assert r_cache == pytest.approx(2.0)
    assert r_dram < r_cache
    # explicit bw_utilization still overrides the model
    assert wa.traffic_ratio_for("golden_cove", ws_bytes=16e3,
                                bw_utilization=1.0) == pytest.approx(1.75)


# --- WA residue per mode ---------------------------------------------------

def test_wa_residue_per_mode_across_all_registered_machines():
    """Per-tier store-traffic ratios follow each machine's wa_mode and
    its declared per-tier residue on every registered machine."""
    for name, m in MACHINES.items():
        res = memtier.transfer_time(m, ws_bytes=1e12, load_bytes=0.0,
                                    store_bytes=1e6,
                                    cores_active=m.cores or 1)
        tiers = {t.name: t for t in memtier.tiers_of(m)}
        for leg in res.legs:
            residue = tiers[leg.tier].wa_residue
            if m.wa_mode == "auto_claim":
                assert leg.wa_ratio == pytest.approx(1.0 + residue), \
                    (name, leg.tier)
            elif m.wa_mode == "explicit_only":
                assert leg.wa_ratio == pytest.approx(2.0), (name, leg.tier)
            else:           # saturation_gated: between residue and full WA
                assert 1.0 + residue <= leg.wa_ratio + 1e-9, (name, leg.tier)
                assert leg.wa_ratio <= 2.0 + 1e-9, (name, leg.tier)
            assert 1.0 <= leg.wa_ratio <= 2.0 + 1e-9


def test_nt_stores_invert_zen4_at_dram_only():
    std = memtier.transfer_time("zen4", ws_bytes=1e9, load_bytes=0.0,
                                store_bytes=1e6)
    nt = memtier.transfer_time("zen4", ws_bytes=1e9, load_bytes=0.0,
                               store_bytes=1e6, nt_stores=True)
    assert std.legs[-1].wa_ratio == pytest.approx(2.0)
    assert nt.legs[-1].wa_ratio == pytest.approx(1.0)   # full NT evasion


def test_paper_cpu_specs_carry_four_tier_ladders():
    for name in PAPER_CPUS:
        spec = CPU_CHIPS[name]
        names = [t.name for t in spec.mem_tiers]
        assert names == ["L1", "L2", "L3", "DRAM"], name
        assert spec.mem_tiers[0].capacity_bytes == spec.l1d_bytes, name
        assert spec.mem_tiers[-1].shared_bw == spec.mem_bw, name
        model = get_machine(name)
        assert tuple(model.mem_tiers) == tuple(spec.mem_tiers), name
        assert model.cores == spec.cores, name


# --- validation ------------------------------------------------------------

def _model_with_tiers(tiers):
    base = get_machine("zen4")
    import dataclasses
    return dataclasses.replace(base, name="tiers_test", mem_tiers=tiers)


def test_validate_rejects_bad_ladders():
    bad = [
        _ladder(("L1", -1.0, 1e9, 1e9, 0.0, 1.0),
                ("DRAM", math.inf, 1e9, 1e9, 0.0, 1.0)),   # negative cap
        _ladder(("L1", 1e6, 1e9, 1e9, 0.0, 1.0),
                ("L2", 1e3, 1e9, 1e9, 0.0, 1.0),
                ("DRAM", math.inf, 1e9, 1e9, 0.0, 1.0)),   # shrinking cap
        _ladder(("L1", 1e3, 0.0, 1e9, 0.0, 1.0),
                ("DRAM", math.inf, 1e9, 1e9, 0.0, 1.0)),   # zero bw
        _ladder(("L1", 1e3, 1e9, 1e9, 0.0, 1.5),
                ("DRAM", math.inf, 1e9, 1e9, 0.0, 1.0)),   # residue > 1
        _ladder(("L1", 1e3, 1e9, 1e9, 0.0, 1.0),),         # no inf tier
    ]
    for tiers in bad:
        with pytest.raises(MachineValidationError):
            validate_model(_model_with_tiers(tiers))


def test_validate_accepts_zero_capacity_disabled_levels():
    validate_model(_model_with_tiers(_ladder(
        ("L1", 1e3, 1e9, 1e9, 0.0, 1.0),
        ("L2", 0.0, 1e9, 1e9, 0.0, 1.0),
        ("DRAM", math.inf, 1e9, 1e9, 0.0, 1.0))))


# --- fig5 regression -------------------------------------------------------

def test_fig5_ladder_keeps_grace_spr_zen4_ordering():
    from benchmarks import fig5_memladder
    rows = fig5_memladder.ladder_rows()
    verdicts = fig5_memladder.ordering_ok(rows)
    assert set(verdicts) == {"L1", "L2", "L3", "DRAM"}
    assert all(verdicts.values()), verdicts
    # every sweep point resolved to the tier it was aimed at
    for r in rows:
        assert r["home"] == r["ws_label"], r


def test_fig5_main_emits_rows_and_verdicts():
    from benchmarks import fig5_memladder
    lines = fig5_memladder.main(quick=True)
    assert any(",ordering_DRAM,0,grace<=spr<=zen4=OK" in ln
               for ln in lines)
    assert sum(1 for ln in lines if ln.startswith("fig5,")) >= 16
