"""Minimal stand-in for `hypothesis` used when the real package is not
installed (satellite of the CI issue: the suite must *run* everywhere,
with full property-based coverage whenever hypothesis is available).

conftest.py installs these objects into ``sys.modules`` as `hypothesis`,
`hypothesis.strategies`, and `hypothesis.extra.numpy` BEFORE test modules
import them. `@given` then draws a small, deterministically-seeded set of
examples per test (boundary values first), which keeps the properties
exercised — just with far fewer examples than real hypothesis.

Only the API surface this repo's tests use is implemented: integers,
floats, booleans, sampled_from, lists, tuples, just, arrays (from
hypothesis.extra.numpy), @given, settings, HealthCheck.
"""

from __future__ import annotations

import random
import types

import numpy as np

N_EXAMPLES = 12


class _Strategy:
    """A strategy draws one value from a seeded Random; `boundary()`
    yields the deterministic edge examples tried before random draws."""

    def draw(self, rnd: random.Random):
        raise NotImplementedError

    def boundary(self) -> list:
        return []

    # real hypothesis supports `.map`/`.filter`; keep the common two
    def map(self, fn):
        return _Mapped(self, fn)

    def filter(self, pred):
        return _Filtered(self, pred)


class _Mapped(_Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def draw(self, rnd):
        return self.fn(self.base.draw(rnd))

    def boundary(self):
        return [self.fn(v) for v in self.base.boundary()]


class _Filtered(_Strategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def draw(self, rnd):
        for _ in range(100):
            v = self.base.draw(rnd)
            if self.pred(v):
                return v
        raise ValueError("filter predicate too strict for stub strategy")

    def boundary(self):
        return [v for v in self.base.boundary() if self.pred(v)]


class _Integers(_Strategy):
    def __init__(self, min_value=0, max_value=1 << 16):
        self.lo, self.hi = min_value, max_value

    def draw(self, rnd):
        return rnd.randint(self.lo, self.hi)

    def boundary(self):
        mid = (self.lo + self.hi) // 2
        return list(dict.fromkeys([self.lo, self.hi, mid]))


class _Floats(_Strategy):
    def __init__(self, min_value=0.0, max_value=1.0, width=64, **_kw):
        self.lo, self.hi = float(min_value), float(max_value)

    def draw(self, rnd):
        return rnd.uniform(self.lo, self.hi)

    def boundary(self):
        return [self.lo, self.hi, 0.5 * (self.lo + self.hi)]


class _Booleans(_Strategy):
    def draw(self, rnd):
        return rnd.random() < 0.5

    def boundary(self):
        return [False, True]


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def draw(self, rnd):
        return rnd.choice(self.elements)

    def boundary(self):
        return self.elements[: min(3, len(self.elements))]


class _Just(_Strategy):
    def __init__(self, value):
        self.value = value

    def draw(self, rnd):
        return self.value

    def boundary(self):
        return [self.value]


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=8, unique=False):
        self.el, self.lo = elements, min_size
        self.hi, self.unique = max_size, unique

    def draw(self, rnd):
        n = rnd.randint(self.lo, self.hi)
        out: list = []
        tries = 0
        while len(out) < n and tries < 100 * (n + 1):
            v = self.el.draw(rnd)
            tries += 1
            if self.unique and v in out:
                continue
            out.append(v)
        return out

    def boundary(self):
        b = []
        if self.lo == 0:
            b.append([])
        eb = self.el.boundary()
        if eb:
            b.append((eb * self.hi)[: max(self.lo, min(self.hi, 2))])
        return b


class _Tuples(_Strategy):
    def __init__(self, *strategies):
        self.strategies = strategies

    def draw(self, rnd):
        return tuple(s.draw(rnd) for s in self.strategies)

    def boundary(self):
        bs = [s.boundary() or [s.draw(random.Random(0))]
              for s in self.strategies]
        return [tuple(b[0] for b in bs)]


class _Arrays(_Strategy):
    def __init__(self, dtype, shape, elements=None, **_kw):
        self.dtype = np.dtype(dtype)
        self.shape = shape
        self.elements = elements

    def _shape(self, rnd):
        s = self.shape
        if isinstance(s, _Strategy):
            s = s.draw(rnd)
        return (s,) if isinstance(s, int) else tuple(s)

    def draw(self, rnd):
        shape = self._shape(rnd)
        n = int(np.prod(shape)) if shape else 1
        el = self.elements or _Floats(-1e3, 1e3)
        flat = [el.draw(rnd) for _ in range(n)]
        return np.array(flat, dtype=self.dtype).reshape(shape)

    def boundary(self):
        rnd = random.Random(0)
        shape = self._shape(rnd)
        return [np.zeros(shape, dtype=self.dtype)]


def given(*gargs, **gkwargs):
    """Deterministic mini-@given: boundary examples, then seeded draws."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            strategies = list(gargs)
            rnd = random.Random(fn.__qualname__)
            runs = []
            bounds = [s.boundary() for s in strategies]
            if all(bounds):
                runs.append([b[0] for b in bounds])
            for _ in range(N_EXAMPLES):
                runs.append([s.draw(rnd) for s in strategies])
            kw_strats = {k: v for k, v in gkwargs.items()}
            for drawn in runs:
                kws = dict(kwargs)
                kws.update({k: v.draw(rnd) for k, v in kw_strats.items()})
                fn(*args, *drawn, **kws)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_stub = True
        return wrapper

    return deco


class settings:
    """No-op stand-in for hypothesis.settings (incl. profile registry)."""

    _profiles: dict = {}

    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, *args, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._profiles.setdefault(name, {})


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


def _build_modules() -> dict:
    """{module name: module} ready for sys.modules insertion."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.sampled_from = _SampledFrom
    st.lists = _Lists
    st.tuples = _Tuples
    st.just = _Just

    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = _Arrays

    extra = types.ModuleType("hypothesis.extra")
    extra.numpy = hnp

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.extra = extra
    hyp.__version__ = "0.0-stub"
    hyp.__is_repro_stub__ = True

    return {"hypothesis": hyp, "hypothesis.strategies": st,
            "hypothesis.extra": extra, "hypothesis.extra.numpy": hnp}
