"""Write-allocate / RMW analyzer: tile math, the three behavioural machine
modes of paper Fig. 4, and module-level store scanning."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from repro.core import wa


def test_full_tile_store_perfect_evasion():
    p = wa.store_profile((4096, 4096), "f32")
    assert p.ratio == pytest.approx(1.0)
    p16 = wa.store_profile((4096, 4096), "bf16")
    assert p16.ratio == pytest.approx(1.0)


def test_partial_tile_store_pays_rmw():
    p = wa.store_profile((7, 100), "f32", offset_aligned=False)
    assert p.ratio > 1.5
    edge = wa.store_profile((4095, 4090), "f32")
    assert 1.0 < edge.ratio < 1.1     # only the edge tiles RMW


def test_missing_donation_costs_full_copy():
    p = wa.store_profile((8, 128), "f32", donated=False,
                         full_overwrite=False, buffer_bytes=1e6)
    assert p.traffic >= 2e6


def test_machine_modes_match_paper_fig4():
    # Grace: flat 1.0
    assert wa.machine_traffic_ratio("auto_claim") == pytest.approx(1.0)
    # SPR: 2.0 at low utilization, partial evasion near saturation
    lo = wa.machine_traffic_ratio("saturation_gated", bw_utilization=0.2)
    hi = wa.machine_traffic_ratio("saturation_gated", bw_utilization=1.0)
    assert lo == pytest.approx(2.0)
    assert 1.7 <= hi < 2.0
    # SPR NT stores: ~10% residue
    assert wa.machine_traffic_ratio("saturation_gated", nt_stores=True) \
        == pytest.approx(1.1)
    # Zen 4: 2.0 standard, exactly 1.0 with NT stores
    assert wa.machine_traffic_ratio("explicit_only") == pytest.approx(2.0)
    assert wa.machine_traffic_ratio("explicit_only", nt_stores=True) \
        == pytest.approx(1.0)


@given(st.sampled_from(["auto_claim", "saturation_gated", "explicit_only"]),
       st.booleans(), st.floats(0.0, 1.0))
def test_ratio_bounds(mode, nt, util):
    r = wa.machine_traffic_ratio(mode, nt_stores=nt, bw_utilization=util)
    assert 1.0 <= r <= 3.0


@given(st.integers(1, 300), st.integers(1, 300),
       st.sampled_from(["f32", "bf16"]))
def test_store_profile_ratio_bounds(rows, cols, dtype):
    p = wa.store_profile((rows, cols), dtype)
    assert p.ratio >= 1.0
    # RMW can at most read back every touched tile once
    assert p.ratio <= 1.0 + (p.rmw_read_bytes / max(p.stored_bytes, 1)) + 1e-9


def test_module_scan_finds_stores():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (3, 5))
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 256), jnp.float32),
        jax.ShapeDtypeStruct((8, 100), jnp.float32)).compile().as_text()
    out = wa.analyze_text_stores(txt)
    assert out["stored_bytes"] > 0
    assert out["wa_ratio"] >= 1.0
