"""Regenerate the golden compare() regression fixture.

Lowers a deterministic multi-feature workload (nested scans, fused
update-in-place, slices, gather, divide, transcendentals, dots), saves
the compiled HLO text to tests/data/golden.hlo, and captures the
default-backend ``portmodel.compare`` output over the six built-in
machines as tests/data/golden_compare.json.

The digest format is shared with tests/test_golden_compare.py — run
this script ONLY when an intentional model change invalidates the
golden (and say so in the commit).

Run:  PYTHONPATH=src:. python scripts/gen_golden_compare.py
"""

import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from tests.test_golden_compare import GOLDEN_MACHINES, digest  # noqa: E402


def golden_workload_hlo() -> str:
    """A deterministic module exercising every analyzer path."""

    def step(x, w1, w2, idx, cache):
        def outer(carry, _):
            c, i = carry

            def inner(h, _):
                return jnp.tanh(h @ w1) * 0.5 + h * 0.5, None

            h, _ = jax.lax.scan(inner, c, None, length=3)
            g = jax.nn.softmax(h, axis=-1) @ w2
            g = g / (1.0 + jnp.exp(-h))          # divide + logistic
            return (g + c, i + 1), None

        (y, _), _ = jax.lax.scan(outer, (x, 0), None, length=5)
        top = jnp.take(y, idx, axis=0)           # gather
        sl = jax.lax.slice(y, (0, 0), (8, y.shape[1]))
        cache = jax.lax.dynamic_update_slice(cache, y[None], (1, 0, 0))
        return y, top.sum() + sl.sum(), cache

    args = [
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.int32),
        jax.ShapeDtypeStruct((4, 64, 128), jnp.float32),
    ]
    return jax.jit(step).lower(*args).compile().as_text()


def main():
    from repro.core import portmodel

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    data = os.path.join(here, "tests", "data")
    os.makedirs(data, exist_ok=True)
    hlo_path = os.path.join(data, "golden.hlo")
    json_path = os.path.join(data, "golden_compare.json")

    if os.path.exists(hlo_path):
        hlo = open(hlo_path).read()
        print(f"reusing existing fixture {hlo_path}")
    else:
        hlo = golden_workload_hlo()
        with open(hlo_path, "w") as f:
            f.write(hlo)
        print(f"wrote {hlo_path} ({len(hlo)} bytes)")

    reports = portmodel.compare(hlo, machines=GOLDEN_MACHINES,
                                parallel="serial")
    with open(json_path, "w") as f:
        f.write(digest(reports))
    print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
