#!/usr/bin/env bash
# Serving launch environment. Source before any repro.launch entrypoint:
#
#   source scripts/launch_env.sh [n_host_devices]
#
# Two things are exported, both safe no-ops when unavailable:
#
# 1. tcmalloc preload — the serve engines churn large host buffers
#    (prompt staging, per-round block tables, result assembly); glibc
#    malloc's arena locking shows up in the dispatch loop under replica
#    concurrency. If a tcmalloc shared object exists on this box it is
#    LD_PRELOADed; otherwise nothing changes. The large-alloc report
#    threshold is raised so page-pool-sized mmaps don't spam stderr.
#
# 2. XLA host device count — the sharded serve tests and fig9_load run
#    TP over *faked* host devices
#    (--xla_force_host_platform_device_count). The count comes from the
#    first argument, then $REPRO_HOST_DEVICES, then defaults to 1 (the
#    bit-exact single-device path). Set before the first jax import —
#    jax pins the device count at init. An existing XLA_FLAGS value is
#    kept and extended, never clobbered; if it already forces a device
#    count, it wins.

_repro_ndev="${1:-${REPRO_HOST_DEVICES:-1}}"

for _repro_lib in \
    /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
    /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
    /usr/lib/libtcmalloc_minimal.so.4 \
    /usr/lib/libtcmalloc.so.4; do
    if [ -f "${_repro_lib}" ]; then
        case ":${LD_PRELOAD:-}:" in
            *":${_repro_lib}:"*) ;;
            *) export LD_PRELOAD="${_repro_lib}${LD_PRELOAD:+:${LD_PRELOAD}}" ;;
        esac
        # page pools are tens of MB per replica: mute the per-alloc log
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=1073741824
        break
    fi
done
unset _repro_lib

case " ${XLA_FLAGS:-} " in
    *xla_force_host_platform_device_count*) ;;
    *)
        export XLA_FLAGS="--xla_force_host_platform_device_count=${_repro_ndev}${XLA_FLAGS:+ ${XLA_FLAGS}}"
        ;;
esac
unset _repro_ndev
