#!/usr/bin/env bash
# Serving launch environment — config-driven runtime policy. Source
# before any repro.launch entrypoint:
#
#   source scripts/launch_env.sh [n_host_devices]
#
# Policy knobs (all optional, all safe no-ops when unset/unavailable):
#
#   REPRO_HOST_DEVICES=N        faked host device count (arg 1 wins)
#   REPRO_TCMALLOC_REPORT=N     TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD
#                               bytes (default 1 GiB: page pools are
#                               tens of MB per replica — mute the log)
#   REPRO_STEP_MARKER=0|1|2     --xla_step_marker_location placement
#                               (0=entry, 1=per-step markers around the
#                               outer loop, 2=none); profile-friendly
#                               step boundaries for the decode rounds
#   REPRO_DTYPE_POLICY=bf16|tf32|f32
#                               default matmul precision, consumed
#                               in-process by repro.launch.serve
#                               (apply_runtime_policy) — exported here
#                               so shell and driver share one config
#
# What gets exported:
#
# 1. tcmalloc preload — the serve engines churn large host buffers
#    (prompt staging, per-round block tables, result assembly); glibc
#    malloc's arena locking shows up in the dispatch loop under replica
#    concurrency. If a tcmalloc shared object exists on this box it is
#    LD_PRELOADed; otherwise nothing changes. The large-alloc report
#    threshold honors REPRO_TCMALLOC_REPORT.
#
# 2. XLA flags — host device count for the sharded serve tests and
#    fig9_load (--xla_force_host_platform_device_count; first argument,
#    then $REPRO_HOST_DEVICES, then 1 — the bit-exact single-device
#    path), plus the step-marker placement when REPRO_STEP_MARKER is
#    set. Set before the first jax import — jax pins XLA flags at
#    backend init. An existing XLA_FLAGS value is kept and extended,
#    never clobbered; flags it already carries win.
#
# 3. The dtype-policy env block — REPRO_DTYPE_POLICY is validated and
#    re-exported for repro.launch.serve to apply via
#    jax.config.update("jax_default_matmul_precision", ...). XLA flags
#    must be set pre-import, but matmul precision is a jax config —
#    the python side owns the actual update.

_repro_ndev="${1:-${REPRO_HOST_DEVICES:-1}}"

for _repro_lib in \
    /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
    /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
    /usr/lib/libtcmalloc_minimal.so.4 \
    /usr/lib/libtcmalloc.so.4; do
    if [ -f "${_repro_lib}" ]; then
        case ":${LD_PRELOAD:-}:" in
            *":${_repro_lib}:"*) ;;
            *) export LD_PRELOAD="${_repro_lib}${LD_PRELOAD:+:${LD_PRELOAD}}" ;;
        esac
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${REPRO_TCMALLOC_REPORT:-1073741824}"
        break
    fi
done
unset _repro_lib

case " ${XLA_FLAGS:-} " in
    *xla_force_host_platform_device_count*) ;;
    *)
        export XLA_FLAGS="--xla_force_host_platform_device_count=${_repro_ndev}${XLA_FLAGS:+ ${XLA_FLAGS}}"
        ;;
esac
unset _repro_ndev

# step-marker placement: profile tools cut the trace at step boundaries;
# placement 1 wraps each outer (decode-round) step. Only appended when
# requested and not already present — existing flags win.
if [ -n "${REPRO_STEP_MARKER:-}" ]; then
    case " ${XLA_FLAGS:-} " in
        *xla_step_marker_location*) ;;
        *)
            export XLA_FLAGS="${XLA_FLAGS:+${XLA_FLAGS} }--xla_step_marker_location=${REPRO_STEP_MARKER}"
            ;;
    esac
fi

# dtype policy: validate here (fail fast at source time, not mid-serve)
# and re-export; repro.launch.serve.apply_runtime_policy applies it.
if [ -n "${REPRO_DTYPE_POLICY:-}" ]; then
    case "${REPRO_DTYPE_POLICY}" in
        bf16|tf32|f32)
            export REPRO_DTYPE_POLICY
            ;;
        *)
            echo "launch_env.sh: unknown REPRO_DTYPE_POLICY='${REPRO_DTYPE_POLICY}' (expected bf16|tf32|f32)" >&2
            return 1 2>/dev/null || exit 1
            ;;
    esac
fi
