"""Verify that relative links in the repo's markdown docs resolve.

Scans README.md, DESIGN.md, ROADMAP.md, and docs/*.md for inline
markdown links (``[text](target)``) and checks every non-external,
non-anchor target exists relative to the file that references it.
Exits non-zero listing the broken links — CI's docs job runs this.

Usage: python scripts/check_docs_links.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

#: inline links; images share the syntax (leading ! is harmless here)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

EXTERNAL = ("http://", "https://", "mailto:")


def doc_files(root: Path) -> list:
    """The markdown files whose links the docs job guarantees."""
    files = [root / "README.md", root / "DESIGN.md", root / "ROADMAP.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def broken_links(md_file: Path) -> list:
    """(target, reason) for every unresolvable link in one file."""
    bad = []
    text = md_file.read_text(encoding="utf-8")
    # strip fenced code blocks — ASCII diagrams aren't links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in LINK_RE.findall(text):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md_file.parent / path).exists():
            bad.append((target, "missing file"))
    return bad


def main() -> int:
    """Check every doc file; print failures; return the exit code."""
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    failures = 0
    for f in doc_files(root):
        for target, reason in broken_links(f):
            print(f"BROKEN {f}: ({target}) {reason}")
            failures += 1
    n = len(doc_files(root))
    print(f"checked {n} files: "
          f"{'OK' if not failures else f'{failures} broken links'}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
