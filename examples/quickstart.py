"""Quickstart: the paper's workflow end-to-end on one kernel.

1. Write a JAX kernel (STREAM triad).
2. Compile it and let the port model (OSACA-semantics TP/CP/LCD over the
   compiled HLO) produce a lower-bound runtime for the TPU v5e machine
   model AND the ubench-calibrated host model.
3. Measure on the host and compare both our model and the naive
   cost_analysis baseline (the LLVM-MCA stand-in) — paper Fig. 3 in
   miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import baseline, portmodel
from repro.core.machine import MACHINES
from repro.core.ubench import calibrated_host_model, host_peaks, tier_bw

N = 1 << 22


def triad(b, c):
    return b + 2.5 * c


def main():
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, (N,), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (N,), jnp.float32)

    fn = jax.jit(triad)
    compiled = fn.lower(b, c).compile()
    hlo = compiled.as_text()

    # --- target machine: TPU v5e (spec-derived model) ---
    v5e = MACHINES["tpu_v5e"]
    rep = portmodel.analyze(hlo, v5e)
    print("== TPU v5e (target) ==")
    print(f"  flops={rep.flops:.3e}  hbm_bytes={rep.bytes_hbm:.3e}")
    print(f"  in-core bound: {rep.bound_incore_cycles/v5e.clock_hz*1e6:.2f} us"
          f"   full bound: {rep.seconds(v5e)*1e6:.2f} us"
          f"   bottleneck: {rep.bottleneck()}")

    # --- host: calibrate, predict, measure ---
    host = calibrated_host_model()
    rep_h = portmodel.analyze(hlo, host)
    ws = 2 * 4 * N
    t_pred = max(rep_h.seconds_incore(host), rep_h.bytes_hbm / tier_bw(ws))
    peak, bw = host_peaks()
    ca = compiled.cost_analysis()   # predict() normalizes old-jax lists
    t_naive = baseline.predict(ca, host, peak, bw).seconds

    out = fn(b, c)
    jax.block_until_ready(out)
    best = min(_timed(fn, b, c) for _ in range(5))
    print("== host (measured vs predicted) ==")
    print(f"  measured     : {best*1e6:9.1f} us")
    print(f"  port model   : {t_pred*1e6:9.1f} us  "
          f"(rpe {(best-t_pred)/best:+.2f}; >=0 means lower bound held)")
    print(f"  naive (MCA~) : {t_naive*1e6:9.1f} us  "
          f"(rpe {(best-t_naive)/best:+.2f})")


def _timed(fn, *args):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


if __name__ == "__main__":
    main()
