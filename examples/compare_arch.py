"""Cross-vendor comparison table (the paper's headline result): analyze
ONE compiled HLO module on the three paper CPUs (Zen 4 / Genoa, Golden
Cove / Sapphire Rapids, Neoverse V2 / Grace) and a TPU, side by side.

For each machine the registry fan-out (`portmodel.compare`) reports the
in-core bound, the bottleneck port, the tier-resolved bound with its
bottleneck memory tier (ECM ladder, core/memtier.py), and the
WA-adjusted store traffic under that machine's write-allocate mode —
reproducing the paper's qualitative ordering: Grace (auto claim) <=
SPR (SpecI2M) <= Zen 4 (explicit NT stores only).

Run:  PYTHONPATH=src python examples/compare_arch.py [--seq 128] [--nt]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import portmodel, wa
from repro.core.machine import get_machine

DEFAULT_MACHINES = ("zen4", "golden_cove", "neoverse_v2", "tpu_v5p")


def workload_hlo(seq: int, d_model: int, n_layers: int) -> str:
    """A scanned residual MLP block writing into a cache slot — enough
    structure to exercise matmul, transcendental, and store paths."""

    def step(x, w1, w2, cache):
        def body(carry, _):
            c, i = carry
            h = jnp.tanh(c @ w1)
            o = jax.nn.softmax(h, axis=-1) @ w2 + c
            return (o, i + 1), None
        (y, _), _ = jax.lax.scan(body, (x, 0), None, length=n_layers)
        cache = jax.lax.dynamic_update_slice(cache, y[None], (0, 0, 0))
        return y, cache

    args = [
        jax.ShapeDtypeStruct((seq, d_model), jnp.float32),
        jax.ShapeDtypeStruct((d_model, d_model), jnp.float32),
        jax.ShapeDtypeStruct((d_model, d_model), jnp.float32),
        jax.ShapeDtypeStruct((4, seq, d_model), jnp.float32),
    ]
    return jax.jit(step).lower(*args).compile().as_text()


def compare_table(hlo: str, machines=DEFAULT_MACHINES,
                  nt_stores: bool = False,
                  backend: str = "tp_bound") -> list:
    """[(name, report, wa-dict)] for one module across machines.

    ``backend`` picks the scheduling engine (``tp``/``mca``); the trace
    is lowered once regardless (core/trace.py).
    """
    reports = portmodel.compare(hlo, machines=machines, backends=backend)
    scan = wa.analyze_text_stores(hlo)     # machine-independent: once
    rows = []
    for name, rep in reports.items():
        w = wa.apply_wa_mode(scan, name, nt_stores=nt_stores)
        rows.append((name, rep, w))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--nt", action="store_true",
                    help="assume non-temporal stores")
    ap.add_argument("--backend", default="tp",
                    help="scheduling backend: tp (analytical bound) or "
                         "mca (cycle simulator)")
    args = ap.parse_args()

    hlo = workload_hlo(args.seq, args.d_model, args.layers)
    rows = compare_table(hlo, nt_stores=args.nt, backend=args.backend)

    hdr = (f"{'machine':<13} {'uarch':<22} {'clock':>6} {'bound cy':>12} "
           f"{'in-core cy':>12} {'t_bound':>9} {'t_tier':>9} "
           f"{'bottleneck':>12} {'tier':>5} "
           f"{'wa_mode':<16} {'wa x':>5} {'store MB':>9}")
    print(f"module: scan[{args.layers}] residual MLP, "
          f"{args.seq}x{args.d_model} f32"
          + (" (NT stores)" if args.nt else ""))
    print(hdr)
    print("-" * len(hdr))
    for name, rep, w in rows:
        m = get_machine(name)
        uarch = (m.notes.split(":")[0] if ":" in m.notes
                 else f"{m.vendor} {m.isa_name}".strip())[:22]
        print(f"{name:<13} {uarch:<22} "
              f"{m.clock_hz/1e9:>5.2f}G {rep.bound_cycles:>12.3e} "
              f"{rep.bound_incore_cycles:>12.3e} "
              f"{rep.seconds(m)*1e6:>7.1f}us "
              f"{rep.tier_bound_seconds(m)*1e6:>7.1f}us "
              f"{rep.bottleneck():>12} "
              f"{rep.bottleneck_tier or 'n/a':>5} "
              f"{w['wa_mode']:<16} {w['wa_ratio']:>5.2f} "
              f"{w['traffic_bytes']/1e6:>9.2f}")

    traffic = {name: w["traffic_bytes"] for name, _, w in rows}
    # the paper's qualitative ordering only applies to standard stores —
    # with NT stores Zen 4 evades fully and the ordering inverts
    if not args.nt and \
            all(k in traffic for k in ("neoverse_v2", "golden_cove", "zen4")):
        ok = (traffic["neoverse_v2"] <= traffic["golden_cove"]
              <= traffic["zen4"])
        print(f"\nWA ordering Grace <= SPR <= Zen4 (no NT stores): "
              f"{'OK' if ok else 'VIOLATED'} "
              f"({traffic['neoverse_v2']/1e6:.2f} <= "
              f"{traffic['golden_cove']/1e6:.2f} <= "
              f"{traffic['zen4']/1e6:.2f} MB)")


if __name__ == "__main__":
    main()
