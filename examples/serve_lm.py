"""Continuous-batching serving example: more requests than slots, mixed
prompt lengths and budgets, on a reduced gemma3 config (local+global
attention mix exercises both cache kinds). Requests are admitted as
slots free up; the KV slot cache is preallocated once and updated in
place (the framework's NT-store analogue).

Run:  PYTHONPATH=src python examples/serve_lm.py
Sharded (2 fake host devices, heads split over TP):
      XLA_FLAGS=--xla_force_host_platform_device_count=2 \
          PYTHONPATH=src python examples/serve_lm.py --mesh data,model=1,2
Replicated (2 engines behind the round-robin router):
      PYTHONPATH=src python examples/serve_lm.py --replicas 2
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve import ReplicaRouter, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="",
                    help="device mesh spec 'data,model=1,N' "
                         "(default: single-device, no mesh)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the round-robin router")
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_serve_mesh
    mesh = make_serve_mesh(args.mesh)

    cfg = get_smoke_config("gemma3-4b")
    k_params, k_prompts = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(cfg, k_params)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"req{i}",
                    prompt=tuple(rng.integers(0, cfg.vocab_size,
                                              16 if i % 2 else 24)),
                    max_new_tokens=16 + 8 * (i % 3))
            for i in range(6)]

    engines = [ServeEngine(cfg, params, max_slots=2, max_len=64,
                           temperature=0.8, seed=0, mesh=mesh)
               for _ in range(max(1, args.replicas))]
    eng = engines[0]
    t0 = time.time()
    if len(engines) == 1:
        results = eng.run(list(reqs))
    else:
        results = ReplicaRouter(engines, policy="round_robin",
                                max_queue=len(reqs)).run(list(reqs))
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    shard = f", tp={eng.tp}" if mesh is not None else ""
    repl = f", {len(engines)} replicas" if len(engines) > 1 else ""
    print(f"served {len(reqs)} requests on {eng.max_slots} slots: "
          f"{total} tokens in {dt:.2f}s — chunk={eng.chunk}, "
          f"{eng.decode_dispatches} decode dispatches, "
          f"{eng.prefill_dispatches} prefills{shard}{repl}")
    for r in reqs:
        print(f"  {r.rid}: {len(results[r.rid])} tokens, "
              f"first 8 = {results[r.rid][:8].tolist()}")


if __name__ == "__main__":
    main()
