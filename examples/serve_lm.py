"""Batched serving example: prefill + decode with a donated KV cache
(the framework's NT-store analogue) on a reduced gemma3 config (local+
global attention mix exercises both cache kinds).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "gemma3-4b", "--smoke",
                "--batch", "4", "--prompt-len", "64", "--gen", "32",
                "--temperature", "0.8"])


if __name__ == "__main__":
    main()
