"""End-to-end driver: train the FULL xlstm-125m config (~125M params —
the assignment's ~100M-model example) for a few hundred steps on the
synthetic LM stream, with checkpointing and straggler detection.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU-friendly: batch 4 x seq 256; expect a clearly decreasing loss.)
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    train_main(["--arch", "xlstm-125m",            # full 125M config
                "--steps", str(args.steps),
                "--batch", str(args.batch),
                "--seq", str(args.seq),
                "--lr", "1e-3",
                "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "100",
                "--log-every", "10"])


if __name__ == "__main__":
    main()
