"""Analyze one (architecture x shape) cell like the dry-run does, on a
reduced config and tiny mesh so it runs anywhere: lower + compile a train
step, run the port model + WA analyzer on the compiled HLO, print the
three roofline terms for TPU v5e.

Run:  PYTHONPATH=src python examples/analyze_arch.py --arch jamba-v0.1-52b
"""

import argparse

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ShapeSpec
from repro.core import portmodel, wa
from repro.core.machine import MACHINES
from repro.optim.adamw import OptConfig
from repro.train import step as step_lib
from repro.utils.hw import HBM_BW, ICI_BW, PEAK_FLOPS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="jamba-v0.1-52b",
                    choices=list(ARCH_IDS))
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = ShapeSpec("example", args.seq, args.batch, "train")
    fn = step_lib.make_train_step(cfg, OptConfig(), 1)
    state = step_lib.train_state_shapes(cfg)
    batch = step_lib.batch_shapes(cfg, shape)
    compiled = jax.jit(fn).lower(state, batch).compile()
    hlo = compiled.as_text()

    v5e = MACHINES["tpu_v5e"]
    rep = portmodel.analyze(hlo, v5e)
    war = wa.analyze_text_stores(hlo)
    t_c = rep.flops / PEAK_FLOPS
    t_m = rep.bytes_hbm * war["wa_ratio"] / HBM_BW
    t_x = sum(rep.coll_bytes.values()) / (ICI_BW * 4)
    print(f"arch={args.arch} (smoke) shape={shape.seq_len}x{shape.global_batch}")
    print(f"  flops/step      : {rep.flops:.3e}")
    print(f"  hbm bytes/step  : {rep.bytes_hbm:.3e}  (wa_ratio "
          f"{war['wa_ratio']:.2f})")
    print(f"  T_compute       : {t_c*1e6:10.1f} us")
    print(f"  T_compute(port) : {rep.seconds_incore(v5e)*1e6:10.1f} us")
    print(f"  T_memory        : {t_m*1e6:10.1f} us")
    print(f"  T_collective    : {t_x*1e6:10.1f} us")
    print(f"  bottleneck      : {rep.bottleneck()}  "
          f"(serial/LCD cycles {rep.serial_cycles:.2e})")
    print(f"  loop trips seen : {dict(list(rep.trips_seen.items())[:6])}")


if __name__ == "__main__":
    main()
